"""End-to-end adaptive serving driver (the paper's full pipeline, deliverable
(b): serve a small model with batched requests).

  1. train a tiny target + draft on the same Markov stream (so the draft's
     acceptance l(s) is non-trivial, like a distilled OPT-125M);
  2. PROFILING stage: grid-measure per-token latency over (b, s), build the
     b -> s_opt LUT (paper §4);
  3. EXECUTION stage: serve Gamma-traffic batched requests with the adaptive
     controller vs no-spec / fixed-s baselines on the SAME trace (§5.3);
  4. beyond-paper: the same trace through the LIVE iteration-level
     continuous-batching runtime (serving/scheduler.py) — requests join and
     leave the running batch at speculative-step granularity and s is
     re-chosen from live occupancy every step.

  PYTHONPATH=src python examples/adaptive_serving.py [--requests 32]

The runtime's full study set (paged KV, preemption, chunked prefill, and
sharded serving on a data mesh) lives in benchmarks/fig7_continuous.py
--live [--shards 2]; docs/ARCHITECTURE.md walks the runtime end to end.
"""
import argparse
import dataclasses
import os
import sys

import numpy as np

# make the benchmarks package importable regardless of the invocation cwd
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _ROOT) if _ROOT not in sys.path else None

from benchmarks.common import bench_prompts, get_trained_pair
from repro.core.adaptive import (AdaptiveController, fixed_controller,
                                 measure_acceptance, profile_engine)
from repro.core.analytical import acceptance_curve, fit_power_law
from repro.serving.metrics import mean_occupancy, summarize, ttft_summary
from repro.serving.scheduler import serve_continuous_live
from repro.serving.server import EngineBackend, serve
from repro.serving.traffic import uniform_traffic

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=32)
ap.add_argument("--max-new", type=int, default=32)
ap.add_argument("--max-batch", type=int, default=8)
args = ap.parse_args()

# ---- 1. trained pair (cached in results/bench_models.npz) ----
engine, tparams, dparams, meta = get_trained_pair()
engine.max_new = args.max_new
print(f"pair ready (target loss {meta['target_loss']:.3f}, "
      f"draft loss {meta['draft_loss']:.3f})")

# acceptance sanity: fit l(s) = c s^gamma like paper Fig. 2
pp, pl = bench_prompts(8, seed=5)
runs = measure_acceptance(engine, tparams, dparams, pp, pl, s=6,
                          gen_tokens=24, cache_len=256)
ls = acceptance_curve(runs, range(1, 7))
c, g = fit_power_law(range(1, 7), ls)
print(f"acceptance fit: l(s) ~= {c:.2f} * s^{g:.2f}  (paper: 0.9 s^0.548)")

# ---- 2. profiling stage ----
lut = profile_engine(engine, tparams, dparams, pp, pl,
                     batch_sizes=(1, 2, 4, 8), s_values=range(0, 7),
                     gen_tokens=16, cache_len=256)
print(f"LUT: {lut.table}  (s_opt non-increasing: {lut.is_monotone()})")

# ---- 3. execution stage: same trace, four schemes ----
tcfg = engine.tcfg
trace = lambda: uniform_traffic(args.requests, 0.02, 2.0, tcfg.vocab_size,
                                seed=11, max_new=args.max_new)
backend = EngineBackend(engine, tparams, dparams, cache_len=256)
rows = {}
for name, ctrl in {
    "no_spec": fixed_controller(0),
    "fixed_s2": fixed_controller(2),
    "fixed_s4": fixed_controller(4),
    "adaptive": AdaptiveController(lut=lut),
}.items():
    res = serve(trace(), backend, ctrl, max_batch=args.max_batch)
    rows[name] = summarize(res)
    print(f"{name:9s}: mean {rows[name].mean:.3f}s  p90 {rows[name].p90:.3f}s")

best_fixed = min(rows["fixed_s2"].mean, rows["fixed_s4"].mean)
print(f"\nadaptive vs no-spec : {rows['no_spec'].mean/rows['adaptive'].mean:.2f}x")
print(f"adaptive vs best-fixed: {best_fixed/rows['adaptive'].mean:.2f}x")

# ---- 4. live continuous batching: same trace, iteration-level scheduling ----
res_live = serve_continuous_live(trace(), engine, tparams, dparams,
                                 AdaptiveController(lut=lut),
                                 capacity=args.max_batch, cache_len=256)
live = summarize(res_live)
print(f"\ncontinuous (live slot pool): mean {live.mean:.3f}s  "
      f"p90 {live.p90:.3f}s  TTFT {ttft_summary(res_live).mean:.3f}s  "
      f"mean occupancy {mean_occupancy(res_live):.2f}")
print(f"continuous vs run-to-completion (adaptive): "
      f"{rows['adaptive'].mean/live.mean:.2f}x")
