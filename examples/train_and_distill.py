"""Training-substrate driver: pretrain a small target LM on the synthetic
Markov stream, then train a draft on the same stream and watch the
speculative acceptance rate rise — the systems-level reason the paper's SSM
must "accurately mimic the behavior of the original LLM" (§1).

  PYTHONPATH=src python examples/train_and_distill.py [--steps 120]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig, ModelConfig
from repro.core.adaptive import measure_acceptance
from repro.core.spec_decode import SpecDecodeEngine
from repro.training import (AdamWConfig, DataConfig, batch_at, init_adamw,
                            make_train_step)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--probe-every", type=int, default=60)
args = ap.parse_args()

VOCAB = 512
tcfg = ModelConfig(name="demo-target", family="dense", n_layers=3, d_model=192,
                   d_ff=768, vocab_size=VOCAB,
                   attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=48),
                   dtype="float32")
dcfg = ModelConfig(name="demo-draft", family="dense", n_layers=1, d_model=64,
                   d_ff=256, vocab_size=VOCAB,
                   attn=AttnConfig(n_heads=2, n_kv_heads=2, head_dim=32),
                   dtype="float32")
engine = SpecDecodeEngine(tcfg, dcfg, max_new=32)
dc = DataConfig(vocab_size=VOCAB, batch=16, seq_len=64, alphabet=128,
                skew=0.9, seed=7)


def train(model, cfg, steps, lr, seed):
    params = model.init(jax.random.PRNGKey(seed))
    opt = AdamWConfig(lr=lr, warmup_steps=10, total_steps=steps, weight_decay=0.0)
    st = init_adamw(params)
    step = jax.jit(make_train_step(model, cfg, opt), donate_argnums=(0, 1))
    for i in range(steps):
        params, st, m = step(params, st,
                             {k: jnp.asarray(v) for k, v in batch_at(dc, i).items()})
    return params, float(m["loss"])


def probe_acceptance(tp, dp):
    prompts = batch_at(dataclasses.replace(dc, batch=8), 9999)["tokens"][:, :16]
    lens = np.full((8,), 16, np.int32)
    runs = measure_acceptance(engine, tp, dp, prompts.astype(np.int32),
                              np.asarray(lens), s=4, gen_tokens=16, cache_len=128)
    return float(np.mean(runs))


t0 = time.time()
tparams, tloss = train(engine.target, tcfg, args.steps, 3e-3, 0)
print(f"target trained: loss {tloss:.3f} ({time.time()-t0:.0f}s)")

# draft quality vs training progress
dparams_rand = engine.draft.init(jax.random.PRNGKey(1))
a0 = probe_acceptance(tparams, dparams_rand)
dparams, dloss = train(engine.draft, dcfg, args.steps, 1e-2, 1)
a1 = probe_acceptance(tparams, dparams)
print(f"draft trained: loss {dloss:.3f}")
print(f"mean accepted drafts per step (s=4): untrained {a0:.2f} -> trained {a1:.2f}")
assert a1 > a0, "training the draft must raise acceptance"
print("speculation becomes profitable exactly when the draft mimics the "
      "target — the coupling the adaptive LUT exploits.")
