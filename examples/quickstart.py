"""Quickstart: batched speculative decoding in ~40 lines.

Builds a reduced-config target (yi-9b family) + a tiny draft, runs one batch
of prompts with and without speculation, and prints the per-step acceptance.
Runs on CPU in under a minute.

  PYTHONPATH=src python examples/quickstart.py

Next steps: examples/adaptive_serving.py (the full adaptive pipeline on a
trained pair) and docs/ARCHITECTURE.md (the continuous-batching runtime).
"""
import dataclasses

import jax
import numpy as np

from repro.configs import registry as R
from repro.core.spec_decode import SpecDecodeEngine

# 1. configs: a reduced same-family variant of an assigned architecture,
#    and its draft (the paper's SSM) shrunk to CPU scale
tcfg = R.get_smoke_config("yi-9b")
dcfg = R.get_draft_config("yi-9b")
dcfg = dataclasses.replace(
    dcfg, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
    attn=dataclasses.replace(dcfg.attn, n_heads=2, n_kv_heads=2, head_dim=32))

# 2. engine + params
engine = SpecDecodeEngine(tcfg, dcfg, max_new=24)
tparams = engine.target.init(jax.random.PRNGKey(0))
dparams = engine.draft.init(jax.random.PRNGKey(1))

# 3. a ragged batch of prompts
rng = np.random.default_rng(0)
B, P = 4, 12
prompts = rng.integers(0, tcfg.vocab_size, (B, P)).astype(np.int32)
lens = np.array([12, 9, 7, 10], np.int32)

# 4. speculative generation at s=4 vs plain autoregression (s=0)
out_spec, stats, steps_spec = engine.generate(
    tparams, dparams, prompts, lens, s=4, cache_len=128, collect_stats=True)
out_greedy, _, steps_greedy = engine.generate(
    tparams, dparams, prompts, lens, s=0, cache_len=128)

# 5. the golden invariant: speculation NEVER changes the output stream
np.testing.assert_array_equal(out_spec, out_greedy)
acc = np.mean([st.accepted.mean() for st in stats])
print(f"tokens identical to greedy: True")
print(f"steps: spec={steps_spec} vs greedy={steps_greedy} "
      f"(mean accepted drafts/step: {acc:.2f})")
print(f"first request tokens: {out_spec[0, :12].tolist()}")
print("note: an untrained draft accepts ~0 drafts; see "
      "examples/adaptive_serving.py for a trained pair with real speedups")
