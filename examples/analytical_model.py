"""The paper's analytical model (§3.3), standalone.

Reproduces the monotonicity result — s_opt non-increasing in batch size —
from Eq. 7-12, then projects the same machinery onto the production TPU v5e
mesh via the roofline backend (beyond-paper, DESIGN §8.1): an adaptive LUT
for hardware we never touched, derived from chip peaks + parameter counts.

  PYTHONPATH=src python examples/analytical_model.py
"""
import numpy as np

from repro.configs.base import param_count
from repro.configs.registry import get_config, get_draft_config
from repro.core.adaptive import lut_from_model
from repro.core.analytical import HardwareSpec, LatencyModel, roofline_latency_model

# ---- 1. the paper's own setting: OPT-6.7B + OPT-125M, single accelerator ----
# acceptance fit straight from the paper's Fig. 2: l(s) = 0.9 * s^0.548
c, gamma = 0.9, 0.548

# verify latency t_L(b, s) = alpha_b * s + beta with slopes growing in b
# (shape of paper Fig. 3); numbers loosely scaled to an RTX3090-class device
batches = (1, 2, 4, 8, 16, 32)
alpha = {b: 0.4e-3 * b ** 0.8 for b in batches}
beta = {b: 22e-3 for b in batches}
t_s = {b: 1.2e-3 + 0.05e-3 * b for b in batches}
paper_like = LatencyModel(alpha=alpha, beta=beta, t_s=t_s, c=c, gamma=gamma)

print("=== paper-style analytical model ===")
print("  b   s_opt   per-token(s_opt)  per-token(s=0)  speedup")
prev = 99
for b in batches:
    s = paper_like.s_opt(b)
    t1, t0 = paper_like.per_token_time(b, s), paper_like.per_token_time(b, 0)
    print(f"{b:4d} {s:6d} {t1*1e3:15.2f}ms {t0*1e3:14.2f}ms {t0/t1:8.2f}x")
    assert s <= prev, "monotonicity violated"
    prev = s
print("s_opt is non-increasing in b (paper §3.3.3)  [verified]\n")

# stationarity residual delta(b, s) increasing in both args (Eq. 11-12)
d_small = paper_like.delta(1, 4.0)
d_big_b = paper_like.delta(32, 4.0)
d_big_s = paper_like.delta(1, 8.0)
print(f"delta(1,4)={d_small:.2e}  delta(32,4)={d_big_b:.2e}  "
      f"delta(1,8)={d_big_s:.2e}  (increasing in b and s)\n")

# ---- 2. beyond-paper: roofline LUT for the v5e pod we dry-ran ----
print("=== roofline-projected LUT (TPU v5e, 256-chip pod) ===")
for arch in ("yi-9b", "qwen3-moe-30b-a3b", "deepseek-v2-236b"):
    tcfg, dcfg = get_config(arch), get_draft_config(arch)
    hw = HardwareSpec(chips=256)
    model = roofline_latency_model(
        param_count(tcfg, active_only=tcfg.moe is not None), param_count(dcfg),
        hw, c, gamma, batch_sizes=(1, 8, 32, 128, 512, 2048),
        cache_bytes_per_seq=float(32768 * 1e5 // 1e3))   # ~32k ctx KV rows
    lut = lut_from_model(model, s_max=8)
    print(f"{arch:24s} LUT {lut.table}  monotone={lut.is_monotone()}")
print("\nlarger global batches -> smaller optimal speculation length, even on "
      "a 256-chip pod: the paper's law survives the hardware swap.")
