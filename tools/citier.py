"""Tiered test runner: a fast gate for every PR, the full matrix for merges.

Tiers:
  fast  — ``pytest -m "not slow"``: everything except the >5-minute
          model-consistency matrix and the subprocess pjit dry-run.  This is
          the tier the continuous-batching scheduler tests gate on (~5 min).
  full  — the whole suite including ``slow`` (tier-1 verify,
          ROADMAP "Tier-1 verify" command).

Usage:
  PYTHONPATH=src python tools/citier.py fast [extra pytest args...]
  PYTHONPATH=src python tools/citier.py full
"""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TIERS = {
    "fast": ["-m", "not slow"],
    "full": [],
}


def main(argv):
    tier = argv[0] if argv else "fast"
    if tier not in TIERS:
        print(f"unknown tier {tier!r}; pick one of {sorted(TIERS)}")
        return 2
    env = dict(os.environ)
    src = os.path.join(ROOT, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "pytest", "-q", *TIERS[tier], *argv[1:]]
    print("$", " ".join(cmd), flush=True)
    return subprocess.call(cmd, cwd=ROOT, env=env)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
