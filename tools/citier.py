"""Tiered test runner: a fast gate for every PR, the full matrix for merges.

Tiers:
  fast    — the ``docs`` check, then ``pytest -m "not slow"``: everything
            except the >5-minute model-consistency matrix and the
            subprocess pjit dry-run.  This is the tier the
            continuous-batching scheduler tests gate on (~5 min).
  full    — the ``docs`` check, then the whole suite including ``slow``
            (tier-1 verify, ROADMAP "Tier-1 verify" command).
  kernels — interpret-mode kernel parity tests only (tests/test_kernels.py
            + tests/test_paged_fused_kernel.py +
            tests/test_ragged_paged_attn.py): the Pallas kernel bodies
            against the pure-jnp oracles, the fused paged kernel against
            gather+verify, and the ragged real-length-grid kernel (manual
            DMA depths, mixed verify+chunk launch) against both.  A subset
            of ``fast`` for quick kernel iteration; runs inside fast/full
            automatically (the files carry no ``slow`` marker).
  cache   — prefix-cache subset: the copy-on-write refcount/radix property
            campaign plus the shared-vs-cold parity tests
            (tests/test_prefix_cache.py), then the serving-bench smoke,
            whose sim_templated scenario gates hit-rate > 0 and a cached
            TTFT win.  A subset of ``fast`` (the file carries no ``slow``
            marker) for quick iteration on the sharing layer.
  obs     — observability subset: telemetry read-only-parity tests
            (tests/test_telemetry.py) + the serving/metrics unit tests
            (tests/test_metrics.py), then the serving-bench regression
            smoke (``benchmarks/serving_bench.py --check --sim-only``)
            against the committed results/BENCH_serving.json.  The bench
            smoke also runs at the end of fast and full.
  docs    — documentation-hygiene gate only, no pytest: fails when
            README.md or docs/ARCHITECTURE.md is missing, or when any
            module under src/repro/serving/, src/repro/core/ or
            src/repro/kernels/ lacks a module docstring (the serving
            layer is the repo's public runtime surface and core/kernels
            carry the invariants; an undocumented module there is a
            regression).
  lint    — repro-lint static analysis only (``python -m tools.lint
            src``): the AST invariant checker for the runtime's standing
            contracts (docs/ARCHITECTURE.md "Enforced invariants").
            Nonzero on findings; a run that collects zero files is
            treated as a failure, same as pytest exit code 5.  Runs at
            the head of fast and full.
  graph   — graph-lint compiled-artifact checks (``python -m
            tools.graphlint``): replays a tiny serving trace through the
            real engine and checks every registered jit's jaxpr/HLO —
            transfer-free hot paths, no gathered-KV materialization on
            the fused paged path, KV pool donation actually aliased in
            the lowering, sharding conformance, and a retrace guard
            (docs/ARCHITECTURE.md "Compiled-graph contracts").  A run
            that collects zero jits exits 5 and is loud-failed like a
            zero-test pytest run.  Runs at the head of fast and full,
            after lint.

Usage:
  PYTHONPATH=src python tools/citier.py fast [extra pytest args...]
  PYTHONPATH=src python tools/citier.py full
  PYTHONPATH=src python tools/citier.py kernels
  python tools/citier.py docs
  python tools/citier.py lint [lint targets/flags...]
  python tools/citier.py graph [graphlint flags...]

The runner sets PYTHONPATH itself, then sanity-checks that ``repro`` is
actually importable with that environment and that pytest collected at
least one test — a broken src layout or pytest exit code 5 ("no tests
collected") previously looked like a green run.
"""
import ast
import glob
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TIERS = {
    "fast": ["-m", "not slow"],
    "full": [],
    # kernel parity subset (also contained in fast/full): the Pallas kernel
    # bodies (interpret mode) vs the jnp oracles, incl. the fused paged path
    "kernels": [os.path.join("tests", "test_kernels.py"),
                os.path.join("tests", "test_paged_fused_kernel.py"),
                os.path.join("tests", "test_ragged_paged_attn.py")],
    # prefix-cache subset: COW/refcount property campaign + parity tests
    # (the bench smoke with its hit-rate/TTFT gates runs after pytest)
    "cache": [os.path.join("tests", "test_prefix_cache.py")],
    # observability subset: telemetry parity + metrics units (the serving
    # bench smoke runs after pytest — see SERVING_SMOKE_TIERS)
    "obs": [os.path.join("tests", "test_telemetry.py"),
            os.path.join("tests", "test_metrics.py")],
}

# tiers that finish with the serving-bench regression smoke (sim scenarios
# are deterministic and take seconds; exits nonzero on goodput/TTFT drift
# against the committed results/BENCH_serving.json)
SERVING_SMOKE_TIERS = ("fast", "full", "obs", "cache")

# pytest's "no tests were collected" exit code — a vacuous pass, not a pass
EXIT_NO_TESTS_COLLECTED = 5

# files whose absence fails the docs gate
REQUIRED_DOCS = ["README.md", os.path.join("docs", "ARCHITECTURE.md")]
# every module here must carry a module docstring
DOCSTRING_DIRS = [os.path.join("src", "repro", "serving"),
                  os.path.join("src", "repro", "core"),
                  os.path.join("src", "repro", "kernels")]

# tiers that open with the repro-lint invariant gate (cheap, pure-AST)
LINT_TIERS = ("fast", "full")

# tiers that then run the graph-lint compiled-artifact gate (a few minutes:
# it traces, lowers and replays the engine's actual jits)
GRAPH_TIERS = ("fast", "full")


def docs_check() -> int:
    """Documentation-hygiene gate (tier ``docs``; also runs before every
    pytest tier).  Returns 0 when clean, 2 with a problem list on stderr."""
    problems = []
    for rel in REQUIRED_DOCS:
        if not os.path.isfile(os.path.join(ROOT, rel)):
            problems.append(f"missing required doc: {rel}")
    for d in DOCSTRING_DIRS:
        for path in sorted(glob.glob(os.path.join(ROOT, d, "*.py"))):
            rel = os.path.relpath(path, ROOT)
            try:
                tree = ast.parse(open(path, encoding="utf-8").read())
            except SyntaxError as e:
                problems.append(f"{rel}: unparseable ({e})")
                continue
            if not ast.get_docstring(tree):
                problems.append(f"{rel}: missing module docstring")
    if problems:
        print("citier docs check FAILED:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 2
    print("citier docs check OK "
          f"({len(REQUIRED_DOCS)} required docs, module docstrings under "
          + ", ".join(DOCSTRING_DIRS) + ")")
    return 0


def lint_check(extra=None) -> int:
    """repro-lint gate (tier ``lint``; also opens the fast/full tiers).
    Forwards extra CLI args so a fixture directory can be linted in place
    of the default ``src`` target.  Returns 0 when clean; a zero-file run
    is loud-failed like a zero-test pytest run."""
    cmd = [sys.executable, "-m", "tools.lint",
           *(extra if extra else ["src"])]
    print("$", " ".join(cmd), flush=True)
    rc = subprocess.call(cmd, cwd=ROOT)
    if rc == EXIT_NO_TESTS_COLLECTED:
        print("citier: repro-lint collected ZERO files — treating the "
              "vacuous run as a failure (bad target path?)",
              file=sys.stderr)
        return 2
    if rc:
        print("citier: repro-lint FAILED — the tree violates a standing "
              "contract (see findings above; fix it or add a justified "
              "`# lint: allow-<rule>(reason)` pragma)", file=sys.stderr)
    return rc


def graph_check(extra=None) -> int:
    """graph-lint gate (tier ``graph``; also runs inside fast/full after
    lint).  Forwards extra CLI args (e.g. ``--json``, ``--inject`` for the
    loudness self-test).  A zero-jit collection (exit 5) is a vacuous run
    and fails loudly."""
    cmd = [sys.executable, "-m", "tools.graphlint", *(extra or [])]
    print("$", " ".join(cmd), flush=True)
    rc = subprocess.call(cmd, cwd=ROOT)
    if rc == EXIT_NO_TESTS_COLLECTED:
        print("citier: graph-lint collected ZERO jits — the serving replay "
              "registered nothing; treating the vacuous run as a failure",
              file=sys.stderr)
        return 2
    if rc:
        print("citier: graph-lint FAILED — a compiled engine jit violates "
              "a standing contract (see findings above; fix it or add a "
              "justified `# graphlint: allow-<pass>(reason)` pragma)",
              file=sys.stderr)
    return rc


def build_env() -> dict:
    """os.environ with ROOT/src prepended to PYTHONPATH, validated loudly."""
    src = os.path.join(ROOT, "src")
    if not os.path.isdir(os.path.join(src, "repro")):
        raise SystemExit(
            f"citier: {src}/repro does not exist — cannot build a PYTHONPATH "
            f"that makes the test suite importable")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def check_importable(env: dict) -> None:
    """Fail loudly if ``repro`` cannot be imported with ``env`` — otherwise
    pytest quietly fails collection (or collects zero tests) and the tier
    looks green for the wrong reason."""
    probe = subprocess.run([sys.executable, "-c", "import repro"],
                           env=env, cwd=ROOT, capture_output=True, text=True)
    if probe.returncode != 0:
        raise SystemExit(
            "citier: `import repro` failed with the runner's PYTHONPATH "
            f"({env.get('PYTHONPATH')!r}) — refusing to run a suite that "
            f"would collect zero tests:\n{probe.stderr.strip()}")


def main(argv):
    tier = argv[0] if argv else "fast"
    if tier == "docs":
        return docs_check()
    if tier == "lint":
        return lint_check(argv[1:])
    if tier == "graph":
        return graph_check(argv[1:])
    if tier not in TIERS:
        print(f"unknown tier {tier!r}; pick one of "
              f"{sorted([*TIERS, 'docs', 'graph', 'lint'])}")
        return 2
    rc = docs_check()
    if rc:
        return rc
    if tier in LINT_TIERS:
        rc = lint_check()
        if rc:
            return rc
    if tier in GRAPH_TIERS:
        rc = graph_check()
        if rc:
            return rc
    env = build_env()
    check_importable(env)
    cmd = [sys.executable, "-m", "pytest", "-q", *TIERS[tier], *argv[1:]]
    print("$", " ".join(cmd), flush=True)
    rc = subprocess.call(cmd, cwd=ROOT, env=env)
    if rc == EXIT_NO_TESTS_COLLECTED:
        print("citier: pytest collected ZERO tests — treating the vacuous "
              "run as a failure (is PYTHONPATH missing src, or the tests "
              "directory empty?)", file=sys.stderr)
        return 2
    if rc == 0 and tier in SERVING_SMOKE_TIERS:
        smoke = [sys.executable,
                 os.path.join("benchmarks", "serving_bench.py"),
                 "--check", "--sim-only"]
        print("$", " ".join(smoke), flush=True)
        src = subprocess.call(smoke, cwd=ROOT, env=env)
        if src:
            print("citier: serving bench regression smoke FAILED "
                  "(see problems above)", file=sys.stderr)
            return src
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
