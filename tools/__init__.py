"""Developer tooling for the repro runtime: the tiered CI runner
(``tools/citier.py``) and the repro-lint static analyzer (``tools.lint``)."""
