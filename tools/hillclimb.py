"""§Perf hillclimb driver: run named (arch, shape, lever) experiments through
the production dry-run and record before/after roofline terms.

  PYTHONPATH=src python tools/hillclimb.py <experiment> [...]
  PYTHONPATH=src python tools/hillclimb.py --list

Each experiment re-runs launch/dryrun.run_one with a lever (sharding-rule
override, config transform, spec length) on the single-pod mesh and writes
results/perf/<name>.json.  Baselines are the untouched results/dryrun/
records.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

import dataclasses
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import dryrun as D


def moe_gather(cfg):
    return cfg.with_(moe=dataclasses.replace(cfg.moe, dispatch="gather"))


def draft_window(w):
    return {"draft_rules_overrides": None}  # placeholder, see below


EXPERIMENTS = {
    # ---- pair A: qwen3-moe-30b-a3b x decode_32k (paper-representative) ----
    "A1_qwen3moe_gather_dispatch": dict(
        arch="qwen3-moe-30b-a3b", shape="decode_32k",
        plan_kw={"transform": moe_gather},
        hypothesis="one-hot dispatch/combine einsums cost 2.6e12 flops/step "
                   "(> the experts' own 2.3e12); sort-based gather dispatch "
                   "removes them -> compute term -40%"),
    "A2_qwen3moe_higher_s": dict(
        arch="qwen3-moe-30b-a3b", shape="decode_32k",
        plan_kw={"transform": moe_gather, "s": 8},
        hypothesis="memory term is weight+cache streaming amortized over "
                   "committed tokens; s=8 doubles verified tokens per sweep "
                   "-> per-TOKEN memory cost drops ~2x if acceptance holds"),
    "A3_qwen3moe_kv_int8": dict(
        arch="qwen3-moe-30b-a3b", shape="decode_32k",
        plan_kw={"transform": lambda cfg: cfg.with_(
            kv_quant=True, moe=dataclasses.replace(cfg.moe, dispatch="gather"))},
        hypothesis="the 412 GB/step KV-cache sweep is 67% of the memory term "
                   "(weights only 61 GB); int8 cache with per-row scales "
                   "halves it -> memory term -33%, still correct decode "
                   "(golden invariant holds; logits err ~5e-3)"),
    # ---- pair B: deepseek-v2-236b x decode_32k (capacity + MLA) ----
    "B1_deepseek_expert_fsdp": dict(
        arch="deepseek-v2-236b", shape="decode_32k",
        plan_kw={"rules_overrides": {"expert_ff": "data"}},
        hypothesis="routed-expert weights (29.5 GiB/dev) are replicated "
                   "across the data axis and blow the 16 GiB HBM budget; "
                   "sharding d_ff_expert over data=16 cuts weight residency "
                   "~16x while moving only activation-sized collectives"),
    "B2_deepseek_fsdp_plus_gather": dict(
        arch="deepseek-v2-236b", shape="decode_32k",
        plan_kw={"rules_overrides": {"expert_ff": "data"},
                 "transform": moe_gather},
        hypothesis="B1 + A1 compose: dispatch einsums are 6e12 flops here"),
    "B3_deepseek_train_fsdp": dict(
        arch="deepseek-v2-236b", shape="train_4k",
        plan_kw={"rules_overrides": {"expert_ff": "data"}},
        hypothesis="train_4k args are 144 GiB/dev (fp32 AdamW m/v of 236B "
                   "params replicated over data); expert-ff FSDP shards the "
                   "dominant expert m/v 16x more -> ~9x smaller residency, "
                   "gradient all-reduce unchanged (it becomes reduce-scatter "
                   "sized by the sharded dim)"),
    "B4_deepseek_train_full_zero3": dict(
        arch="deepseek-v2-236b", shape="train_4k",
        plan_kw={"rules_overrides": {"expert_ff": "data", "d_model": "data"}},
        hypothesis="B3 leaves 20.5 GiB/dev (dense attention/MLA params + "
                   "their fp32 m/v still replicated over data); sharding "
                   "d_model over data = full ZeRO-3 -> under the 16 GiB "
                   "budget, at the cost of per-layer weight all-gathers "
                   "(acceptable at train arithmetic intensity)"),
    "C3_mamba2_higher_s": dict(
        arch="mamba2-1.3b", shape="decode_32k",
        plan_kw={"s": 8},
        hypothesis="after the commit fix the SSM decode is memory-bound on "
                   "WEIGHT streaming (2.9 GB/step, no big cache to sweep); "
                   "s=8 amortizes the same sweep over ~30% more committed "
                   "tokens (l sublinear) -> per-token memory down, and "
                   "checkpoint traffic (state x s+1) is the only cost"),
    "C4_mamba2_cheap_draft": dict(
        arch="mamba2-1.3b", shape="decode_32k",
        plan_kw={"draft_transform": lambda d: d.with_(
            kv_quant=True,
            attn=__import__("dataclasses").replace(d.attn, window=1024))},
        hypothesis="C3 refuted because draft streaming (18 GB/step, 53% of "
                   "the sweep growth) outpaces sublinear acceptance; int8 + "
                   "1k window on the draft cuts its cache sweep ~8x, making "
                   "the target weights the true floor"),
    "C5_mamba2_cheap_draft_s8": dict(
        arch="mamba2-1.3b", shape="decode_32k",
        plan_kw={"s": 8,
                 "draft_transform": lambda d: d.with_(
            kv_quant=True,
            attn=__import__("dataclasses").replace(d.attn, window=1024))},
        hypothesis="with the draft cheapened (C4), retry s=8: the fixed "
                   "target sweep now amortizes over l(8)+1=3.8 tokens vs "
                   "2.9 -> per-token memory should finally drop"),
    # ---- pair C: mamba2-1.3b x decode_32k (most collective-bound) ----
    "C1_mamba2_replicated_embed": dict(
        arch="mamba2-1.3b", shape="decode_32k",
        plan_kw={"rules_overrides": {"vocab": None}},
        hypothesis="the vocab-sharded embedding gather + logits all-reduce "
                   "dominate the 826 MB/step collectives; the table is only "
                   "206 MB - replicating it trades 206 MB HBM/dev for "
                   "killing the per-step embed/unembed collectives"),
}


def main(argv):
    if "--list" in argv or not argv:
        for k, v in EXPERIMENTS.items():
            print(f"{k}: {v['hypothesis'][:100]}")
        return
    os.makedirs("results/perf", exist_ok=True)
    for name in argv:
        exp = EXPERIMENTS[name]
        print(f"=== {name} ===\nhypothesis: {exp['hypothesis']}", flush=True)
        rec = D.run_one(exp["arch"], exp["shape"], "pod", exp["plan_kw"])
        rec["experiment"] = name
        rec["hypothesis"] = exp["hypothesis"]
        with open(f"results/perf/{name}.json", "w") as f:
            json.dump(rec, f, indent=1, default=float)
        base_path = f"results/dryrun/{exp['arch']}__{exp['shape']}__pod.json"
        base = json.load(open(base_path)) if os.path.exists(base_path) else None
        r = rec["roofline"]
        print(f"after : compute={r['compute_s']:.3e} memory={r['memory_s']:.3e} "
              f"coll={r['collective_s']:.3e} dom={r['dominant']} "
              f"arg/dev={rec['memory']['argument_bytes']/2**30:.2f}GiB")
        if base:
            b = base["roofline"]
            print(f"before: compute={b['compute_s']:.3e} memory={b['memory_s']:.3e} "
                  f"coll={b['collective_s']:.3e} dom={b['dominant']} "
                  f"arg/dev={base['memory']['argument_bytes']/2**30:.2f}GiB",
                  flush=True)


def _a4_draft(cfg):
    import dataclasses as dc
    return cfg.with_(kv_quant=True,
                     attn=dc.replace(cfg.attn, window=2048))


EXPERIMENTS["A4_qwen3moe_draft_window_int8"] = dict(
    arch="qwen3-moe-30b-a3b", shape="decode_32k",
    plan_kw={"transform": lambda c: __import__("dataclasses").replace(
                 c, kv_quant=True,
                 moe=__import__("dataclasses").replace(c.moe, dispatch="gather")),
             "draft_transform": _a4_draft},
    hypothesis="after A3 the draft's 137 GB/step cache sweep (34 GB x s=4 "
               "calls at full 32k context) is the next slab; a 2k sliding "
               "window + int8 on the DRAFT cache cuts it ~30x (drafts only "
               "need local context to propose) -> memory term -25%")


# bonus appendix: the B-series ZeRO levers applied to the remaining
# over-HBM-budget train cells from the baseline sweep
for _arch, _name in [("yi-34b", "X1_yi34b_train_zero3"),
                     ("qwen3-moe-30b-a3b", "X2_qwen3moe_train_zero3"),
                     ("yi-9b", "X3_yi9b_train_zero3")]:
    EXPERIMENTS[_name] = dict(
        arch=_arch, shape="train_4k",
        plan_kw={"rules_overrides": {"expert_ff": "data", "d_model": "data"}},
        hypothesis=f"{_arch} train_4k exceeds the 16 GiB/dev budget at "
                   "baseline (fp32 m/v replicated over data); the B4 ZeRO-3 "
                   "overrides apply verbatim")


for _arch, _shape, _name, _rules in [
        ("deepseek-v2-236b", "prefill_32k", "X4_deepseek_prefill_fsdp",
         {"expert_ff": "data"}),
        ("deepseek-v2-236b", "long_500k", "X5_deepseek_long_fsdp",
         {"expert_ff": "data"}),
        ("yi-34b", "decode_32k", "X6_yi34b_decode_int8", None)]:
    EXPERIMENTS[_name] = dict(
        arch=_arch, shape=_shape,
        plan_kw=({"rules_overrides": _rules} if _rules else
                 {"transform": lambda c: c.with_(kv_quant=True),
                  "draft_transform": lambda d: d.with_(kv_quant=True)}),
        hypothesis=f"close the remaining over-budget {_arch} x {_shape} "
                   "cell with the already-validated lever")


if __name__ == "__main__":
    main(sys.argv[1:])
