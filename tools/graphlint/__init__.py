"""graph-lint: jaxpr/HLO-level contract checking for every engine jit.

repro-lint (tools/lint) enforces the runtime's standing contracts at the
*source* level; graph-lint enforces them on the *compiled artifact*.  It
drives a tiny but complete serving replay through the real
:class:`~repro.core.spec_decode.SpecDecodeEngine` (paged pool, fused
kernel, chunked admission, adaptive-s sweep, retirement — plus a sharded
contiguous pool), harvests the engine's jit registry
(``SpecDecodeEngine.jit_registry``, populated by ``_register_jit`` for
every compiled function the dispatch loop can ever run), and then checks
each entry's jaxpr / lowered StableHLO / compiled executable:

* ``transfer-free`` — no host callback / infeed / outfeed primitive inside
  any per-iteration jit;
* ``no-materialization`` — the fused paged path never produces a
  ``[B, MAXB*bs, KVH, hd]`` gathered-KV-shaped intermediate (the PR 5
  kernel proof, generalized from ``benchmarks/kernel_bench.py`` to every
  registered step/chunk jit, with a gather-path probe that keeps the
  check non-vacuous);
* ``donation`` — the KV pool / cache leaves of the state-threading jits
  are donated and actually input-output aliased in the lowered HLO
  (``tf.aliasing_output``), so the multi-GB pool is never double-buffered;
* ``sharding-conformance`` — every jit of a sharded engine was built with
  explicit shardings and its *compiled* output shardings match the
  declared :class:`~repro.core.spec_decode.PoolShardings`;
* ``retrace`` — replaying the same trace twice, every jit compiles exactly
  once per distinct (name, key) and the second run compiles nothing.

CLI mirrors repro-lint: ``python -m tools.graphlint`` (human output) or
``--json`` (sorted, diffable); exit 0 clean / 1 findings / 2 usage /
5 zero jits collected (a vacuous run is a failure).  Findings anchor to
the jitted function's ``def`` site, so line-scoped
``# graphlint: allow-<pass>(reason)`` pragmas — same grammar and
stale/malformed policing as repro-lint's — can suppress them.
``tools/citier.py graph`` is the CI gate (head of fast/full).
"""
