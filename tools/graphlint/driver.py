"""graph-lint collection driver: run the real engine, harvest its jits.

graph-lint does not construct jits by hand — that list would drift the
first time the engine grew a new dispatch path.  Instead it replays a
tiny but complete serving trace through ``serve_continuous_live`` and
reads back :attr:`SpecDecodeEngine.jit_registry`, so the checked set is
*exactly* the set of compiled functions the dispatch loop ran.  Three
collections:

* ``paged-fused`` — the main replay: paged pool, fused kernel forced,
  chunked admission (budget below the longest prompts), adaptive-s sweep
  (LUT spanning s=2..3 over occupancy), retirement, run twice with
  identical requests against the same backend for the retrace pass;
* ``gather-probe`` — one real step on a ``paged_fused=False`` engine:
  the known-materializing path that keeps the no-materialization
  detector honest;
* ``sharded`` — the contiguous replay on a 2-device host mesh (run
  twice, same backend), feeding the sharding-conformance pass.  Only
  collected when >= 2 devices are visible: the CLI forces
  ``--xla_force_host_platform_device_count=2`` before importing jax,
  in-process callers (tests) may skip it.

The model pair is the yi-9b smoke target (KVH=2, hd=32) with a draft
whose KV geometry deliberately differs (KVH=1, hd=16): the draft's
contiguous ring cache legitimately carries ``logical_len`` rows, so the
no-materialization trailing-dims filter must be able to tell the two
apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import registry as R
from repro.core.adaptive import AdaptiveController, SpeculationLUT
from repro.core.spec_decode import JitEntry, SpecDecodeEngine
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     PrefillBudgetAdmit,
                                     serve_continuous_live)

Key = Tuple[str, Tuple]

CAPACITY = 3
CACHE_LEN = 32
BLOCK_SIZE = 8
MAX_NEW = 10
CHUNK_BUDGET = 6          # below the longest prompts => chunked admission
SHARD_CAPACITY = 4        # must split evenly over the 2-device mesh


@dataclasses.dataclass
class Collection:
    """One driven engine plus everything the passes need from it."""
    label: str
    engine: Any
    entries: List[JitEntry]
    run1: Dict[Key, int]            # n_traces per entry after replay 1
    run2: Dict[Key, int]            # additional traces from replay 2
    kv_trailing: Tuple[int, int]    # target (n_kv_heads, head_dim)


def configs():
    """Tiny target/draft pair with *distinct* KV geometries (see module
    docstring)."""
    tcfg = R.get_smoke_config("yi-9b")
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=32, d_ff=64, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=1,
                                 head_dim=16))
    return tcfg, dcfg


_PARAMS: Optional[Tuple[Any, Any]] = None


def params(tcfg, dcfg):
    global _PARAMS
    if _PARAMS is None:
        eng = SpecDecodeEngine(tcfg, dcfg, max_new=MAX_NEW)
        _PARAMS = (eng.target.init(jax.random.PRNGKey(0)),
                   eng.draft.init(jax.random.PRNGKey(1)))
    return _PARAMS


def requests(tcfg, n=5) -> List[Request]:
    """Deterministic replay trace: prompt lengths straddle CHUNK_BUDGET so
    some admissions chunk and some do not; arrivals are all zero so the
    composition is structural, not wall-clock dependent."""
    rng = np.random.default_rng(11)
    reqs = []
    for rid in range(n):
        L = int(rng.integers(5, 12))
        toks = rng.integers(0, tcfg.vocab_size, (L,)).astype(np.int32)
        reqs.append(Request(rid=rid, arrival=0.0, tokens=toks, prompt_len=L,
                            max_new=int(rng.integers(4, 9))))
    return reqs


def _ctrl() -> AdaptiveController:
    # s varies with batch bucket => the replay sweeps multiple (B, s) steps
    return AdaptiveController(lut=SpeculationLUT({1: 3, 2: 2, 4: 2}))


def _snap(eng) -> Dict[Key, int]:
    return {k: e.n_traces for k, e in eng.jit_registry.items()}


def _delta(eng, base: Dict[Key, int]) -> Dict[Key, int]:
    return {k: e.n_traces - base.get(k, 0)
            for k, e in eng.jit_registry.items()}


def _trailing(tcfg) -> Tuple[int, int]:
    return (tcfg.attn.n_kv_heads, tcfg.attn.head_dim)


def _replay_twice(label, tcfg, eng, be, policy, inject_retrace) -> Collection:
    """Serve the same trace twice against one live backend.  Requests are
    rebuilt per run (serving mutates them); the engine's jit caches and
    registry persist across runs, so run 2 must be a cache hit end to end
    — that delta is the retrace pass's input."""
    tp, dp = params(*configs())
    serve_continuous_live(requests(tcfg), eng, tp, dp, _ctrl(),
                          backend=be, policy=policy)
    run1 = _snap(eng)
    if inject_retrace:
        # deliberate violation for --inject retrace / the CI loudness test:
        # dropping the compiled caches makes replay 2 re-trace everything
        for e in eng.jit_registry.values():
            e.fn.clear_cache()
    serve_continuous_live(requests(tcfg), eng, tp, dp, _ctrl(),
                          backend=be, policy=policy)
    return Collection(label=label, engine=eng,
                      entries=list(eng.jit_registry.values()),
                      run1=run1, run2=_delta(eng, run1),
                      kv_trailing=_trailing(tcfg))


def collect_fused(donate: bool = True,
                  inject_retrace: bool = False) -> Collection:
    """Main replay: paged pool + fused ragged kernel + chunked admission
    with the mixed verify+chunk launch on, so the registry carries
    ``step_mixed`` jits for the ragged-grid / no-materialization passes."""
    tcfg, dcfg = configs()
    tp, dp = params(tcfg, dcfg)
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=MAX_NEW, donate=donate)
    be = ContinuousEngineBackend(eng, tp, dp, capacity=CAPACITY,
                                 cache_len=CACHE_LEN, warm_s=[2, 3],
                                 block_size=BLOCK_SIZE, paged_fused=True,
                                 mixed_launch=True)
    return _replay_twice("paged-fused", tcfg, eng, be,
                         PrefillBudgetAdmit(token_budget=CHUNK_BUDGET),
                         inject_retrace)


def collect_gather_probe() -> Collection:
    """One real admit + step on the gather path (``paged_fused=False``):
    its step jit is the known-materializing control for the
    no-materialization vacuousness guard."""
    tcfg, dcfg = configs()
    tp, dp = params(tcfg, dcfg)
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=MAX_NEW, paged_fused=False)
    state = eng.init_slots(CAPACITY, CACHE_LEN, block_size=BLOCK_SIZE)
    toks = np.arange(6, dtype=np.int32) % tcfg.vocab_size
    state = eng.prefill_into(tp, dp, state, 0, toks, len(toks), CACHE_LEN)
    state, _ = eng.step(tp, dp, state, 3)
    return Collection(label="gather-probe", engine=eng,
                      entries=list(eng.jit_registry.values()),
                      run1=_snap(eng), run2={},
                      kv_trailing=_trailing(tcfg))


def collect_sharded(inject_retrace: bool = False) -> Optional[Collection]:
    """Contiguous replay on a 2-device host mesh, for the
    sharding-conformance pass.  Returns None when fewer than 2 devices are
    visible (the CLI env guarantees 2; in-process callers may not)."""
    if len(jax.devices()) < 2:
        return None
    from repro.launch.mesh import make_serving_mesh
    tcfg, dcfg = configs()
    tp, dp = params(tcfg, dcfg)
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=MAX_NEW)
    be = ContinuousEngineBackend(eng, tp, dp, capacity=SHARD_CAPACITY,
                                 cache_len=CACHE_LEN, warm_s=[2, 3],
                                 mesh=make_serving_mesh(2))
    return _replay_twice("sharded", tcfg, eng, be, None, inject_retrace)
