"""donation: KV pool / cache leaves are donated and actually aliased.

The paged pool and contiguous caches are the engine's only multi-GB
buffers; every state-threading jit (step, inject, retire, chunk) rewrites
them in place *semantically*, so without ``donate_argnums`` XLA double-
buffers the pool on every dispatch.  This pass checks, per registered jit
of a donating family:

1. the registry's contract holds — ``kv_args`` is non-empty (a donating
   family registered without declared KV argnums is a refactor that lost
   the annotation);
2. the engine actually donated them — ``donate == kv_args`` (an engine
   built with ``donate=False`` serving production traffic fails here);
3. the lowering agrees — the StableHLO carries at least one
   ``tf.aliasing_output`` attribute per flat leaf of the donated args
   (donation that XLA silently declines — dtype/layout mismatch between
   an input leaf and every output — double-buffers anyway, with no
   warning on this jax version; the attribute count is the proof).
"""
from __future__ import annotations

from typing import List

from tools.lint.report import Finding

PASS = "donation"

# jit families that thread pool/cache state and must donate it.  prefill
# is absent by design: it *creates* the per-request caches from nothing
# and chunk_begin's paged variant returns its tpos input untouched (the
# caller keeps the input buffer), so its kv_args already exclude it.
DONATING_NAMES = (
    "step", "inject", "inject_paged", "retire", "retire_paged",
    "chunk", "chunk_begin", "chunk_commit",
)

ALIAS_ATTR = "tf.aliasing_output"


def check(entries, lowered_texts) -> List[Finding]:
    """``lowered_texts`` maps ``(name, key)`` to the entry's lowered
    StableHLO text (``entry.fn.lower(*entry.arg_specs).as_text()``,
    produced once by the CLI)."""
    import jax

    findings: List[Finding] = []

    def emit(entry, message):
        findings.append(Finding(
            file=entry.src_file, line=entry.src_line, col=0,
            rule=PASS, severity="error",
            message=f"jit {entry.name}{entry.key}: {message}"))

    for entry in entries:
        if entry.name not in DONATING_NAMES:
            continue
        if not entry.kv_args:
            emit(entry, "state-threading jit registered without kv_args — "
                        "the KV argnum annotation was lost")
            continue
        if tuple(entry.donate) != tuple(entry.kv_args):
            emit(entry, f"KV pool/cache args {tuple(entry.kv_args)} are not "
                        f"donated (donate_argnums={tuple(entry.donate)}) — "
                        "every dispatch double-buffers the pool")
            continue
        text = lowered_texts.get((entry.name, entry.key))
        if text is None or entry.arg_specs is None:
            continue
        expected = sum(len(jax.tree.leaves(entry.arg_specs[i]))
                       for i in entry.donate if i < len(entry.arg_specs))
        if expected == 0:
            continue  # donated args traced as empty pytrees: nothing to alias
        got = text.count(ALIAS_ATTR)
        if got < expected:
            emit(entry, f"donated {expected} KV leaves but lowered HLO "
                        f"aliases only {got} ({ALIAS_ATTR}) — XLA declined "
                        "the donation, the pool is double-buffered")
    return findings
