"""retrace: every jit compiles exactly once per (name, key), ever.

The engine's whole dispatch design — shape buckets, pow2 prompt padding,
the warm list, static (B, s) step keys — exists so the serving loop never
pays a trace mid-flight.  A silent retrace (weak-type flip-flop, a Python
scalar that should be a jnp array, a tuple that should be static) costs
hundreds of ms per occurrence and is invisible to tests that only check
tokens.  The registry's trace counter (incremented inside the traced
body, so it costs nothing on cached dispatch) makes it checkable:

* after one full serving replay, every registered entry must have traced
  exactly once — more means something retraced mid-run, zero would mean
  the registry recorded a jit that never ran (impossible by construction,
  but checked anyway);
* after a second *identical* replay against the same engine, the delta
  must be zero for every entry — the replay is a cache hit end to end.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from tools.lint.report import Finding

PASS = "retrace"

Key = Tuple[str, Tuple]


def check(entries, run1: Dict[Key, int], run2: Dict[Key, int]) -> List[Finding]:
    """``run1``: n_traces per entry key after the first replay.  ``run2``:
    *additional* traces accumulated by the second, identical replay."""
    findings: List[Finding] = []

    def emit(entry, message):
        findings.append(Finding(
            file=entry.src_file, line=entry.src_line, col=0,
            rule=PASS, severity="error",
            message=f"jit {entry.name}{entry.key}: {message}"))

    for entry in entries:
        key = (entry.name, entry.key)
        n1 = run1.get(key)
        if n1 is None:
            continue  # entry born after the snapshot (e.g. probe-only jits)
        if n1 != 1:
            emit(entry, f"traced {n1}x during a single serving replay — "
                        "expected exactly once per (name, key); something "
                        "recompiles mid-flight")
            continue
        n2 = run2.get(key, 0)
        if n2 != 0:
            emit(entry, f"retraced {n2}x on an identical second replay — "
                        "the compilation cache misses on repeat traffic")
    return findings
