"""sharding-conformance: compiled output shardings match the declaration.

A sharded pool (init_slots with a mesh) declares NamedShardings for every
pool leaf (:class:`~repro.core.spec_decode.PoolShardings`) and threads
them into each jit as in/out_shardings.  Two drifts this pass catches:

* a builder call site that stops passing shardings — the jit still runs
  (GSPMD infers something) but the pool silently de-shards or gathers on
  dispatch boundaries (``sharded=False`` on an entry of a sharded engine);
* a declared sharding the *compiled* executable does not honor — compare
  ``compiled.output_shardings`` leaf-by-leaf against the declared tree
  via ``Sharding.is_equivalent_to`` (spec-level equality, robust to
  mesh-object identity).

Declarations are pytree prefixes (jax.jit semantics): a single sharding
or ``None`` broadcasts over the corresponding output subtree; ``None``
leaves declare nothing and are skipped.
"""
from __future__ import annotations

from typing import Any, List, Tuple

from tools.lint.report import Finding

PASS = "sharding-conformance"


def _is_sharding(x) -> bool:
    import jax
    return isinstance(x, jax.sharding.Sharding)


def broadcast_decl(decl, out_spec) -> List[Tuple[Any, Any]]:
    """Flatten a (possibly prefix) declaration tree against the output
    spec tree into ``[(decl_leaf_or_None, out_leaf), ...]`` pairs, in the
    same order jax flattens the outputs."""
    import jax

    if decl is None or _is_sharding(decl):
        return [(decl, leaf) for leaf in jax.tree.leaves(out_spec)]
    if isinstance(decl, dict) and isinstance(out_spec, dict):
        pairs = []
        for k in sorted(out_spec):
            pairs.extend(broadcast_decl(decl.get(k), out_spec[k]))
        return pairs
    if isinstance(decl, (tuple, list)) and isinstance(out_spec, (tuple, list)) \
            and len(decl) == len(out_spec):
        pairs = []
        for d, o in zip(decl, out_spec):
            pairs.extend(broadcast_decl(d, o))
        return pairs
    # structure mismatch: jax.jit would have rejected it at trace time, so
    # reaching here means the spec capture drifted — declare nothing rather
    # than misalign the zip
    return [(None, leaf) for leaf in jax.tree.leaves(out_spec)]


def check(entries, compiled_shardings) -> List[Finding]:
    """``entries``: registry entries of a *sharded* engine.
    ``compiled_shardings`` maps ``(name, key)`` to
    ``entry.fn.lower(*entry.arg_specs).compile().output_shardings``."""
    import jax

    findings: List[Finding] = []

    def emit(entry, message):
        findings.append(Finding(
            file=entry.src_file, line=entry.src_line, col=0,
            rule=PASS, severity="error",
            message=f"jit {entry.name}{entry.key}: {message}"))

    for entry in entries:
        if not entry.sharded:
            emit(entry, "built without explicit shardings on a sharded "
                        "engine — GSPMD is inferring the pool layout")
            continue
        got_tree = compiled_shardings.get((entry.name, entry.key))
        if got_tree is None or entry.out_specs is None:
            continue
        got = jax.tree.leaves(got_tree, is_leaf=_is_sharding)
        pairs = broadcast_decl(entry.out_shardings, entry.out_specs)
        out_leaves = jax.tree.leaves(entry.out_specs)
        if len(got) != len(pairs):
            emit(entry, f"compiled executable has {len(got)} output "
                        f"shardings but the trace captured {len(pairs)} "
                        "output leaves — spec capture drifted")
            continue
        for i, ((decl, spec), actual) in enumerate(zip(pairs, got)):
            if decl is None:
                continue
            ndim = len(getattr(spec, "shape", out_leaves[i].shape))
            if not actual.is_equivalent_to(decl, ndim):
                emit(entry, f"output leaf {i} compiled with sharding "
                            f"{actual} but PoolShardings declares {decl}")
                break
    return findings
