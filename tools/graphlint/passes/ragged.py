"""ragged-grid: every fused-paged engine jit rides the real-length grid.

The ragged paged-attention kernel (kernels/paged_verify_attn.py) sizes its
grid by the REAL allocated block count, carried into the jit as the
host-computed ``cu_blocks`` scalar-prefetch operand (kernels/tuning.py
``host_cu_blocks``).  The kernel being ragged is worthless if a dispatch
path forgets to thread the operand — the fused call silently cannot run
and the engine would fall back to dense launches (or crash at trace
time).  This pass pins the contract at the registry level: every
fused-paged ``step`` / ``chunk`` / ``step_mixed`` jit must declare a
``cu_arg`` (the operand's argnum) and its traced arg spec at that position
must be the 1-D int32 cumulative array the kernel prefetches.

The gathered-KV-view half of the ragged contract (no ``[B, MAXB*bs, ...]``
materialization anywhere in these jits, mixed launch included) is the
no-materialization pass — ``step_mixed`` is in its CHECKED_NAMES, so the
shared ``find_gathered_views`` detector and its gather-probe vacuousness
guard cover the new launch too.  This pass carries its own vacuousness
guard for the operand check: collecting zero ragged jits from a
fused-paged replay is a failure, not a pass.
"""
from __future__ import annotations

from typing import List

import numpy as np

from tools.lint.report import Finding

PASS = "ragged-grid"

# jit families whose traces embed the ragged paged-attention call
RAGGED_NAMES = ("step", "chunk", "step_mixed")


def _checked(entry) -> bool:
    return (entry.name in RAGGED_NAMES
            and entry.paged_rows is not None
            and entry.paged_fused is True)


def _cu_spec(entry):
    """The ShapeDtypeStruct at ``cu_arg`` of the last-trace arg specs (None
    when the entry never traced or the argnum is out of range)."""
    specs = entry.arg_specs
    if specs is None or entry.cu_arg is None:
        return None
    if not isinstance(specs, tuple) or entry.cu_arg >= len(specs):
        return None
    return specs[entry.cu_arg]


def check(entries) -> List[Finding]:
    findings: List[Finding] = []
    checked_any = False
    anchor = None
    for entry in entries:
        if not _checked(entry):
            continue
        checked_any = True
        anchor = anchor or (entry.src_file, entry.src_line)
        if entry.cu_arg is None:
            findings.append(Finding(
                file=entry.src_file, line=entry.src_line, col=0,
                rule=PASS, severity="error",
                message=(f"jit {entry.name}{entry.key}: fused paged jit "
                         f"registered without a cu_blocks operand (cu_arg "
                         f"is None) — the ragged real-length grid cannot "
                         f"run; dense launches regressed in")))
            continue
        spec = _cu_spec(entry)
        if spec is None:
            continue                 # never traced: nothing to validate yet
        shape = tuple(getattr(spec, "shape", ()))
        dtype = getattr(spec, "dtype", None)
        if len(shape) != 1 or (dtype is not None
                               and np.dtype(dtype) != np.int32):
            findings.append(Finding(
                file=entry.src_file, line=entry.src_line, col=0,
                rule=PASS, severity="error",
                message=(f"jit {entry.name}{entry.key}: cu_blocks operand "
                         f"at argnum {entry.cu_arg} traced as "
                         f"{dtype}{list(shape)} — the kernel scalar-"
                         f"prefetches a 1-D int32 cumulative array")))
    if entries and not checked_any:
        e0 = entries[0]
        findings.append(Finding(
            file=e0.src_file, line=e0.src_line, col=0,
            rule=PASS, severity="error",
            message=("no fused-paged step/chunk/step_mixed jits collected — "
                     "the ragged-grid pass is vacuous (did the replay stop "
                     "forcing the fused kernel?)")))
    return findings
