"""graph-lint passes: each module exposes ``PASS`` (id) and ``check(...)``.

A pass receives :class:`~repro.core.spec_decode.JitEntry` objects (plus
whatever pre-computed snapshots it needs) and returns
:class:`tools.lint.report.Finding`s anchored at the jitted function's
``def`` site — the one source location a compiled-graph property can be
traced back to, and the anchor line-scoped
``# graphlint: allow-<pass>(reason)`` pragmas attach to.

``iter_eqns`` is the shared jaxpr walker: it yields every equation in a
jaxpr *including* those inside sub-jaxpr params (pjit bodies, scan/cond
branches, custom_vjp calls), because the properties we check are
whole-program — a host callback buried two closed_call levels deep is
just as much a violation as one at top level.
"""
from __future__ import annotations

from typing import Iterator

PASS_IDS = (
    "transfer-free",
    "no-materialization",
    "ragged-grid",
    "donation",
    "sharding-conformance",
    "retrace",
)


def iter_eqns(jaxpr, skip_inside=()) -> Iterator:
    """Yield every eqn in ``jaxpr`` and, recursively, in any jaxpr-valued
    param of those eqns (closed or open).

    ``skip_inside`` names primitives whose params are *not* descended into
    (the eqn itself is still yielded).  The no-materialization pass skips
    ``pallas_call`` bodies this way: a Pallas kernel's jaxpr operates on
    per-block Refs whose shapes are tile sizes, not allocations — the
    logical-view rows count appearing there would be a false positive, and
    a kernel physically cannot materialize an HBM-resident view anyway."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name in skip_inside:
            continue
        for val in eqn.params.values():
            for sub in _subjaxprs(val):
                yield from iter_eqns(sub, skip_inside)


def _subjaxprs(val):
    inner = getattr(val, "jaxpr", None)
    if inner is not None:                      # ClosedJaxpr
        yield inner
    elif hasattr(val, "eqns"):                 # bare Jaxpr
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _subjaxprs(v)
