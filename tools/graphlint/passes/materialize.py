"""no-materialization: the fused paged path never gathers the KV view.

The PR 5 fused kernel exists to keep the paged pool's KV out of a
materialized ``[B, logical_len, KVH, hd]`` contiguous copy (2 such copies
per layer per verify step on the gather path).  ``benchmarks/kernel_bench.py``
proves that for the bare kernel call; this pass proves it for every
*registered engine jit* the dispatch loop actually runs — the kernel being
clean is worthless if the step function wrapping it regrows a gather.

``find_gathered_views`` is the shared detector (kernel_bench imports it):
an output aval whose leading two dims contain the logical row count is the
gathered view.  The engine-level check narrows with ``trailing`` — the
target's ``(KVH, hd)`` — because a full step also runs the *draft* model,
whose contiguous ring cache legitimately carries ``logical_len`` rows with
its own (different) head geometry.

The check is self-guarding against vacuousness: a gather-path probe
(``paged_fused=False``) must trip the same detector, or the pass fails —
if the detector ever goes blind, it says so instead of passing silently.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from tools.graphlint.passes import iter_eqns
from tools.lint.report import Finding

PASS = "no-materialization"

# jit families whose traces embed the paged-attention call (step_mixed is
# the single-launch verify+chunk fusion — it must stay just as gather-free)
CHECKED_NAMES = ("step", "chunk", "step_mixed")


def find_gathered_views(jaxpr, rows: int,
                        trailing: Optional[Sequence[int]] = None
                        ) -> List[Tuple[int, ...]]:
    """Output-aval shapes that look like the materialized logical view:
    ``rows`` (= logical_len = max_blocks * block_size) in the leading two
    dims, and — when ``trailing`` is given — the last dims equal to it
    (the KV head geometry).  ``trailing=None`` is kernel_bench's original,
    stricter-context check (bare kernel call, no draft model in trace)."""
    hits: List[Tuple[int, ...]] = []
    for eqn in iter_eqns(jaxpr, skip_inside=("pallas_call",)):
        for av in eqn.outvars:
            sh = tuple(getattr(av.aval, "shape", ()))
            if len(sh) < 2 or rows not in sh[:2]:
                continue
            if trailing is not None:
                t = tuple(trailing)
                if len(sh) < 2 + len(t) or sh[-len(t):] != t:
                    continue
            hits.append(sh)
    return hits


def _checked(entry) -> bool:
    return (entry.name in CHECKED_NAMES
            and entry.paged_rows is not None
            and entry.paged_fused is True)


def check(entries, jaxprs, trailing,
          guard_entries=(), guard_jaxprs=None) -> List[Finding]:
    """``entries``/``jaxprs``: the fused-path collection and its pre-traced
    ClosedJaxprs keyed ``(name, key)``.  ``guard_entries``/``guard_jaxprs``:
    same, from the gather-path probe engine — at least one must trip the
    detector or the whole pass is declared vacuous."""
    findings: List[Finding] = []
    checked_any = False
    for entry in entries:
        if not _checked(entry):
            continue
        closed = jaxprs.get((entry.name, entry.key))
        if closed is None:
            continue
        checked_any = True
        hits = find_gathered_views(closed.jaxpr, entry.paged_rows, trailing)
        if hits:
            findings.append(Finding(
                file=entry.src_file, line=entry.src_line, col=0,
                rule=PASS, severity="error",
                message=(f"jit {entry.name}{entry.key}: fused paged path "
                         f"materializes a gathered KV view "
                         f"{sorted(set(hits))[0]} "
                         f"(logical_len={entry.paged_rows} rows x KV "
                         f"geometry {tuple(trailing)})")))

    guard_tripped = False
    guard_src = None
    for entry in guard_entries:
        closed = (guard_jaxprs or {}).get((entry.name, entry.key))
        if closed is None or entry.paged_rows is None:
            continue
        guard_src = guard_src or (entry.src_file, entry.src_line)
        if find_gathered_views(closed.jaxpr, entry.paged_rows, trailing):
            guard_tripped = True
            break
    if checked_any and guard_entries and not guard_tripped:
        if guard_src is None:   # no probe entry even had a jaxpr: anchor
            e0 = next(e for e in entries if _checked(e))
            guard_src = (e0.src_file, e0.src_line)
        findings.append(Finding(
            file=guard_src[0], line=guard_src[1], col=0,
            rule=PASS, severity="error",
            message=("gather-path probe no longer materializes a KV view — "
                     "the no-materialization detector is vacuous (did the "
                     "view shape or KV geometry change?)")))
    return findings
