"""transfer-free: no host round-trip primitives inside any engine jit.

repro-lint's host-sync rule catches ``.item()`` / ``float()`` / ``np.*``
syncs lexically, but anything that survives into the *trace* — a
``jax.debug.print`` left behind, an ``io_callback`` smuggled in through a
helper, ``host_callback`` remnants — shows up in the jaxpr as a callback
or infeed/outfeed primitive and stalls the dispatch pipeline exactly the
same way.  This pass walks every equation (including sub-jaxprs) of every
registered jit and fails on any such primitive.
"""
from __future__ import annotations

from typing import List

from tools.graphlint.passes import iter_eqns
from tools.lint.report import Finding

PASS = "transfer-free"

# Primitive names that imply a host round-trip.  Substring match on
# "callback" covers pure_callback / io_callback / debug_callback and
# whatever jax renames them to next.
_BLOCKED_EXACT = {"infeed", "outfeed"}
_BLOCKED_SUBSTR = ("callback",)


def _blocked(prim_name: str) -> bool:
    if prim_name in _BLOCKED_EXACT:
        return True
    return any(s in prim_name for s in _BLOCKED_SUBSTR)


def check(entries, jaxprs) -> List[Finding]:
    """``jaxprs`` maps ``(entry.name, entry.key)`` to the entry's traced
    ClosedJaxpr (traced once by the CLI so passes never re-trace — a
    re-trace would corrupt the retrace pass's counters)."""
    findings: List[Finding] = []
    for entry in entries:
        closed = jaxprs.get((entry.name, entry.key))
        if closed is None:
            continue
        hit = set()
        for eqn in iter_eqns(closed.jaxpr):
            name = eqn.primitive.name
            if _blocked(name) and name not in hit:
                hit.add(name)
                findings.append(Finding(
                    file=entry.src_file, line=entry.src_line, col=0,
                    rule=PASS, severity="error",
                    message=(f"jit {entry.name}{entry.key}: primitive "
                             f"`{name}` performs a host round-trip inside "
                             "a compiled engine function")))
    return findings
