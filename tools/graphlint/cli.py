"""graph-lint CLI.  See the package docstring for what the passes check.

  python -m tools.graphlint [--json] [--no-sharded] [--inject MODE]

Exit codes match repro-lint: 0 clean, 1 findings, 2 usage error, 5 zero
jits collected (a vacuous run must fail loudly, not pass silently).

``--inject`` plants a deliberate violation so CI can prove the gate
actually trips (tools/citier.py's loudness test):

* ``no-donation`` — build the replay engine with ``donate=False``;
* ``retrace``     — drop every compiled cache between the two replays;
* ``no-jits``     — skip collection entirely (must exit 5).
"""
from __future__ import annotations

import argparse
import os
import re
import sys

EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_USAGE = 2
EXIT_NO_JITS = 5

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# graph-lint shares repro-lint's pragma grammar under its own marker:
#   # graphlint: allow-<pass>(reason)
PRAGMA_RE = re.compile(r"#\s*graphlint:\s*allow-([A-Za-z0-9_-]+)\(([^()]*)\)")


def _setup_env() -> None:
    """Force 2 host devices (for the sharded collection) — must happen
    before jax is imported anywhere in this process — and make both the
    repo root (tools.*) and src/ (repro.*) importable regardless of how
    the CLI was launched."""
    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=2").strip()
    for p in (ROOT, os.path.join(ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)


def _relpath(path: str) -> str:
    try:
        rel = os.path.relpath(path, ROOT)
    except ValueError:
        return path
    return rel if not rel.startswith("..") else path


def run_passes(replays, probe, findings):
    """Trace/lower each collected entry once, then feed every pass.  The
    jaxpr/HLO snapshots are taken *after* the retrace counts were recorded
    (each .trace()/.lower() call re-traces and would corrupt them)."""
    from tools.graphlint.passes import (donation, materialize, ragged,
                                        retrace, sharding, transfer_free)

    # retrace first: counters are already final, no artifacts needed
    for col in replays:
        findings.extend(retrace.check(col.entries, col.run1, col.run2))

    def jaxprs_of(col):
        out = {}
        for e in col.entries:
            if e.arg_specs is None:
                continue
            try:
                out[(e.name, e.key)] = e.fn.trace(*e.arg_specs).jaxpr
            except Exception:
                pass  # spec-only retrace can fail for host-hybrid args
        return out

    all_cols = list(replays) + ([probe] if probe else [])
    jaxprs = {id(c): jaxprs_of(c) for c in all_cols}

    for col in all_cols:
        findings.extend(transfer_free.check(col.entries, jaxprs[id(col)]))

    fused = next((c for c in replays if c.label == "paged-fused"), None)
    if fused is not None:
        findings.extend(materialize.check(
            fused.entries, jaxprs[id(fused)], fused.kv_trailing,
            guard_entries=(probe.entries if probe else ()),
            guard_jaxprs=(jaxprs[id(probe)] if probe else None)))
        findings.extend(ragged.check(fused.entries))

    for col in all_cols:
        lowered = {}
        for e in col.entries:
            if e.name not in donation.DONATING_NAMES or e.arg_specs is None:
                continue
            try:
                lowered[(e.name, e.key)] = e.fn.lower(*e.arg_specs).as_text()
            except Exception:
                pass
        findings.extend(donation.check(col.entries, lowered))

    for col in replays:
        if col.label != "sharded":
            continue
        compiled = {}
        for e in col.entries:
            if e.arg_specs is None:
                continue
            try:
                compiled[(e.name, e.key)] = (
                    e.fn.lower(*e.arg_specs).compile().output_shardings)
            except Exception:
                pass
        findings.extend(sharding.check(col.entries, compiled))


def apply_pragmas(findings):
    """Rebase findings onto repo-relative paths, then run them through the
    shared pragma machinery (collect with the graph-lint marker) over each
    source file an entry anchors to."""
    from tools.lint import pragmas as P
    from tools.lint.report import Finding

    rebased = [Finding(file=_relpath(f.file), line=f.line, col=f.col,
                       rule=f.rule, severity=f.severity, message=f.message)
               for f in findings]
    prags = []
    for rel in sorted({f.file for f in rebased}):
        full = os.path.join(ROOT, rel)
        if not os.path.isfile(full):
            continue
        with open(full, encoding="utf-8") as fh:
            prags.extend(P.collect(rel, fh.read(), pattern=PRAGMA_RE))
    kept, problems = P.apply(rebased, prags)
    return kept + problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.graphlint",
        description="jaxpr/HLO-level contract checks over the engine's "
                    "registered jits")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings (sorted, diffable)")
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the sharded collection (saves ~half the "
                         "runtime; sharding-conformance does not run)")
    ap.add_argument("--inject", choices=["no-donation", "retrace", "no-jits"],
                    help="plant a deliberate violation (CI loudness test)")
    try:
        args = ap.parse_args(argv)
    except SystemExit as e:
        return EXIT_USAGE if e.code not in (0, None) else 0

    _setup_env()
    from tools.graphlint import driver
    from tools.lint.report import render_human, render_json, sort_findings

    replays, probe = [], None
    if args.inject != "no-jits":
        replays.append(driver.collect_fused(
            donate=args.inject != "no-donation",
            inject_retrace=args.inject == "retrace"))
        probe = driver.collect_gather_probe()
        if not args.no_sharded:
            sharded = driver.collect_sharded()
            if sharded is not None:
                replays.append(sharded)

    entries = [e for c in replays for e in c.entries]
    n_jits = len(entries)
    if n_jits == 0:
        print("graph-lint: no jits collected — the serving replay "
              "registered nothing; the run is vacuous", file=sys.stderr)
        return EXIT_NO_JITS

    findings = []
    run_passes(replays, probe, findings)
    findings = sort_findings(apply_pragmas(findings))

    if args.json:
        print(render_json(findings))
    else:
        if findings:
            print(render_human(findings))
        labels = ", ".join(c.label for c in replays)
        if findings:
            errs = sum(1 for f in findings if f.severity == "error")
            print(f"graph-lint: {n_jits} jits ({labels}), "
                  f"{len(findings)} findings ({errs} errors)")
        else:
            print(f"graph-lint: {n_jits} jits ({labels}), clean")

    if any(f.severity == "error" for f in findings):
        return EXIT_FINDINGS
    return EXIT_CLEAN
