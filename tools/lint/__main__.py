"""Entry point so the analyzer runs as ``python -m tools.lint src``."""
import sys

from tools.lint.cli import main

sys.exit(main(sys.argv[1:]))
