"""Suppression pragmas: ``# lint: allow-<rule>(reason)``.

A pragma binds to the findings of its rule on a single line:

* on a code line, it suppresses that line's findings;
* on a line of its own, it suppresses the *next* line's findings (for
  statements too long to carry a trailing comment).

Two failure modes are themselves findings, so suppressions stay honest:

* a pragma with an empty reason is ``malformed-pragma`` (and suppresses
  nothing — the reason is the point);
* a pragma whose rule produced no finding on its target line is
  ``stale-pragma`` — the violation it excused is gone, delete it.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable, List, Tuple

from tools.lint.report import Finding

PRAGMA_RE = re.compile(r"#\s*lint:\s*allow-([A-Za-z0-9_-]+)\(([^()]*)\)")


@dataclasses.dataclass
class Pragma:
    file: str
    line: int          # line the pragma comment sits on (1-based)
    rule: str
    reason: str
    target_line: int   # line whose findings it suppresses
    used: int = 0      # findings suppressed (stale when 0)

    @property
    def valid(self) -> bool:
        return bool(self.reason.strip())


def collect(relpath: str, source: str,
            pattern: "re.Pattern" = PRAGMA_RE) -> List[Pragma]:
    """Scan source lines for pragmas.  Standalone comment lines target the
    following line; trailing comments target their own line.

    ``pattern`` swaps the pragma marker: graph-lint (tools/graphlint)
    reuses this collector with ``# graphlint: allow-<pass>(reason)`` so the
    two subsystems share one suppression grammar (group 1 = rule/pass id,
    group 2 = mandatory reason).
    """
    out = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        for m in pattern.finditer(text):
            before = text[:m.start()].strip()
            standalone = before == "" or before.startswith("#")
            target = lineno + 1 if standalone else lineno
            out.append(Pragma(file=relpath, line=lineno, rule=m.group(1),
                              reason=m.group(2), target_line=target))
    return out


def apply(findings: Iterable[Finding],
          pragmas: List[Pragma]) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (kept, pragma-problems).

    Kept findings are the ones no valid pragma covers.  Pragma problems
    are malformed (no reason) and stale (suppressed nothing) pragmas,
    both errors.
    """
    by_target = {}
    for p in pragmas:
        if p.valid:
            by_target.setdefault((p.file, p.target_line, p.rule), []).append(p)

    kept = []
    for f in findings:
        covering = by_target.get((f.file, f.line, f.rule))
        if covering:
            for p in covering:
                p.used += 1
        else:
            kept.append(f)

    problems = []
    for p in pragmas:
        if not p.valid:
            problems.append(Finding(
                file=p.file, line=p.line, col=0, rule="malformed-pragma",
                severity="error",
                message=(f"pragma allow-{p.rule} has no reason — write "
                         f"`# lint: allow-{p.rule}(why this is safe)`")))
        elif p.used == 0:
            problems.append(Finding(
                file=p.file, line=p.line, col=0, rule="stale-pragma",
                severity="error",
                message=(f"pragma allow-{p.rule} suppresses nothing on line "
                         f"{p.target_line} — stale pragmas are errors; "
                         f"delete it")))
    return kept, problems
