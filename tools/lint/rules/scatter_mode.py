"""scatter-drop: ragged-tail KV scatters must say ``mode="drop"``.

PR 3's invariant: chunked prefill and speculative commit write *ragged*
token tails into the KV cache — every ``.at[...].set/.add`` into a
KV-cache/pool array masks its out-of-range rows by scattering them to a
sentinel index, and ``mode="drop"`` is what makes that sentinel a no-op
instead of an out-of-bounds clamp that corrupts row 0 / row L-1.  The
rule requires the mode to be *explicit* on every cache write in
``models/`` and ``kernels/`` — including the in-bounds ring-buffer
writes, where it is a semantic no-op but keeps the contract visible.

A write is "cache-like" when it subscripts a known KV leaf key
(``cache["k"]`` …), when any identifier on the chain contains ``cache``
or ``pool``, or when it targets the scan-carried KV leaf names
(``lk``/``lv``/``nk``/``nv``) used by the recurrent models.  Expert-
routing buffers in ``moe.py`` match none of these and stay out of scope.
"""
from __future__ import annotations

import ast
import re
from typing import List

from tools.lint import astutil
from tools.lint.report import Finding

RULE = "scatter-drop"

KV_KEYS = {"k", "v", "pos", "bt", "k_scale", "v_scale", "ckv", "krope",
           "xk", "xv"}
CACHE_NAME_RE = re.compile(r"cache|pool", re.IGNORECASE)
KV_LEAF_NAMES = {"lk", "lv", "nk", "nv"}
SCATTER_METHODS = {"set", "add"}


def _applies(relpath: str) -> bool:
    parts = astutil.path_parts(relpath)
    return "models" in parts or "kernels" in parts


def _cache_like(target: ast.AST) -> bool:
    if isinstance(target, ast.Subscript):
        sl = target.slice
        if isinstance(sl, ast.Constant) and isinstance(sl.value, str) \
                and sl.value in KV_KEYS:
            return True
    if isinstance(target, ast.Name) and target.id in KV_LEAF_NAMES:
        return True
    return any(CACHE_NAME_RE.search(ident)
               for ident in astutil.chain_identifiers(target))


def _mode_is_drop(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "mode":
            return (isinstance(kw.value, ast.Constant)
                    and kw.value.value == "drop")
    return False


def check(tree: ast.AST, source: str, relpath: str) -> List[Finding]:
    if not _applies(relpath):
        return []
    findings: List[Finding] = []
    for node in ast.walk(tree):
        # match  <target>.at[<idx>].set(...) / .add(...)
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in SCATTER_METHODS
                and isinstance(node.func.value, ast.Subscript)
                and isinstance(node.func.value.value, ast.Attribute)
                and node.func.value.value.attr == "at"):
            continue
        target = node.func.value.value.value
        if not _cache_like(target):
            continue
        if _mode_is_drop(node):
            continue
        findings.append(Finding(
            relpath, node.lineno, node.col_offset, RULE, "error",
            f".at[...].{node.func.attr}() into a KV-cache/pool array "
            'without mode="drop" — ragged-tail scatters clamp out-of-'
            "bounds rows into live cache slots unless dropped"))
    return findings
