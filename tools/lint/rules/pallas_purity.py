"""pallas-index-map: BlockSpec index maps are pure address arithmetic.

PR 5's fused paged-attention kernel streams KV through the block table
*inside* the kernel by scalar-prefetching the table and letting each
BlockSpec index map pick the next block: the index map runs on the
scalar core ahead of the DMA engine, so it may touch only its own
parameters (grid indices + scalar-prefetch refs) and closed-form scalar
math.  A captured tracer silently becomes a constant at trace time; a
``jnp`` reduction inside the map runs per grid step on the scalar core.
Both break the prefetch pipeline the fused kernel depends on.

The rule inspects every ``pl.BlockSpec(...)`` in ``kernels/`` (lambda or
locally-defined function) and flags (a) free variables that are not
module-level names/imports/builtins — i.e. values captured from the
enclosing function scope — and (b) calls outside a small scalar-safe
allowlist (``jnp.maximum``-style clamps and ``pl.cdiv``-style helpers).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.lint import astutil
from tools.lint.report import Finding

RULE = "pallas-index-map"

ALLOWED_CALLS = {
    "jax.numpy.maximum", "jax.numpy.minimum", "jax.numpy.clip",
    "jax.numpy.where", "jax.numpy.mod", "jax.numpy.floor_divide",
    "jax.experimental.pallas.cdiv", "jax.experimental.pallas.ds",
    "jax.experimental.pallas.multiple_of",
}
ALLOWED_BUILTIN_CALLS = {"min", "max", "int", "divmod"}
ALLOWED_METHODS = {"astype"}


def _applies(relpath: str) -> bool:
    return "kernels" in astutil.path_parts(relpath)


def _module_scope_names(tree: ast.Module) -> Set[str]:
    """Names bound at module level: imports, defs, constants.  These are
    static at trace time, so an index map may read them."""
    names: Set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                names.update(astutil.assigned_names(t))
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            names.add(node.target.id)
        elif isinstance(node, ast.Import):
            for a in node.names:
                names.add(a.asname or a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                names.add(a.asname or a.name)
    return names


def _local_binds(fn: ast.AST) -> Set[str]:
    """Names bound inside the index map itself: params, local assigns,
    comprehension targets."""
    binds: Set[str] = set()
    args = fn.args
    for a in [*args.posonlyargs, *args.args, *args.kwonlyargs]:
        binds.add(a.arg)
    if args.vararg:
        binds.add(args.vararg.arg)
    if args.kwarg:
        binds.add(args.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                binds.update(astutil.assigned_names(t))
        elif isinstance(node, ast.comprehension):
            binds.update(astutil.assigned_names(node.target))
    return binds


def _index_map_expr(call: ast.Call) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "index_map":
            return kw.value
    if len(call.args) >= 2:
        return call.args[1]
    return None


def _check_map(fn: ast.AST, module_names: Set[str], aliases: Dict[str, str],
               relpath: str, findings: List[Finding]) -> None:
    binds = _local_binds(fn)
    body = fn.body if isinstance(fn, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)) else [fn.body]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                name = node.id
                if name in binds or name in module_names \
                        or name in astutil.BUILTIN_NAMES:
                    continue
                findings.append(Finding(
                    relpath, node.lineno, node.col_offset, RULE, "error",
                    f"BlockSpec index map reads `{name}` from the enclosing "
                    "function scope — index maps may close over grid "
                    "indices and scalar-prefetch refs only"))
            elif isinstance(node, ast.Call):
                func = node.func
                if isinstance(func, ast.Name):
                    if func.id in ALLOWED_BUILTIN_CALLS or func.id in binds:
                        continue
                    resolved = aliases.get(func.id, func.id)
                    if resolved in ALLOWED_CALLS:
                        continue
                    display = func.id
                elif isinstance(func, ast.Attribute):
                    resolved = astutil.resolve(func, aliases)
                    if resolved in ALLOWED_CALLS:
                        continue
                    if func.attr in ALLOWED_METHODS:
                        continue
                    display = astutil.dotted(func) or func.attr
                else:
                    display = "<expr>"
                findings.append(Finding(
                    relpath, node.lineno, node.col_offset, RULE, "error",
                    f"`{display}(...)` inside a BlockSpec index map — index "
                    "maps must be pure block-address arithmetic (allowed: "
                    "clamps like jnp.maximum/minimum/clip and pl.cdiv/ds)"))


def check(tree: ast.AST, source: str, relpath: str) -> List[Finding]:
    if not _applies(relpath):
        return []
    aliases = astutil.module_aliases(tree)
    module_names = _module_scope_names(tree)
    # locally-defined functions, for resolving named index maps
    local_defs: Dict[str, List[ast.FunctionDef]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            local_defs.setdefault(node.name, []).append(node)

    findings: List[Finding] = []
    checked: Set[int] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = astutil.dotted(node.func)
        if not (name == "BlockSpec" or (name and name.endswith(".BlockSpec"))):
            continue
        expr = _index_map_expr(node)
        if expr is None:
            continue
        if isinstance(expr, ast.Lambda):
            _check_map(expr, module_names, aliases, relpath, findings)
        elif isinstance(expr, ast.Name):
            for fn in local_defs.get(expr.id, []):
                if id(fn) not in checked:
                    checked.add(id(fn))
                    _check_map(fn, module_names, aliases, relpath, findings)
        # anything else (e.g. functools.partial) is opaque; stay silent
        # rather than guess — the fixture tests pin the supported shapes
    return findings
