"""host-sync: no device→host round-trips on the per-iteration hot path.

Every ``.item()``, ``.tolist()``, ``np.asarray``, ``jax.device_get``,
``.block_until_ready()`` — or an ``int()``/``float()``/``bool()``/
``np.float32()``/``np.float64()`` cast of a traced local — inside the
decode loop stalls the accelerator pipeline for a full transfer latency —
per *iteration*, which at s=4 speculation means several times per
generated token.  The hot zones are:

* ``core/spec_decode.py`` — ``SpecDecodeEngine.step`` / ``retire_slot``
  and the jitted ``make_spec_step`` body;
* ``serving/scheduler.py`` — the live backend's ``prefill`` /
  ``prefill_chunk`` / ``step`` / ``preempt`` and the scheduler ``run``
  loop (the ``SimStepBackend`` is pure host code and exempt);
* everything under ``kernels/`` (kernel wrappers run inside jit traces,
  where a host sync is either a tracer error waiting to happen or a
  silent recompile trigger).

Deliberate step-boundary syncs (timing fences, commit-count reads that
drive host block accounting) carry ``# lint: allow-host-sync(reason)``.

``np.asarray``/``np.array`` over a literal list/tuple is downgraded to a
*warning*: it never blocks on a device transfer, but it does allocate
per iteration.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from tools.lint import astutil
from tools.lint.report import Finding

RULE = "host-sync"

# file-suffix -> hot function qualnames (nested defs inherit hotness)
HOT_QUALNAMES = {
    ("core", "spec_decode.py"): (
        "SpecDecodeEngine.step",
        "SpecDecodeEngine.retire_slot",
        "make_spec_step",
    ),
    ("serving", "scheduler.py"): (
        "ContinuousEngineBackend.prefill",
        "ContinuousEngineBackend.prefill_chunk",
        "ContinuousEngineBackend.attach",
        "ContinuousEngineBackend.commit_attached",
        "ContinuousEngineBackend.step",
        "ContinuousEngineBackend.preempt",
        "ContinuousScheduler.run",
    ),
}

SYNC_FUNCS = {"jax.device_get"}
NUMPY_CONVERTERS = {"numpy.asarray", "numpy.array"}
# numpy scalar constructors: np.float32(x) on a device value pulls x to
# host exactly like float(x) — the dtype wrapper hides the sync
NUMPY_SCALAR_CASTS = {"numpy.float32", "numpy.float64"}
JAX_MODULES = ("jax", "jax.numpy")


def _hot_zone(relpath: str):
    """(kind, qualnames): kind is 'all' for kernels/, 'named' for the two
    engine files, None when the rule does not apply to this file."""
    parts = astutil.path_parts(relpath)
    if "kernels" in parts:
        return "all", ()
    for suffix, quals in HOT_QUALNAMES.items():
        if parts[-len(suffix):] == suffix:
            return "named", quals
    return None, ()


def _is_hot(call: ast.AST, kind: str, quals) -> bool:
    funcs = astutil.enclosing_functions(call)
    if not funcs:
        return False  # module-level code runs once at import, not per step
    if kind == "all":
        return True
    for fn in funcs:
        q = astutil.qualname(fn)
        if any(q == h or q.startswith(h + ".") for h in quals):
            return True
    return False


def _traced_names(funcs, aliases) -> Set[str]:
    """Names assigned (anywhere in the enclosing function chain) from an
    expression that touches jax/jnp — a cheap lexical stand-in for 'this
    local is a device value'."""
    traced: Set[str] = set()
    seen: Set[int] = set()
    for fn in funcs:
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        for node in ast.walk(fn):
            if not isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                continue
            value = node.value
            if value is None:
                continue
            jaxy = False
            for sub in ast.walk(value):
                if isinstance(sub, ast.Name) and \
                        aliases.get(sub.id) in JAX_MODULES:
                    jaxy = True
                    break
            if not jaxy:
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                traced.update(astutil.assigned_names(t))
    return traced


def check(tree: ast.AST, source: str, relpath: str) -> List[Finding]:
    kind, quals = _hot_zone(relpath)
    if kind is None:
        return []
    aliases = astutil.module_aliases(tree)
    traced_cache: Dict[int, Set[str]] = {}
    findings: List[Finding] = []

    def emit(node, message, severity="error"):
        findings.append(Finding(relpath, node.lineno, node.col_offset,
                                RULE, severity, message))

    def traced_local(call) -> str:
        """The root name of the call's single argument, when that name is
        assigned from a jax-touching expression in the enclosing function
        chain (else None)."""
        root = astutil.root_name(call.args[0])
        if root is None:
            return None
        funcs = astutil.enclosing_functions(call)
        key = id(funcs[0])
        if key not in traced_cache:
            traced_cache[key] = _traced_names(funcs, aliases)
        return root if root in traced_cache[key] else None

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not _is_hot(node, kind, quals):
            continue
        func = node.func

        if isinstance(func, ast.Attribute):
            if func.attr == "item" and not node.args and not node.keywords:
                emit(node, ".item() forces a device→host sync inside a "
                           "per-iteration hot path")
                continue
            if func.attr == "tolist" and not node.args and not node.keywords:
                emit(node, ".tolist() materializes the whole array on host "
                           "(a device→host sync) inside a per-iteration "
                           "hot path")
                continue
            if func.attr == "block_until_ready":
                emit(node, ".block_until_ready() stalls the dispatch "
                           "pipeline inside a per-iteration hot path")
                continue

        resolved = astutil.resolve(func, aliases)
        if resolved in SYNC_FUNCS:
            emit(node, f"{resolved}() copies device memory to host inside "
                       "a per-iteration hot path")
            continue
        if resolved in NUMPY_CONVERTERS:
            arg = node.args[0] if node.args else None
            if isinstance(arg, (ast.List, ast.Tuple, ast.Constant, ast.Dict)):
                emit(node, f"{resolved}() over a literal allocates host "
                           "memory every iteration (no device sync, but "
                           "hoist it out of the loop)", severity="warning")
            else:
                emit(node, f"{resolved}() on a (potential) device value "
                           "blocks on the transfer inside a per-iteration "
                           "hot path")
            continue
        if resolved in NUMPY_SCALAR_CASTS and len(node.args) == 1 \
                and not node.keywords:
            root = traced_local(node)
            if root is not None:
                short = "np." + resolved.split(".", 1)[1]
                emit(node, f"{short}() on traced value `{root}` pulls it "
                           "to host (a device→host sync) inside a "
                           "per-iteration hot path")
            continue
        if isinstance(func, ast.Name) and func.id in ("int", "float", "bool") \
                and len(node.args) == 1 and not node.keywords:
            root = traced_local(node)
            if root is not None:
                emit(node, f"{func.id}() on traced value `{root}` "
                           "forces a device→host sync inside a "
                           "per-iteration hot path")
    return findings
