"""telemetry-readonly: the observer may not touch the pipeline (PR 6).

``serving/telemetry.py``'s standing contract is that attaching or
detaching the hub never changes scheduler decisions, pool state, or
model outputs — the parity tests prove it at runtime, this rule enforces
it structurally: telemetry may not *import* engine/model/kernel modules
(so it cannot construct or reach into them) and may not *call* the
pool/engine mutation API surface by name on any object it is handed.

numpy, json, sys and lazy ``import jax`` (for ``jax.profiler`` trace
spans) are fine: they read, they never steer.
"""
from __future__ import annotations

import ast
from typing import List

from tools.lint import astutil
from tools.lint.report import Finding

RULE = "telemetry-readonly"

FORBIDDEN_IMPORT_PREFIXES = (
    "repro.core",
    "repro.models",
    "repro.kernels",
    "repro.launch",
    "repro.training",
    "repro.serving.scheduler",
    "repro.serving.slots",
    "repro.serving.server",
)
# sibling modules reachable by relative import (from . import slots)
FORBIDDEN_SIBLINGS = {"scheduler", "slots", "server", "spec_decode"}

# the engine/pool mutation API surface, by method name
MUTATORS = {
    "prefill", "prefill_chunk", "prefill_into", "prefill_chunk_into",
    "step", "retire", "retire_slot", "preempt", "run",
    "claim", "release", "consume", "ensure", "commit",
    "init_slots", "set_paged_fused", "mark_pending", "clear_pending",
    "free_blocks", "grow",
}


def _applies(relpath: str) -> bool:
    parts = astutil.path_parts(relpath)
    return parts[-2:] == ("serving", "telemetry.py")


def check(tree: ast.AST, source: str, relpath: str) -> List[Finding]:
    if not _applies(relpath):
        return []
    findings: List[Finding] = []

    def emit(node, message):
        findings.append(Finding(relpath, node.lineno, node.col_offset,
                                RULE, "error", message))

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith(FORBIDDEN_IMPORT_PREFIXES):
                    emit(node, f"telemetry imports `{a.name}` — the "
                               "observer must not reach the engine/pool "
                               "layer (read-only contract)")
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level == 0 and mod.startswith(FORBIDDEN_IMPORT_PREFIXES):
                emit(node, f"telemetry imports from `{mod}` — the observer "
                           "must not reach the engine/pool layer "
                           "(read-only contract)")
            elif node.level > 0:
                names = {mod.split(".")[0]} | {a.name for a in node.names}
                hit = sorted(names & FORBIDDEN_SIBLINGS)
                if hit:
                    emit(node, f"telemetry imports sibling module "
                               f"`{hit[0]}` — the observer must not reach "
                               "the engine/pool layer (read-only contract)")
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in MUTATORS:
                emit(node, f"telemetry calls mutation API `.{node.func.attr}"
                           "()` — the observer reads spans and gauges, it "
                           "never steers the pipeline")
    return findings
