"""Rule registry: one module per rule family, each exposing

* ``RULE`` — the rule id used in findings and ``allow-<rule>`` pragmas,
* ``check(tree, source, relpath)`` — returns a list of Findings; the rule
  itself decides applicability from ``relpath`` (so test fixtures in a
  tmpdir exercise the same path-scoping as the real tree).

The tree passed to ``check`` already has parent links attached
(``astutil.attach_parents``).
"""
from tools.lint.rules import (cow_write, host_sync, jit_shardings,
                              pallas_purity, scatter_mode,
                              telemetry_readonly)

ALL_RULES = [
    host_sync,
    jit_shardings,
    scatter_mode,
    cow_write,
    telemetry_readonly,
    pallas_purity,
]

RULE_IDS = tuple(m.RULE for m in ALL_RULES)
