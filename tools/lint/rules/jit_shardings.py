"""jit-sharding: engine jits must be explicitly sharded (PR 4's contract).

Every ``jax.jit`` in engine code (``core/`` and ``launch/specs.py``) must
either pass *both* ``in_shardings`` and ``out_shardings``, or sit in a
recognized unsharded branch — the body of an ``if sh is None:`` (or the
else of ``... is not None``), including the conditional-expression form
``jax.jit(fn) if sh is None else jax.jit(fn, in_shardings=...)``.

A bare ``jax.jit`` outside such a branch compiles with whatever sharding
GSPMD infers, which on the production mesh silently replicates the KV
pool — exactly the regression PR 4's prose contract exists to prevent.
The training driver (``launch/train.py``) is out of scope: its jits are
single-host ``donate_argnums`` steps, not the serving engine.
"""
from __future__ import annotations

import ast
from typing import List, Set

from tools.lint import astutil
from tools.lint.report import Finding

RULE = "jit-sharding"

JIT_NAMES = {"jax.jit"}
SHARDING_KWARGS = {"in_shardings", "out_shardings"}


def _applies(relpath: str) -> bool:
    parts = astutil.path_parts(relpath)
    return "core" in parts or parts[-2:] == ("launch", "specs.py")


def _none_test_kinds(test: ast.AST) -> Set[str]:
    """{'is_none', 'is_not_none'} memberships found anywhere in a test
    expression (covers ``sh is None or B != cap`` BoolOps)."""
    kinds: Set[str] = set()
    for node in ast.walk(test):
        if not isinstance(node, ast.Compare):
            continue
        for op, comparator in zip(node.ops, node.comparators):
            is_none_const = (isinstance(comparator, ast.Constant)
                             and comparator.value is None)
            if not is_none_const:
                continue
            if isinstance(op, ast.Is):
                kinds.add("is_none")
            elif isinstance(op, ast.IsNot):
                kinds.add("is_not_none")
    return kinds


def _in_unsharded_branch(call: ast.Call) -> bool:
    """True when the bare jit sits in the unsharded side of a None-check:
    the body of ``if sh is None`` / else of ``if sh is not None`` (both
    statement If and conditional-expression IfExp forms)."""
    child: ast.AST = call
    for parent in astutil.parents(call):
        if isinstance(parent, ast.If):
            in_body = any(child is stmt for stmt in parent.body)
            in_orelse = any(child is stmt for stmt in parent.orelse)
            kinds = _none_test_kinds(parent.test)
            if (in_body and "is_none" in kinds) or \
                    (in_orelse and "is_not_none" in kinds):
                return True
        elif isinstance(parent, ast.IfExp):
            kinds = _none_test_kinds(parent.test)
            if (child is parent.body and "is_none" in kinds) or \
                    (child is parent.orelse and "is_not_none" in kinds):
                return True
        child = parent
    return False


def check(tree: ast.AST, source: str, relpath: str) -> List[Finding]:
    if not _applies(relpath):
        return []
    aliases = astutil.module_aliases(tree)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if astutil.resolve(node.func, aliases) not in JIT_NAMES:
            continue
        present = {kw.arg for kw in node.keywords} & SHARDING_KWARGS
        if present == SHARDING_KWARGS:
            continue
        if present:
            missing = (SHARDING_KWARGS - present).pop()
            findings.append(Finding(
                relpath, node.lineno, node.col_offset, RULE, "error",
                f"jax.jit passes {present.pop()} but not {missing} — "
                "engine jits shard both sides explicitly"))
            continue
        if _in_unsharded_branch(node):
            continue
        findings.append(Finding(
            relpath, node.lineno, node.col_offset, RULE, "error",
            "bare jax.jit in engine code: pass explicit in_shardings/"
            "out_shardings, or guard the unsharded fallback with an "
            "`... is None` branch"))
    return findings
