"""cow-write: KV scatters in sharing-aware paths route through block-copy.

With the prefix cache (serving/prefix_cache.py), blocks in the paged pool
can be *shared*: several slot tables — and the cache index itself — may
reference one physical block.  The copy-on-write contract says a block
with refcount > 1 is never written in place: writers allocate a fresh
block and move rows through the engine's jit-cached block-copy helper
(``_build_block_copy`` in core/spec_decode.py), which is the only place
allowed to scatter into pool-addressed KV rows wholesale.

This rule flags direct ``.at[...].set/.add`` writes into pool-backed KV
arrays (the tcache leaves ``k``/``v``/``pos``/``k_scale``/``v_scale``, a
bare ``pos`` carry, or any subscripted array whose identifier chain smells
like a cache/pool) inside ``serving/`` and ``core/spec_decode.py``.  Block
*tables* (``bt``) are per-slot host state, never shared, and stay out of
scope.  Writes that are provably safe — scatters into blocks the writer
just allocated at refcount 1, retirement/eviction wipes of already-freed
rows — carry an explicit ``# lint: allow-cow-write(reason)`` pragma, which
doubles as documentation of *why* the target cannot be shared.
"""
from __future__ import annotations

import ast
import re
from typing import List

from tools.lint import astutil
from tools.lint.report import Finding

RULE = "cow-write"

# pool-addressed tcache leaves; `bt` is deliberately absent (host-side
# per-slot tables are never shared between slots)
POOL_KEYS = {"k", "v", "pos", "k_scale", "v_scale"}
CACHE_NAME_RE = re.compile(r"cache|pool", re.IGNORECASE)
SCATTER_METHODS = {"set", "add"}


def _applies(relpath: str) -> bool:
    parts = astutil.path_parts(relpath)
    return "serving" in parts or parts[-1:] == ("spec_decode.py",)


def _pool_backed(target: ast.AST) -> bool:
    if isinstance(target, ast.Subscript):
        sl = target.slice
        if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
            return sl.value in POOL_KEYS
        # dynamic key: conservative — flag if the chain smells pool-like
        return any(CACHE_NAME_RE.search(ident)
                   for ident in astutil.chain_identifiers(target))
    if isinstance(target, ast.Name) and target.id == "pos":
        return True
    return False


def _inside_block_copy(node: ast.AST) -> bool:
    return any("block_copy" in fn.name
               for fn in astutil.enclosing_functions(node))


def check(tree: ast.AST, source: str, relpath: str) -> List[Finding]:
    if not _applies(relpath):
        return []
    findings: List[Finding] = []
    for node in ast.walk(tree):
        # match  <target>.at[<idx>].set(...) / .add(...)
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in SCATTER_METHODS
                and isinstance(node.func.value, ast.Subscript)
                and isinstance(node.func.value.value, ast.Attribute)
                and node.func.value.value.attr == "at"):
            continue
        target = node.func.value.value.value
        if not _pool_backed(target):
            continue
        if _inside_block_copy(node):
            continue                     # the sanctioned copy helper
        findings.append(Finding(
            relpath, node.lineno, node.col_offset, RULE, "error",
            f".at[...].{node.func.attr}() into a pool-backed KV array in a "
            "sharing-aware path — blocks may be shared (refcount > 1); "
            "route the write through the block-copy helper, or prove the "
            "target is exclusively owned with "
            "`# lint: allow-cow-write(reason)`"))
    return findings
