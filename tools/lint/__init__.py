"""repro-lint: an AST invariant checker for the runtime's standing contracts.

Six PRs of growth left the continuous-batching runtime resting on *prose*
contracts — explicit shardings on every engine jit (PR 4), ``mode="drop"``
on ragged-tail KV scatters (PR 3), a read-only telemetry layer (PR 6),
scalar-prefetch-pure BlockSpec index maps in the fused paged kernel
(PR 5), and a host-sync-free per-iteration hot path.  This package turns
each of those into a machine-checked rule over the stdlib ``ast`` — no
third-party dependencies, no imports of the code under analysis.

Usage::

    python -m tools.lint src              # human-readable findings
    python -m tools.lint src --json       # sorted, timestamp-free JSON
    python -m tools.lint src --baseline tools/lint/baseline.json

Findings are suppressed line-by-line with a justified pragma::

    x = np.asarray(dev)  # lint: allow-host-sync(deliberate timing fence)

A pragma on its own line applies to the next line.  A pragma that
suppresses nothing is *stale* and is itself an error, so suppressions
cannot outlive the code they excuse.

Exit codes: 0 clean, 1 findings, 2 usage error, 5 zero files collected
(a vacuous run is a failure, mirroring ``tools/citier.py``).
"""
from tools.lint.cli import lint_paths, main  # noqa: F401
