"""Finding record and deterministic rendering (human + JSON).

Output is sorted by (file, line, col, rule, message) and carries no
timestamps or absolute paths, so ``--json`` runs diff cleanly against the
committed baseline and against each other across machines.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, List

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a file:line:col location."""

    file: str       # path with forward slashes, as passed on the CLI
    line: int       # 1-based
    col: int        # 0-based (ast convention)
    rule: str       # rule id, e.g. "host-sync"
    severity: str   # "error" | "warning"
    message: str

    def sort_key(self):
        return (self.file, self.line, self.col, self.rule, self.message)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=Finding.sort_key)


def render_human(findings: Iterable[Finding]) -> str:
    lines = [f"{f.file}:{f.line}:{f.col}: [{f.severity}] {f.rule}: {f.message}"
             for f in sort_findings(findings)]
    return "\n".join(lines)


def render_json(findings: Iterable[Finding]) -> str:
    """A sorted JSON array of finding objects — the baseline file format."""
    payload = [f.to_dict() for f in sort_findings(findings)]
    return json.dumps(payload, indent=2, sort_keys=True) + "\n"


def summarize(findings: Iterable[Finding], n_files: int) -> str:
    fs = list(findings)
    errors = sum(1 for f in fs if f.severity == "error")
    warnings = len(fs) - errors
    if not fs:
        return f"repro-lint: {n_files} files, clean"
    return (f"repro-lint: {n_files} files, {len(fs)} findings "
            f"({errors} errors, {warnings} warnings)")
