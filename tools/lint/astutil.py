"""Shared AST helpers: parent links, qualnames, import-alias resolution.

Every rule works on the same annotated tree: ``attach_parents`` is run
once per file by the CLI, and rules use these helpers instead of
re-walking.  Names are resolved *lexically* — ``np.asarray`` becomes
``numpy.asarray`` via the file's own import aliases, never by importing
the module under analysis.
"""
from __future__ import annotations

import ast
import builtins
from typing import Dict, Iterator, List, Optional, Set

BUILTIN_NAMES = frozenset(dir(builtins))


def attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._lint_parent = node  # type: ignore[attr-defined]


def parents(node: ast.AST) -> Iterator[ast.AST]:
    """Ancestors from the immediate parent up to the Module."""
    cur = getattr(node, "_lint_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_lint_parent", None)


def enclosing_functions(node: ast.AST) -> List[ast.AST]:
    """FunctionDef/AsyncFunctionDef ancestors, innermost first."""
    return [p for p in parents(node)
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef))]


def qualname(func: ast.AST) -> str:
    """Dotted name of a function through its ClassDef/FunctionDef ancestors
    (no ``<locals>`` markers): ``SpecDecodeEngine.step.body_fn``."""
    names = [func.name]  # type: ignore[attr-defined]
    for p in parents(func):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            names.append(p.name)
    return ".".join(reversed(names))


def module_aliases(tree: ast.AST) -> Dict[str, str]:
    """Map local names to the dotted import path they denote.

    ``import jax.numpy as jnp``                       -> jnp: jax.numpy
    ``import numpy as np``                            -> np: numpy
    ``import jax``                                    -> jax: jax
    ``from jax.experimental import pallas as pl``     -> pl: jax.experimental.pallas
    ``from jax import jit``                           -> jit: jax.jit
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    aliases[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.level == 0 and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def resolve(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Dotted name with the leading segment expanded through the file's
    import aliases: ``np.asarray`` -> ``numpy.asarray``."""
    name = dotted(node)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    base = aliases.get(head, head)
    return f"{base}.{rest}" if rest else base


def root_name(node: ast.AST) -> Optional[str]:
    """Base Name id of an Attribute/Subscript chain (``x`` in ``x.a[i].b``)."""
    cur = node
    while isinstance(cur, (ast.Attribute, ast.Subscript)):
        cur = cur.value
    return cur.id if isinstance(cur, ast.Name) else None


def chain_identifiers(node: ast.AST) -> Set[str]:
    """All identifiers along an Attribute/Subscript chain, e.g.
    ``self.kv_pool["k"]`` -> {self, kv_pool}."""
    out: Set[str] = set()
    cur = node
    while True:
        if isinstance(cur, ast.Attribute):
            out.add(cur.attr)
            cur = cur.value
        elif isinstance(cur, ast.Subscript):
            cur = cur.value
        elif isinstance(cur, ast.Name):
            out.add(cur.id)
            return out
        else:
            return out


def assigned_names(target: ast.AST) -> Iterator[str]:
    """Simple Name ids bound by an assignment target (tuples unpacked)."""
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from assigned_names(elt)


def path_parts(relpath: str) -> tuple:
    return tuple(p for p in relpath.replace("\\", "/").split("/") if p)
