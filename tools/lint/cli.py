"""File collection, rule orchestration, and the command-line interface.

``lint_paths(targets)`` is the programmatic surface (used by the tests
and by ``tools/citier.py``); ``main(argv)`` wraps it with argparse and
the exit-code contract:

* 0 — clean
* 1 — findings (after pragma suppression and baseline subtraction)
* 2 — usage error (unknown target, unreadable baseline)
* 5 — zero Python files collected (a vacuous run is a failure, the same
  convention ``tools/citier.py`` applies to pytest exit code 5)
"""
from __future__ import annotations

import argparse
import ast
import json
import os
import sys
from typing import List, Sequence, Tuple

from tools.lint import astutil, pragmas, report
from tools.lint.report import Finding
from tools.lint.rules import ALL_RULES, RULE_IDS

EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_USAGE = 2
EXIT_NO_FILES = 5

SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "build", "dist"}


def collect_files(targets: Sequence[str]) -> List[str]:
    """Expand files/directories into a sorted, de-duplicated list of .py
    paths.  Nonexistent targets raise ValueError (a usage error, not an
    empty run)."""
    out = set()
    for t in targets:
        if os.path.isfile(t):
            if t.endswith(".py"):
                out.add(os.path.normpath(t))
        elif os.path.isdir(t):
            for dirpath, dirnames, filenames in os.walk(t):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in SKIP_DIRS)
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        out.add(os.path.normpath(os.path.join(dirpath, fn)))
        else:
            raise ValueError(f"no such file or directory: {t}")
    return sorted(out)


def lint_file(path: str, relpath: str) -> List[Finding]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(relpath, e.lineno or 0, (e.offset or 1) - 1,
                        "parse-error", "error",
                        f"unparseable: {e.msg}")]
    astutil.attach_parents(tree)
    found: List[Finding] = []
    for rule in ALL_RULES:
        found.extend(rule.check(tree, source, relpath))
    prs = pragmas.collect(relpath, source)
    kept, problems = pragmas.apply(found, prs)
    return kept + problems


def lint_paths(targets: Sequence[str]) -> Tuple[List[Finding], int]:
    """Lint every .py file under targets.  Returns (sorted findings,
    number of files examined)."""
    files = collect_files(targets)
    findings: List[Finding] = []
    for path in files:
        rel = path.replace(os.sep, "/")
        findings.extend(lint_file(path, rel))
    return report.sort_findings(findings), len(files)


def _apply_baseline(findings: List[Finding],
                    baseline_path: str) -> List[Finding]:
    """Subtract baselined findings (matched on file/rule/message so line
    drift does not resurrect them).  The committed baseline is empty;
    this exists so a future grandfathering step diffs cleanly."""
    with open(baseline_path, encoding="utf-8") as f:
        entries = json.load(f)
    allowed = {}
    for e in entries:
        key = (e["file"], e["rule"], e["message"])
        allowed[key] = allowed.get(key, 0) + 1
    kept = []
    for f_ in findings:
        key = (f_.file, f_.rule, f_.message)
        if allowed.get(key, 0) > 0:
            allowed[key] -= 1
        else:
            kept.append(f_)
    return kept


def main(argv: Sequence[str] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint: AST invariant checker for the runtime's "
                    f"standing contracts (rules: {', '.join(RULE_IDS)})")
    parser.add_argument("targets", nargs="*",
                        help="files or directories to lint (e.g. src)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit sorted JSON findings (baseline format)")
    parser.add_argument("--baseline", metavar="PATH",
                        help="JSON findings file to subtract (the committed "
                             "baseline is empty)")
    args = parser.parse_args(argv)

    if not args.targets:
        print("repro-lint: no targets given (try: python -m tools.lint src)",
              file=sys.stderr)
        return EXIT_USAGE
    try:
        findings, n_files = lint_paths(args.targets)
    except ValueError as e:
        print(f"repro-lint: {e}", file=sys.stderr)
        return EXIT_USAGE
    if n_files == 0:
        print("repro-lint: zero Python files collected — refusing to report "
              "a vacuous pass", file=sys.stderr)
        return EXIT_NO_FILES
    if args.baseline:
        try:
            findings = _apply_baseline(findings, args.baseline)
        except (OSError, ValueError, KeyError) as e:
            print(f"repro-lint: cannot apply baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return EXIT_USAGE

    if args.as_json:
        sys.stdout.write(report.render_json(findings))
    else:
        body = report.render_human(findings)
        if body:
            print(body)
        print(report.summarize(findings, n_files))
    return EXIT_FINDINGS if findings else EXIT_CLEAN


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
