"""Launch planning for the ragged fused paged-attention kernel: host-side
``cu_blocks`` construction, grid-step accounting, and the per-cell
autotuned-config cache.

The ragged kernel (kernels/paged_verify_attn.py) sizes its grid by the
*real* number of allocated blocks — ``sum_b max(live_blocks(b), 1)`` steps
instead of the dense ``B * MAXB`` — and exposes two launch knobs
(``num_buffers`` manual-DMA depth, ``vmem_limit_bytes``).  This module
owns the three host/trace-boundary pieces around it:

* :func:`host_cu_blocks` — build the ``[B + 1]`` cumulative step array
  from the host block tables (the engine's ``PagedKVTables`` accounting
  already lives on host, so this costs no device round-trip; the array
  rides into the registered jits as one tiny int32 operand).
* :func:`grid_steps_ragged` / :func:`grid_steps_dense` /
  :func:`dead_tile_fraction` — the shared step-count arithmetic used by
  the dispatch layer, the microbenchmark's per-cell records, the
  ``--check`` regression gate, and the serving telemetry's grid-occupancy
  gauge.  One definition keeps all four honest with the kernel's actual
  grid (``ragged_plan`` gives every empty slot one dead step so its
  output row still finalizes to zeros).
* :func:`lookup_config` — dispatch-time lookup of the autotuned launch
  config for a ``(batch, T, max_blocks)`` cell.  ``benchmarks/
  kernel_bench.py --autotune`` searches the knob space per cell and
  caches the winners into ``results/BENCH_kernels.json`` under
  ``"autotune"``; the lookup loads that file lazily (once per process),
  falls back to :data:`DEFAULT_CONFIG` when the file or cell is missing,
  and otherwise picks the nearest recorded cell by log-distance — so an
  unmeasured shape inherits the config of its closest measured neighbour.
  The lookup runs at *trace* time (shapes are static), never per step.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional, Tuple

import numpy as np

# results/BENCH_kernels.json relative to the repo root (three dirs up)
RESULTS_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "results", "BENCH_kernels.json")


@dataclasses.dataclass(frozen=True)
class RaggedConfig:
    """Launch knobs for one ragged-kernel call.

    ``num_buffers = 0`` keeps the standard BlockSpec auto-pipeline;
    ``>= 2`` switches to the explicit manual-DMA ring of that depth.
    ``vmem_limit_bytes`` bounds the TPU compiler's VMEM budget for the
    launch (None = compiler default; ignored in interpret mode).
    """
    num_buffers: int = 0
    vmem_limit_bytes: Optional[int] = None

    def to_json(self) -> dict:
        return {"num_buffers": self.num_buffers,
                "vmem_limit_bytes": self.vmem_limit_bytes}

    @classmethod
    def from_json(cls, d: dict) -> "RaggedConfig":
        return cls(num_buffers=int(d.get("num_buffers", 0)),
                   vmem_limit_bytes=(None if d.get("vmem_limit_bytes")
                                     is None
                                     else int(d["vmem_limit_bytes"])))


DEFAULT_CONFIG = RaggedConfig()

# the autotuner's search space: manual-DMA depths (0 = auto pipeline,
# then double/triple/quad buffering) x VMEM budgets (None = default)
SEARCH_NUM_BUFFERS = (0, 2, 3, 4)
SEARCH_VMEM_LIMITS = (None, 32 << 20, 64 << 20)


# ---------------------------------------------------------------------------
# host-side grid arithmetic (np only — callers hold host block tables)


def host_cu_blocks(tables: np.ndarray) -> np.ndarray:
    """Cumulative ragged grid-step counts ``[B + 1]`` from host block
    tables ``[B, MAXB]`` (physical ids, -1 unused): per-slot steps =
    ``max(live, 1)`` — every slot keeps at least one (dead) step so its
    accumulators initialize and its output row finalizes to zeros."""
    live = (tables >= 0).sum(axis=1)
    steps = np.maximum(live, 1)
    return np.concatenate([np.zeros(1, np.int32),
                           np.cumsum(steps).astype(np.int32)])


def grid_steps_ragged(tables: np.ndarray) -> int:
    """Total ragged grid steps for these tables: ``sum max(live, 1)``."""
    return int(host_cu_blocks(tables)[-1])


def grid_steps_dense(tables: np.ndarray) -> int:
    """Total dense grid steps: ``B * MAXB``, raggedness notwithstanding."""
    return int(tables.shape[0] * tables.shape[1])


def dead_tile_fraction(tables: np.ndarray) -> float:
    """Fraction of the dense grid that is dead tiles — the share of grid
    steps the ragged kernel simply does not launch."""
    dense = grid_steps_dense(tables)
    return 1.0 - grid_steps_ragged(tables) / float(dense) if dense else 0.0


# ---------------------------------------------------------------------------
# per-cell autotuned-config cache


def cell_key(batch: int, t: int, max_blocks: int) -> str:
    """The JSON key for one autotune cell: q batch x q length (s+1 for
    verify, chunk width for prefix extension) x table width."""
    return f"B{int(batch)}_T{int(t)}_MAXB{int(max_blocks)}"


_cache: Optional[Dict[str, RaggedConfig]] = None
_cache_path: Optional[str] = None


def clear_config_cache() -> None:
    """Drop the lazily-loaded autotune table (tests; after re-tuning)."""
    global _cache, _cache_path
    _cache = None
    _cache_path = None


def _load(path: str) -> Dict[str, RaggedConfig]:
    global _cache, _cache_path
    if _cache is not None and _cache_path == path:
        return _cache
    table: Dict[str, RaggedConfig] = {}
    try:
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
        for key, rec in (data.get("autotune") or {}).items():
            table[key] = RaggedConfig.from_json(rec.get("config", rec))
    except (OSError, ValueError):
        table = {}
    _cache, _cache_path = table, path
    return table


def _parse_key(key: str) -> Optional[Tuple[int, int, int]]:
    try:
        b, t, m = key.split("_")
        return int(b[1:]), int(t[1:]), int(m[4:])
    except (ValueError, IndexError):
        return None


def lookup_config(batch: int, t: int, max_blocks: int,
                  path: Optional[str] = None) -> RaggedConfig:
    """The autotuned launch config for a ``(batch, T, max_blocks)`` cell.

    Exact cell if measured; else the nearest measured cell by summed
    log2-distance over the three dims (shapes scale geometrically, so log
    distance matches how configs generalize); else the safe default.
    """
    table = _load(path or RESULTS_PATH)
    if not table:
        return DEFAULT_CONFIG
    key = cell_key(batch, t, max_blocks)
    if key in table:
        return table[key]
    want = (batch, t, max_blocks)

    def dist(key: str) -> float:
        dims = _parse_key(key)
        if dims is None:
            return math.inf
        return sum(abs(math.log2(max(a, 1)) - math.log2(max(b, 1)))
                   for a, b in zip(want, dims))

    best = min(table, key=dist)
    return table[best] if math.isfinite(dist(best)) else DEFAULT_CONFIG
