"""Speculative-verify attention Pallas kernel (the paper's hot spot).

One verify step scores q_len = s+1 draft positions against a long ragged KV
cache (decode_32k: 32k rows; long_500k: a ring-buffered window).  This is a
flash-decode-style kernel: the *whole* tiny q block (s+1 rows, padded to the
8-row sublane multiple) stays resident in VMEM while the kernel streams the
cache in ``block_k`` tiles; grid = (batch, k_blocks).

TPU adaptation of the paper's GPU attention-mask trick: rejection masking is
position arithmetic on the ring buffer's absolute-position row map (k_pos),
so "discarding" mis-speculated tokens costs nothing — stale rows simply stay
masked until overwritten.  Cache tiles whose positions are all outside the
(q - window, q] visibility range are *skipped* (@pl.when) — on a 512k-row
cache with an 8k window that's a 64x reduction in touched tiles, the
structural equivalent of flash-decode's early exit.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _verify_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, window: Optional[int], prefix_len: int,
                   nk: int, ks_ref=None, vs_ref=None):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    qp = qp_ref[0]                                       # [Tq]
    kp = kp_ref[0]                                       # [bk]

    # tile-level visibility: any cache row in this tile attendable by any query?
    q_hi = qp.max()
    vis = (kp >= 0) & (kp <= q_hi)
    if window is not None:
        q_lo = jnp.where(qp < 0, jnp.iinfo(jnp.int32).max, qp).min()
        vis &= kp > q_lo - window
    if prefix_len:
        vis |= (kp >= 0) & (kp < prefix_len)

    @pl.when(vis.any())
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # [Tq, hd]
        k = k_ref[0].astype(jnp.float32)                 # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        if ks_ref is not None:
            # int8 cache tiles: HBM moved them at 1 B/elem; dequantize in
            # VMEM with the per-row scales (the beyond-paper kv_quant path)
            k = k * ks_ref[0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        ok = (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])
        if window is not None:
            ok &= kp[None, :] > qp[:, None] - window
        if prefix_len:
            ok |= (kp[None, :] >= 0) & (kp[None, :] < prefix_len)
        s = jnp.where(ok, s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.where(ok, jnp.exp(s - m_safe[:, None]), 0.0)
        corr = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def choose_block_k(L: int, block_k: int):
    """Sublane-aligned cache tiling: ``(bk, Lp)`` with ``Lp % bk == 0``.

    The old policy (``while L % bk: bk -= 1``) silently degraded to tiny —
    even 1-row — tiles whenever L had no large divisor (prime-ish cache
    lengths), collapsing MXU utilisation.  Policy now:

    1. prefer a *divisor* tile — the largest multiple-of-8 divisor of L
       that is <= requested and >= the 64-row floor — because it needs no
       padding and therefore no physical copy of the cache operands (e.g.
       L=640, block_k=512 picks 320 exactly as before; L=520 picks 104
       where the old loop picked the unaligned 260 — smaller, but
       sublane-aligned and still zero-copy);
    2. otherwise keep the requested tile (rounded to the 8-row sublane
       multiple) and pad the cache *tail* to the next multiple: padded
       rows carry ``k_pos = -1`` and are never attendable, so numerics
       are unchanged and the tile never collapses.  Padding copies the
       cache operands, so it is reserved for lengths with no
       MXU-reasonable divisor (any non-multiple-of-8 L necessarily pads —
       there is no sublane-aligned divisor to find).

    Known trade-off: a length with *no* divisor tile >= 64 (e.g. 8*prime)
    pays the pad copy every call.  Serving cache lengths are chosen by the
    caller, and every config in this repo uses lengths with good divisors;
    callers picking exotic lengths should round up to a multiple of 64 at
    cache-allocation time to get the zero-copy path.
    """
    req = max(8, min(block_k, L + (-L) % 8))
    req -= req % 8                      # sublane multiple, never a tiny tile
    if L % 8 == 0:
        # 64-row floor: a divisor tile below it is the old degradation
        # failure mode (tiny tiles), worse than one padded copy
        for bk in range(req, min(64, req) - 1, -8):
            if L % bk == 0:
                return bk, L            # divisor tile: zero-copy
    assert req % 8 == 0 and req >= 8, (L, block_k, req)
    Lp = L + (-L) % req
    return req, Lp


def spec_verify_attn_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            q_pos: jax.Array, k_pos: jax.Array,
                            window: Optional[int] = None, prefix_len: int = 0,
                            scale: Optional[float] = None,
                            block_k: int = 512,
                            k_scale: Optional[jax.Array] = None,
                            v_scale: Optional[jax.Array] = None,
                            interpret: bool = False) -> jax.Array:
    """q: [B, Tq, hd] with tiny Tq (s+1, padded to a multiple of 8 by ops.py;
    padded rows carry q_pos = -1); k/v: [B, L, hd]; k_pos: [B, L].
    Optional k_scale/v_scale: [B, L] per-row dequant scales for int8 k/v
    (the kv_quant cache — tiles stream from HBM at 1 B/elem and are
    dequantized in VMEM).  Returns [B, Tq, hd]."""
    B, Tq, hd = q.shape
    L = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bk, Lp = choose_block_k(L, block_k)
    if Lp != L:
        # pad the cache tail with k_pos = -1 rows (never attendable) so the
        # tile stays a sublane multiple instead of degrading for prime-ish L
        ext = ((0, 0), (0, Lp - L), (0, 0))
        k = jnp.pad(k, ext)
        v = jnp.pad(v, ext)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, Lp - L)), constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, Lp - L)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, Lp - L)))
    nk = Lp // bk
    quant = k_scale is not None
    in_specs = [
        pl.BlockSpec((1, Tq, hd), lambda b, j: (b, 0, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
        pl.BlockSpec((1, Tq), lambda b, j: (b, 0)),
        pl.BlockSpec((1, bk), lambda b, j: (b, j)),
    ]
    args = [q, k, v, q_pos, k_pos]
    kern = functools.partial(_verify_kernel, scale=scale, window=window,
                             prefix_len=prefix_len, nk=nk)
    if quant:
        in_specs += [pl.BlockSpec((1, bk), lambda b, j: (b, j)),
                     pl.BlockSpec((1, bk), lambda b, j: (b, j))]
        args += [k_scale, v_scale]

        def kern(q_ref, k_ref, v_ref, qp_ref, kp_ref, ks_ref, vs_ref, o_ref,
                 acc_ref, m_ref, l_ref):
            return _verify_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                                  acc_ref, m_ref, l_ref, scale=scale,
                                  window=window, prefix_len=prefix_len,
                                  nk=nk, ks_ref=ks_ref, vs_ref=vs_ref)
    return pl.pallas_call(
        kern,
        grid=(B, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Tq, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Tq, hd), jnp.float32),
            pltpu.VMEM((Tq,), jnp.float32),
            pltpu.VMEM((Tq,), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
