"""jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernels compile natively; on CPU
(this container) callers either use ``interpret=True`` (tests — executes the
kernel body in Python for bit-faithful validation) or fall back to the
pure-jnp reference (fast path for CPU benchmarks).  Models call these
wrappers, so swapping the implementation never touches model code.

GQA head folding: the attention kernels operate on one kv-head per grid row.
``flash_attn``/``spec_verify_attn`` fold (batch, kv_head) into the kernel
batch dim and the q-head group into the q rows, so a 32-head/4-kv-head GQA
layer becomes 4 kernel batches of 8x-longer q blocks — dense MXU tiles
instead of 8 strided passes.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attn import flash_attn_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.spec_verify_attn import spec_verify_attn_pallas
from repro.kernels.ssd_chunk import ssd_chunk_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(use_pallas: Optional[bool]) -> str:
    """'pallas' | 'interpret' | 'ref'."""
    if use_pallas is None:
        return "pallas" if _on_tpu() else "ref"
    if use_pallas:
        return "pallas" if _on_tpu() else "interpret"
    return "ref"


# public alias: kernels/paged.py routes its fused-vs-gather dispatch through
# the exact same policy (None -> native on TPU / reference on CPU;
# True -> native on TPU / interpret elsewhere; False -> reference)
kernel_mode = _mode


# ---------------------------------------------------------------------------
# rmsnorm


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
            use_pallas: Optional[bool] = None) -> jax.Array:
    m = _mode(use_pallas)
    if m == "ref":
        return _ref.rmsnorm_ref(x, gamma, eps)
    return rmsnorm_pallas(x, gamma, eps, interpret=(m == "interpret"))


# ---------------------------------------------------------------------------
# GQA head folding helpers


def _fold_gqa(q: jax.Array, k: jax.Array, v: jax.Array,
              q_pos: jax.Array, k_pos: jax.Array):
    """[B,T,H,hd] x [B,L,KVH,hd] -> per-kv-head folded batches.

    Returns (qf [B*KVH, G*T, hd], kf [B*KVH, L, hd], vf, qpf [B*KVH, G*T],
    kpf [B*KVH, L], unfold) where unfold maps [B*KVH, G*T, vd] back to
    [B, T, H, vd].
    """
    B, T, H, hd = q.shape
    L, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    # q: [B,T,KVH,G,hd] -> [B,KVH,G,T,hd] -> [B*KVH, G*T, hd]
    qf = (q.reshape(B, T, KVH, G, hd).transpose(0, 2, 3, 1, 4)
           .reshape(B * KVH, G * T, hd))
    kf = k.transpose(0, 2, 1, 3).reshape(B * KVH, L, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KVH, L, v.shape[-1])
    qpf = jnp.broadcast_to(q_pos[:, None, None, :], (B, KVH, G, T)).reshape(
        B * KVH, G * T)
    kpf = jnp.broadcast_to(k_pos[:, None, :], (B, KVH, L)).reshape(B * KVH, L)

    def unfold(o: jax.Array) -> jax.Array:
        vd = o.shape[-1]
        return (o.reshape(B, KVH, G, T, vd).transpose(0, 3, 1, 2, 4)
                 .reshape(B, T, H, vd))

    return qf, kf, vf, qpf, kpf, unfold


# ---------------------------------------------------------------------------
# flash attention (training / prefill)


def flash_attn(q: jax.Array, k: jax.Array, v: jax.Array,
               q_pos: jax.Array, k_pos: jax.Array,
               window: Optional[int] = None, prefix_len: int = 0,
               scale: Optional[float] = None,
               block_q: int = 512, block_k: int = 512,
               use_pallas: Optional[bool] = None) -> jax.Array:
    """GQA flash attention.  q: [B,T,H,hd]; k/v: [B,L,KVH,hd];
    q_pos/k_pos: [B,T]/[B,L].  Returns [B,T,H,vd]."""
    m = _mode(use_pallas)
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    if m == "ref":
        # unfolded layout: keeps the model-axis sharding of q/k/v intact
        return _ref.gqa_masked_ref(q, k, v, q_pos, k_pos, window, prefix_len,
                                   scale)
    qf, kf, vf, qpf, kpf, unfold = _fold_gqa(q, k, v, q_pos, k_pos)
    o = flash_attn_pallas(qf, kf, vf, qpf, kpf, window, prefix_len, scale,
                          block_q, block_k, interpret=(m == "interpret"))
    return unfold(o)


# ---------------------------------------------------------------------------
# speculative verify attention (decode hot path)


def spec_verify_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                     q_pos: jax.Array, k_pos: jax.Array,
                     window: Optional[int] = None, prefix_len: int = 0,
                     scale: Optional[float] = None, block_k: int = 512,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None,
                     use_pallas: Optional[bool] = None) -> jax.Array:
    """Verify-step attention.  Same shapes as :func:`flash_attn` with tiny T
    (s+1); q rows are padded to a multiple of 8 for TPU sublanes, padded rows
    carry q_pos = -1 and are sliced off the output.

    int8 caches (kv_quant): pass the int8 k/v plus per-(row, kv-head)
    ``k_scale``/``v_scale`` [B, L, KVH].  The Pallas kernel streams 1 B/elem
    from HBM and dequantizes in VMEM; the CPU reference dequantizes up front
    (numerically identical, HBM accounting differs — launch/costs.py models
    the kernel behaviour)."""
    m = _mode(use_pallas)
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    if m == "ref":
        if k_scale is not None:
            k = (k.astype(jnp.float32) * k_scale.astype(jnp.float32)[..., None]
                 ).astype(q.dtype)
            v = (v.astype(jnp.float32) * v_scale.astype(jnp.float32)[..., None]
                 ).astype(q.dtype)
        # unfolded layout: keeps the model-axis sharding of the cache intact
        return _ref.gqa_masked_ref(q, k, v, q_pos, k_pos, window, prefix_len,
                                   scale)
    qf, kf, vf, qpf, kpf, unfold = _fold_gqa(q, k, v, q_pos, k_pos)
    ksf = vsf = None
    if k_scale is not None:
        B, L, KVH = k_scale.shape
        ksf = k_scale.transpose(0, 2, 1).reshape(B * KVH, L)
        vsf = v_scale.transpose(0, 2, 1).reshape(B * KVH, L)
    rows = qf.shape[1]
    pad = (-rows) % 8
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        qpf = jnp.pad(qpf, ((0, 0), (0, pad)), constant_values=-1)
    o = spec_verify_attn_pallas(qf, kf, vf, qpf, kpf, window, prefix_len,
                                scale, block_k, k_scale=ksf, v_scale=vsf,
                                interpret=(m == "interpret"))
    if pad:
        o = o[:, :rows]
    return unfold(o)


# ---------------------------------------------------------------------------
# SSD chunk


def ssd_chunk(x: jax.Array, b: jax.Array, c: jax.Array, dt: jax.Array,
              l: jax.Array, h0: jax.Array,
              use_pallas: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Batched SSD chunk.  x: [BH,Q,P]; b/c: [BH,Q,N]; dt/l: [BH,Q];
    h0: [BH,P,N] -> (y [BH,Q,P], h_new [BH,P,N]) fp32."""
    m = _mode(use_pallas)
    if m == "ref":
        ys, hs = jax.vmap(_ref.ssd_chunk_ref)(x, b, c, dt, l, h0)
        return ys, hs
    return ssd_chunk_pallas(x, b, c, dt, l, h0, interpret=(m == "interpret"))
