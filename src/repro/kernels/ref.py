"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle defines the kernel's exact numerical contract; tests sweep
shapes/dtypes and assert_allclose(kernel(interpret=True), ref).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Row RMS norm in fp32 with output in x.dtype (matches models.common)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma)


def flash_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, k_pos: jax.Array,
                   window: Optional[int] = None, prefix_len: int = 0,
                   scale: Optional[float] = None) -> jax.Array:
    """Masked attention, one kv-head group.

    q: [B, Tq, hd]  (the wrapper folds (kv_head, group) into B and rows)
    k/v: [B, Tk, hd]; q_pos: [B, Tq]; k_pos: [B, Tk] (-1 = unwritten row).
    Attendable iff 0 <= k_pos <= q_pos and k_pos > q_pos - window, OR
    k_pos < prefix_len (bidirectional modality prefix).
    """
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqh,bkh->bqk", q, k).astype(jnp.float32) * scale
    qp, kp = q_pos[:, :, None], k_pos[:, None, :]
    ok = (kp >= 0) & (kp <= qp)
    if window is not None:
        ok &= kp > qp - window
    if prefix_len:
        ok |= (kp >= 0) & (kp < prefix_len)
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (q_pos = -1 padding) produce zeros
    p = jnp.where(ok.any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bqk,bkh->bqh", p.astype(v.dtype), v)


def spec_verify_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, k_pos: jax.Array,
                    window: Optional[int] = None, prefix_len: int = 0,
                    scale: Optional[float] = None) -> jax.Array:
    """Verify-step attention: same contract as flash_attn_ref (tiny Tq = s+1,
    long Tk = cache length); kept separate because the kernel tiles
    differently (whole-q block, stream over the cache)."""
    return flash_attn_ref(q, k, v, q_pos, k_pos, window, prefix_len, scale)


def gqa_masked_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, k_pos: jax.Array,
                   window: Optional[int] = None, prefix_len: int = 0,
                   scale: Optional[float] = None) -> jax.Array:
    """GQA attention in the *unfolded* layout (q: [B,T,H,hd]; k/v:
    [B,L,KVH,hd]) with the same position-mask contract as flash_attn_ref.

    This is the CPU / dry-run execution path: it never reshapes the
    (model-axis-sharded) KV cache, so GSPMD keeps heads sharded instead of
    all-gathering the cache (the folded layout is a kernel-only concern).
    """
    B, T, H, hd = q.shape
    L, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, T, KVH, G, hd)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32) * scale
    qp, kp = q_pos[:, :, None], k_pos[:, None, :]
    ok = (kp >= 0) & (kp <= qp)
    if window is not None:
        ok &= kp > qp - window
    if prefix_len:
        ok |= (kp >= 0) & (kp < prefix_len)
    okb = ok[:, None, None]                                # [B,1,1,T,L]
    s = jnp.where(okb, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(okb.any(-1, keepdims=True), p, 0.0)
    out = jnp.einsum("bkgts,bskh->btkgh", p.astype(v.dtype), v)
    return out.reshape(B, T, H, v.shape[-1])


def ssd_chunk_ref(x: jax.Array, b: jax.Array, c: jax.Array, dt: jax.Array,
                  l: jax.Array, h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One SSD chunk (contract of models.mamba2._ssd_chunked's body), for a
    single (batch, head) slice.

    x: [Q, P] inputs; b/c: [Q, N]; dt: [Q] (>=0); l: [Q] log-decay (<=0);
    h0: [P, N] carried state.  Returns (y [Q, P], h_new [P, N]), fp32.
    """
    x = x.astype(jnp.float32); b = b.astype(jnp.float32); c = c.astype(jnp.float32)
    dt = dt.astype(jnp.float32); l = l.astype(jnp.float32); h0 = h0.astype(jnp.float32)
    Q = x.shape[0]
    cs = jnp.cumsum(l)                                   # [Q] inclusive
    cb = jnp.einsum("in,jn->ij", c, b)                   # [Q, Q]
    dec = cs[:, None] - cs[None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(mask, cb * jnp.exp(jnp.where(mask, dec, 0.0)), 0.0)
    y_in = jnp.einsum("ij,j,jp->ip", M, dt, x)
    y_h = jnp.einsum("in,pn->ip", c * jnp.exp(cs)[:, None], h0)
    decay_end = jnp.exp(cs[-1] - cs)                     # [Q]
    contrib = jnp.einsum("j,jp,jn->pn", dt * decay_end, x, b)
    h_new = jnp.exp(cs[-1]) * h0 + contrib
    return y_in + y_h, h_new
