"""Causal flash attention Pallas kernel (TPU target, interpret=True on CPU).

Tiling: grid (batch, q_blocks, k_blocks), k innermost so the online-softmax
accumulators live in VMEM scratch across the k sweep.  Blocks are
(block_q x head_dim) and (block_k x head_dim); with the default 512x128
blocks the working set is ~1 MiB of VMEM — far under the ~16 MiB/core v5e
budget, and every matmul dim is a multiple of 128 for the MXU.

Masking is position-based (absolute positions, -1 = unwritten/padded row),
identical to models.common.position_mask, so the same kernel serves causal
training, sliding-window long-context, bidirectional-prefix VLM attention,
and ragged prefill.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  scale: float, window: Optional[int], prefix_len: int,
                  nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                     # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    qp = qp_ref[0]                                       # [bq] int32
    kp = kp_ref[0]                                       # [bk]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]
    ok = (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])
    if window is not None:
        ok &= kp[None, :] > qp[:, None] - window
    if prefix_len:
        ok |= (kp[None, :] >= 0) & (kp[None, :] < prefix_len)
    s = jnp.where(ok, s, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(ok, p, 0.0)
    corr = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attn_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array,
                      window: Optional[int] = None, prefix_len: int = 0,
                      scale: Optional[float] = None,
                      block_q: int = 512, block_k: int = 512,
                      interpret: bool = False) -> jax.Array:
    """q: [B, Tq, hd]; k/v: [B, Tk, hd]; q_pos/k_pos: [B, Tq]/[B, Tk] int32.
    Returns [B, Tq, hd] in q.dtype.  (GQA head folding lives in ops.py.)"""
    B, Tq, hd = q.shape
    Tk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    def fit(blk, n):
        blk = min(blk, n)
        while n % blk:
            blk -= 1
        return blk

    bq, bk = fit(block_q, Tq), fit(block_k, Tk)
    nq, nk = Tq // bq, Tk // bk

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, window=window,
                          prefix_len=prefix_len, nk=nk),
        grid=(B, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tq, hd), q.dtype),
        scratch_shapes=[
            # (bq, hd) accumulator, (bq,) running max, (bq,) running sum
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos, k_pos)
