"""SSD intra-chunk Pallas kernel (Mamba-2 hot spot, arXiv:2405.21060 §6).

The SSD decomposition splits the sequence into chunks: within a chunk the
recurrence is a masked-decay "attention-like" quadratic form (MXU-friendly),
across chunks a diagonal recurrence carries the state.  This kernel computes
the quadratic intra-chunk term plus the carried-state contributions for one
(batch*head, chunk) grid cell; the O(n_chunks) outer recurrence stays a
lax.scan in the model (it is sequential by construction and tiny).

VMEM working set per cell: x [Q,P] + b,c [Q,N] + M [Q,Q] + state [P,N];
with Q = 256, P = 64, N = 128 that is ~0.6 MiB fp32.  Q and N are multiples
of 128/8 so the two dot_generals hit the MXU; the h0 contribution reuses the
same tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_chunk_kernel(x_ref, b_ref, c_ref, dt_ref, l_ref, h0_ref,
                      y_ref, h_ref):
    x = x_ref[0].astype(jnp.float32)                     # [Q, P]
    b = b_ref[0].astype(jnp.float32)                     # [Q, N]
    c = c_ref[0].astype(jnp.float32)                     # [Q, N]
    dt = dt_ref[0].astype(jnp.float32)                   # [Q]
    l = l_ref[0].astype(jnp.float32)                     # [Q]
    h0 = h0_ref[0].astype(jnp.float32)                   # [P, N]
    Q = x.shape[0]

    cs = jnp.cumsum(l)                                   # [Q]
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))   # [Q, Q]
    dec = cs[:, None] - cs[None, :]
    mask = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    M = jnp.where(mask, cb * jnp.exp(jnp.where(mask, dec, 0.0)), 0.0)
    y_in = jax.lax.dot_general(M * dt[None, :], x, (((1,), (0,)), ((), ())))
    # carried-state contribution to every position
    y_h = jax.lax.dot_general(c * jnp.exp(cs)[:, None], h0,
                              (((1,), (1,)), ((), ())))        # [Q, P]
    y_ref[0] = (y_in + y_h).astype(y_ref.dtype)
    # state update for the next chunk
    decay_end = jnp.exp(cs[-1] - cs)
    wx = x * (dt * decay_end)[:, None]                   # [Q, P]
    contrib = jax.lax.dot_general(wx, b, (((0,), (0,)), ((), ())))  # [P, N]
    h_ref[0] = (jnp.exp(cs[-1]) * h0 + contrib).astype(h_ref.dtype)


def ssd_chunk_pallas(x: jax.Array, b: jax.Array, c: jax.Array,
                     dt: jax.Array, l: jax.Array, h0: jax.Array,
                     interpret: bool = False):
    """One chunk for a batch of (batch*head) slices.

    x: [BH, Q, P]; b/c: [BH, Q, N]; dt/l: [BH, Q]; h0: [BH, P, N].
    Returns (y [BH, Q, P], h_new [BH, P, N]) in fp32.
    """
    BH, Q, P = x.shape
    N = b.shape[-1]
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q), lambda i: (i, 0)),
            pl.BlockSpec((1, Q), lambda i: (i, 0)),
            pl.BlockSpec((1, P, N), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, P, N), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, b, c, dt, l, h0)
