"""Fused paged verify-attention Pallas kernels: stream KV straight through
the block tables, never materializing a gathered logical view.

The gather path (kernels/paged.py ``gather_verify_attn``) rebuilds each
slot's contiguous ``[B, MAXB*bs, KVH, hd]`` KV view before running the
verify kernel over the copy — every paged verify step pays the pool's HBM
traffic twice (gather write + kernel read) and the transient view grows
linearly with batch size, exactly the regime where the paper's batching x
speculation synergy lives.  These kernels remove the copy: the k/v/pos
BlockSpec index maps read each tile *directly* from the shared pool
through the slot's block-table row, prefetched as a scalar
(``PrefetchScalarGridSpec``) so the index maps can consume it before the
kernel body runs.

Two grid strategies over the same tile math:

* **dense** (:func:`paged_verify_attn_pallas`): grid ``(batch,
  max_blocks_per_slot)``; ``-1`` table entries (unallocated logical
  blocks — ragged slots, empty rows, mid-chunked-prefill pending slots)
  contribute nothing: the index map clips them to physical block 0 so the
  DMA address is always valid — consecutive dead entries then revisit the
  same block, which the Pallas pipeline recognizes and skips re-fetching —
  and the body skips the tile entirely (``@pl.when``), which is
  numerically identical to every key in it carrying position ``-1`` (the
  gather path's convention).  Dead tiles still cost grid steps.
* **ragged** (:func:`ragged_paged_verify_attn_pallas`): the grid is a
  flat run of ``cu_blocks[B]`` steps — the *sum of live blocks* (each
  empty slot keeps exactly one dead step so its accumulators still
  initialize and its output row still finalizes to zeros), host-computed
  from the same block accounting that owns the tables and prefetched
  alongside them.  Step ``i`` serves slot ``ss[i]`` and its ``sb[i]``-th
  live logical block, both derived in-trace from ``cu_blocks`` and the
  table (stable argsort packs each row's live entries first, in ascending
  logical order — so a slot's blocks are visited in exactly the dense
  kernel's order and the online-softmax accumulation is bit-identical).
  Accumulators init at ``i == cu[b]`` and the output row finalizes at
  ``i == cu[b+1]-1``.  Dead tiles simply do not exist in the grid:
  raggedness costs nothing.

The ragged kernel additionally offers **explicit multi-buffered DMA**
(``num_buffers >= 2``): k/v/pos (and int8 scale) pool tiles live in
``ANY`` memory space and the kernel drives its own ``make_async_copy``
ring — ``num_buffers`` VMEM landing buffers per stream, one DMA semaphore
lane each, warm-up fetch of the first ``num_buffers - 1`` tiles at step 0
and a steady-state fetch of tile ``i + num_buffers - 1`` each step — so
the fetch horizon (how far DMA runs ahead of compute) is a tunable knob
instead of the pipeline default.  ``num_buffers = 0`` keeps the standard
BlockSpec auto-pipeline.  ``profile='dma'`` / ``profile='compute'`` skip
the compute or the copies respectively so the benchmark can price the two
halves of the pipeline separately (benchmarks/kernel_bench.py
``--profile-dma``).

Masking (q_pos/k_pos arithmetic, ``window``, ``prefix_len``) is the shared
position-mask contract of kernels/ref.py, evaluated against the pool's
per-row ``pos`` map — identical to gathering first, because a slot only
ever reaches its own blocks (ownership by construction of the table).
Mixed launches ride the same contract: a batch row may carry verify
queries (positions ``seq-1 .. seq+s-1``) or a chunk-prefill prefix
extension (positions ``start .. start+n``) — per-query-row masking plus
per-row block tables make the kernel agnostic to which is which, and
``q_pos = -1`` rows (padding in heterogeneous launches) match nothing.

GQA: the pool keeps its ``[NB, bs, KVH, hd]`` layout (one DMA per owned
block covers every kv head — blocks are owned by exactly one slot, so each
pool row is read exactly once per step, the HBM floor), and the kernel
loops the kv heads as an unrolled static loop of 2D MXU dots.  The q block
is pre-folded to ``[B, KVH, G*Tq, hd]`` host-side (tiny) and stays VMEM-
resident across the whole block stream.

int8 KV (kv_quant): per-(row, kv-head) ``k_scale``/``v_scale`` pool arrays
ride the same block-table index maps (or DMA ring); tiles stream from HBM
at 1 B/elem and dequantize in VMEM — the contiguous kernel's quant path,
carried over.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_tile(q_ref, kt, vt, qp, kp, acc_ref, m_ref, l_ref, *,
                scale: float, window: Optional[int], prefix_len: int,
                kvh: int, ks=None, vs=None):
    """Fold one ``[bs, KVH, hd]`` KV tile into the online-softmax
    accumulators — the shared tile math of the dense and ragged grids.

    ``kt``/``vt`` are tile *values* (read from a BlockSpec ref or a manual
    DMA landing buffer); ``ks``/``vs`` are the int8 dequant scale tiles
    ``[bs, KVH]`` when the pool is quantized.
    """
    ok = (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])   # [GT, bs]
    if window is not None:
        ok &= kp[None, :] > qp[:, None] - window
    if prefix_len:
        ok |= (kp[None, :] >= 0) & (kp[None, :] < prefix_len)
    for h in range(kvh):                             # unrolled 2D dots
        q = q_ref[0, h].astype(jnp.float32)          # [GT, hd]
        k = kt[:, h, :].astype(jnp.float32)          # [bs, hd]
        v = vt[:, h, :].astype(jnp.float32)
        if ks is not None:
            # int8 pool tiles: moved at 1 B/elem, dequantized in VMEM
            k = k * ks[:, h].astype(jnp.float32)[:, None]
            v = v * vs[:, h].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        s = jnp.where(ok, s, -jnp.inf)
        m_prev = m_ref[h]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.where(ok, jnp.exp(s - m_safe[:, None]), 0.0)
        corr = jnp.where(jnp.isneginf(m_prev), 0.0,
                         jnp.exp(m_prev - m_safe))
        l_ref[h] = l_ref[h] * corr + p.sum(axis=-1)
        acc_ref[h] = acc_ref[h] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[h] = m_new


def _tile_visible(qp, kp, window: Optional[int], prefix_len: int):
    """Tile-level visibility (flash-decode early exit): is any pool row in
    this tile attendable by any query?  Dead tiles report False outright —
    identical to every row carrying position -1."""
    q_hi = qp.max()
    vis = (kp >= 0) & (kp <= q_hi)
    if window is not None:
        q_lo = jnp.where(qp < 0, jnp.iinfo(jnp.int32).max, qp).min()
        vis &= kp > q_lo - window
    if prefix_len:
        vis |= (kp >= 0) & (kp < prefix_len)
    return vis.any()


def _fold_q(q: jax.Array, q_pos: jax.Array, kvh: int):
    """Fold q per kv head: ``[B, T, H, hd] -> [B, KVH, G*T, hd]`` (rows
    (g, t), matching ops._fold_gqa's ordering), repeat q_pos per group
    row, and pad the row dim to the TPU sublane multiple (8) with
    ``q_pos = -1`` rows that match nothing.  Returns ``(qf, qpf, GT,
    unfold)`` where unfold maps ``[B, KVH, GT, hd]`` back to
    ``[B, T, H, hd]``.
    """
    B, T, H, hd = q.shape
    G = H // kvh
    qf = (q.reshape(B, T, kvh, G, hd).transpose(0, 2, 3, 1, 4)
           .reshape(B, kvh, G * T, hd))
    qpf = jnp.broadcast_to(q_pos[:, None, :], (B, G, T)).reshape(B, G * T)
    rows = G * T
    pad = (-rows) % 8                       # TPU sublane multiple
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        qpf = jnp.pad(qpf, ((0, 0), (0, pad)), constant_values=-1)
    GT = rows + pad

    def unfold(o: jax.Array) -> jax.Array:
        ot = o[:, :, :rows] if pad else o
        return (ot.reshape(B, kvh, G, T, hd).transpose(0, 3, 1, 2, 4)
                  .reshape(B, T, H, hd))

    return qf, qpf, GT, unfold


# ---------------------------------------------------------------------------
# dense grid: (batch, max_blocks_per_slot), @pl.when skipping dead tiles


def _fused_kernel(bt_ref, q_ref, k_ref, v_ref, qp_ref, pp_ref, *rest,
                  scale: float, window: Optional[int], prefix_len: int,
                  nb: int, kvh: int, quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    qp = qp_ref[0]                                       # [GT]
    kp = pp_ref[0]                                       # [bs]
    owned = bt_ref[b, j] >= 0

    @pl.when(owned & _tile_visible(qp, kp, window, prefix_len))
    def _compute():
        _flash_tile(q_ref, k_ref[0], v_ref[0], qp, kp,
                    acc_ref, m_ref, l_ref, scale=scale, window=window,
                    prefix_len=prefix_len, kvh=kvh,
                    ks=None if ks_ref is None else ks_ref[0],
                    vs=None if vs_ref is None else vs_ref[0])

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def paged_verify_attn_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                             q_pos: jax.Array, pos: jax.Array,
                             block_tables: jax.Array,
                             window: Optional[int] = None,
                             prefix_len: int = 0,
                             scale: Optional[float] = None,
                             k_scale: Optional[jax.Array] = None,
                             v_scale: Optional[jax.Array] = None,
                             interpret: bool = False) -> jax.Array:
    """Verify-step attention against the paged pool, fused, dense grid.

    q: [B, T, H, hd] (tiny T = s+1, or a prefill chunk); k/v:
    [NB, bs, KVH, hd] pool; q_pos: [B, T]; pos: [NB, bs] (absolute position,
    -1 unwritten); block_tables: [B, MAXB] (physical block ids, -1 unused).
    Optional k_scale/v_scale: [NB, bs, KVH] per-(row, kv-head) dequant
    scales for an int8 pool.  Returns [B, T, H, hd].

    No ``[B, MAXB*bs, ...]`` logical view is ever built: tiles stream from
    the pool through the prefetched block table (module docstring).  The
    grid is the dense ``(B, MAXB)`` — dead tiles are skipped but still
    cost grid steps; :func:`ragged_paged_verify_attn_pallas` removes them.
    """
    B, T, H, hd = q.shape
    bs, KVH = k.shape[1], k.shape[2]
    MAXB = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf, qpf, GT, unfold = _fold_q(q, q_pos, KVH)

    # index maps receive the prefetched block table; dead entries clip to
    # physical block 0 (valid address, body skips the tile — and repeated
    # dead entries revisit the same block, so the pipeline elides the DMA)
    def _kv_map(b, j, bt):
        return (jnp.maximum(bt[b, j], 0), 0, 0, 0)

    def _pos_map(b, j, bt):
        return (jnp.maximum(bt[b, j], 0), 0)

    def _scale_map(b, j, bt):
        return (jnp.maximum(bt[b, j], 0), 0, 0)

    quant = k_scale is not None
    in_specs = [
        pl.BlockSpec((1, KVH, GT, hd), lambda b, j, bt: (b, 0, 0, 0)),
        pl.BlockSpec((1, bs, KVH, hd), _kv_map),
        pl.BlockSpec((1, bs, KVH, hd), _kv_map),
        pl.BlockSpec((1, GT), lambda b, j, bt: (b, 0)),
        pl.BlockSpec((1, bs), _pos_map),
    ]
    args = [block_tables, qf, k, v, qpf, pos]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, KVH), _scale_map),
                     pl.BlockSpec((1, bs, KVH), _scale_map)]
        args += [k_scale, v_scale]
    kern = functools.partial(_fused_kernel, scale=scale, window=window,
                             prefix_len=prefix_len, nb=MAXB, kvh=KVH,
                             quant=quant)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, MAXB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, KVH, GT, hd),
                               lambda b, j, bt: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KVH, GT, hd), jnp.float32),
            pltpu.VMEM((KVH, GT), jnp.float32),
            pltpu.VMEM((KVH, GT), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, GT, hd), q.dtype),
        interpret=interpret,
    )(*args)
    return unfold(o)


# ---------------------------------------------------------------------------
# ragged grid: one flat run of sum(max(live_blocks, 1)) steps


def ragged_plan(block_tables: jax.Array, cu_blocks: jax.Array):
    """Derive the step->(slot, logical block) maps for the ragged grid.

    ``cu_blocks`` is the host-computed cumulative step count ``[B + 1]``
    (per-slot steps = max(live blocks, 1); see kernels/tuning.py
    ``host_cu_blocks``).  Returns ``(ss, sb, pbs)`` of static length
    ``B * MAXB`` (the grid only visits the first ``cu_blocks[B]``):

    * ``ss[i]``  — the slot served by step ``i``;
    * ``sb[i]``  — the *logical* block index within that slot's table row
      (its ``(i - cu[ss[i]])``-th live entry, in ascending logical order —
      the dense kernel's visit order, so accumulation is bit-identical);
    * ``pbs[i]`` — the physical pool block (dead entries clipped to 0 so
      the address is always valid; the body's ``owned`` check skips them).

    All three are cheap in-trace int32 ops over ``[B, MAXB]``; they ride
    the scalar-prefetch channel into the index maps.
    """
    B, MAXB = block_tables.shape
    cu = cu_blocks.astype(jnp.int32)
    ar = jnp.arange(B * MAXB, dtype=jnp.int32)
    ss = jnp.clip(jnp.searchsorted(cu, ar, side="right") - 1,
                  0, B - 1).astype(jnp.int32)
    # stable argsort of the dead mask packs each row's live logical
    # indices first, in ascending order (interior -1 holes included)
    order = jnp.argsort(jnp.where(block_tables >= 0, 0, 1),
                        axis=1, stable=True).astype(jnp.int32)
    sb = order[ss, jnp.minimum(ar - cu[ss], MAXB - 1)]
    pbs = jnp.maximum(block_tables[ss, sb], 0).astype(jnp.int32)
    return ss, sb, pbs


def _ragged_kernel(bt_ref, ss_ref, sb_ref, cu_ref, q_ref, k_ref, v_ref,
                   qp_ref, pp_ref, *rest,
                   scale: float, window: Optional[int], prefix_len: int,
                   kvh: int, quant: bool, profile: Optional[str]):
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    i = pl.program_id(0)
    b = ss_ref[i]

    @pl.when(i == cu_ref[b])
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    qp = qp_ref[0]                                       # [GT]
    kp = pp_ref[0]                                       # [bs]
    owned = bt_ref[b, sb_ref[i]] >= 0

    if profile != "dma":
        @pl.when(owned & _tile_visible(qp, kp, window, prefix_len))
        def _compute():
            _flash_tile(q_ref, k_ref[0], v_ref[0], qp, kp,
                        acc_ref, m_ref, l_ref, scale=scale, window=window,
                        prefix_len=prefix_len, kvh=kvh,
                        ks=None if ks_ref is None else ks_ref[0],
                        vs=None if vs_ref is None else vs_ref[0])

    @pl.when(i == cu_ref[b + 1] - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def _ragged_dma_kernel(bt_ref, ss_ref, sb_ref, cu_ref, pbs_ref, q_ref,
                       qp_ref, k_hbm, v_hbm, pp_hbm, *rest,
                       scale: float, window: Optional[int], prefix_len: int,
                       kvh: int, quant: bool, nbuf: int,
                       profile: Optional[str]):
    """Ragged grid with an explicit ``nbuf``-deep manual DMA ring.

    k/v/pos (and int8 scale) pools stay in ANY memory space; each stream
    gets ``nbuf`` VMEM landing buffers and a DMA semaphore lane per
    buffer.  Step 0 warm-starts the first ``nbuf - 1`` tile fetches; every
    step then starts tile ``i + nbuf - 1`` and waits on its own —
    generalized double/quad buffering with the fetch horizon as a knob.
    """
    if quant:
        (ks_hbm, vs_hbm, o_ref, acc_ref, m_ref, l_ref,
         kbuf, vbuf, pbuf, ksbuf, vsbuf,
         ksem, vsem, psem, kssem, vssem) = rest
    else:
        (o_ref, acc_ref, m_ref, l_ref, kbuf, vbuf, pbuf,
         ksem, vsem, psem) = rest
        ks_hbm = vs_hbm = ksbuf = vsbuf = kssem = vssem = None
    i = pl.program_id(0)
    n = pl.num_programs(0)
    b = ss_ref[i]

    def _copies(t, slot):
        blk = pbs_ref[t]
        ops = [pltpu.make_async_copy(k_hbm.at[blk], kbuf.at[slot],
                                     ksem.at[slot]),
               pltpu.make_async_copy(v_hbm.at[blk], vbuf.at[slot],
                                     vsem.at[slot]),
               pltpu.make_async_copy(pp_hbm.at[blk], pbuf.at[slot],
                                     psem.at[slot])]
        if quant:
            ops += [pltpu.make_async_copy(ks_hbm.at[blk], ksbuf.at[slot],
                                          kssem.at[slot]),
                    pltpu.make_async_copy(vs_hbm.at[blk], vsbuf.at[slot],
                                          vssem.at[slot])]
        return ops

    @pl.when(i == cu_ref[b])
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    slot = i % nbuf
    if profile != "compute":
        # warm-up: tiles 0 .. nbuf-2 land in buffers 0 .. nbuf-2
        @pl.when(i == 0)
        def _warmup():
            for d in range(nbuf - 1):
                @pl.when(d < n)
                def _start(d=d):
                    for op in _copies(d, d):
                        op.start()

        # steady state: keep the ring full nbuf-1 tiles ahead of compute
        nxt = i + nbuf - 1

        @pl.when(nxt < n)
        def _ahead():
            for op in _copies(nxt, nxt % nbuf):
                op.start()

        for op in _copies(i, slot):
            op.wait()

    qp = qp_ref[0]                                       # [GT]
    kp = pbuf[slot]                                      # [bs]
    owned = bt_ref[b, sb_ref[i]] >= 0

    if profile != "dma":
        @pl.when(owned & _tile_visible(qp, kp, window, prefix_len))
        def _compute():
            _flash_tile(q_ref, kbuf[slot], vbuf[slot], qp, kp,
                        acc_ref, m_ref, l_ref, scale=scale, window=window,
                        prefix_len=prefix_len, kvh=kvh,
                        ks=None if ksbuf is None else ksbuf[slot],
                        vs=None if vsbuf is None else vsbuf[slot])

    @pl.when(i == cu_ref[b + 1] - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def ragged_paged_verify_attn_pallas(q: jax.Array, k: jax.Array,
                                    v: jax.Array, q_pos: jax.Array,
                                    pos: jax.Array,
                                    block_tables: jax.Array,
                                    cu_blocks: jax.Array,
                                    window: Optional[int] = None,
                                    prefix_len: int = 0,
                                    scale: Optional[float] = None,
                                    k_scale: Optional[jax.Array] = None,
                                    v_scale: Optional[jax.Array] = None,
                                    num_buffers: int = 0,
                                    vmem_limit_bytes: Optional[int] = None,
                                    profile: Optional[str] = None,
                                    interpret: bool = False) -> jax.Array:
    """Verify-step attention against the paged pool, fused, *ragged* grid.

    Same operands and masking contract as :func:`paged_verify_attn_pallas`
    plus ``cu_blocks [B + 1]`` — the host-computed cumulative grid-step
    counts (per-slot steps = ``max(live blocks, 1)``; see
    ``kernels/tuning.py host_cu_blocks``).  The grid is one flat run of
    ``cu_blocks[B]`` steps, so dead table entries cost nothing; per-slot
    blocks are visited in ascending logical order, making the output
    bit-identical to the dense kernel (and the gather reference) for every
    raggedness pattern.

    Launch knobs (autotuned per (batch, s, blocks) cell — see
    ``kernels/tuning.py`` and ``benchmarks/kernel_bench.py --autotune``):

    * ``num_buffers = 0`` — standard BlockSpec auto-pipelining;
      ``>= 2`` — explicit manual DMA with that many landing buffers per
      k/v/pos(/scale) stream (double/quad/... buffering).
    * ``vmem_limit_bytes`` — TPU compiler VMEM budget for the launch
      (ignored in interpret mode).
    * ``profile`` — ``'dma'`` skips the tile compute, ``'compute'`` skips
      the copies (manual-DMA variant only): the benchmark's
      DMA-vs-compute split.  Output is garbage in either mode.
    """
    B, T, H, hd = q.shape
    bs, KVH = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf, qpf, GT, unfold = _fold_q(q, q_pos, KVH)
    ss, sb, pbs = ragged_plan(block_tables, cu_blocks)
    total = cu_blocks.astype(jnp.int32)[block_tables.shape[0]]
    quant = k_scale is not None

    # index maps see the grid index plus every scalar-prefetch operand,
    # in positional order
    def _q_map(i, bt, ss, sb, cu):
        return (ss[i], 0, 0, 0)

    def _qp_map(i, bt, ss, sb, cu):
        return (ss[i], 0)

    kwargs = {}
    if vmem_limit_bytes is not None and not interpret:
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            vmem_limit_bytes=int(vmem_limit_bytes))

    if num_buffers >= 2:
        def _q_map_d(i, bt, ss, sb, cu, pbs):
            return (ss[i], 0, 0, 0)

        def _qp_map_d(i, bt, ss, sb, cu, pbs):
            return (ss[i], 0)

        in_specs = [
            pl.BlockSpec((1, KVH, GT, hd), _q_map_d),
            pl.BlockSpec((1, GT), _qp_map_d),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ]
        args = [block_tables, ss, sb, cu_blocks.astype(jnp.int32), pbs,
                qf, qpf, k, v, pos]
        if quant:
            in_specs += [pl.BlockSpec(memory_space=pltpu.ANY),
                         pl.BlockSpec(memory_space=pltpu.ANY)]
            args += [k_scale, v_scale]
        D = num_buffers
        scratch = [
            pltpu.VMEM((KVH, GT, hd), jnp.float32),
            pltpu.VMEM((KVH, GT), jnp.float32),
            pltpu.VMEM((KVH, GT), jnp.float32),
            pltpu.VMEM((D, bs, KVH, hd), k.dtype),
            pltpu.VMEM((D, bs, KVH, hd), v.dtype),
            pltpu.VMEM((D, bs), pos.dtype),
        ]
        if quant:
            scratch += [pltpu.VMEM((D, bs, KVH), k_scale.dtype),
                        pltpu.VMEM((D, bs, KVH), v_scale.dtype)]
        scratch += [pltpu.SemaphoreType.DMA((D,))] * (5 if quant else 3)
        kern = functools.partial(_ragged_dma_kernel, scale=scale,
                                 window=window, prefix_len=prefix_len,
                                 kvh=KVH, quant=quant, nbuf=D,
                                 profile=profile)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(total,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, KVH, GT, hd), _q_map_d),
            scratch_shapes=scratch,
        )
    else:
        def _kv_map(i, bt, ss, sb, cu):
            return (jnp.maximum(bt[ss[i], sb[i]], 0), 0, 0, 0)

        def _pos_map(i, bt, ss, sb, cu):
            return (jnp.maximum(bt[ss[i], sb[i]], 0), 0)

        def _scale_map(i, bt, ss, sb, cu):
            return (jnp.maximum(bt[ss[i], sb[i]], 0), 0, 0)

        in_specs = [
            pl.BlockSpec((1, KVH, GT, hd), _q_map),
            pl.BlockSpec((1, bs, KVH, hd), _kv_map),
            pl.BlockSpec((1, bs, KVH, hd), _kv_map),
            pl.BlockSpec((1, GT), _qp_map),
            pl.BlockSpec((1, bs), _pos_map),
        ]
        args = [block_tables, ss, sb, cu_blocks.astype(jnp.int32),
                qf, k, v, qpf, pos]
        if quant:
            in_specs += [pl.BlockSpec((1, bs, KVH), _scale_map),
                         pl.BlockSpec((1, bs, KVH), _scale_map)]
            args += [k_scale, v_scale]
        kern = functools.partial(_ragged_kernel, scale=scale, window=window,
                                 prefix_len=prefix_len, kvh=KVH,
                                 quant=quant, profile=profile)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(total,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, KVH, GT, hd), _q_map),
            scratch_shapes=[
                pltpu.VMEM((KVH, GT, hd), jnp.float32),
                pltpu.VMEM((KVH, GT), jnp.float32),
                pltpu.VMEM((KVH, GT), jnp.float32),
            ],
        )
    o = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, GT, hd), q.dtype),
        interpret=interpret,
        **kwargs,
    )(*args)
    return unfold(o)
