"""Fused paged verify-attention Pallas kernel: stream KV straight through
the block tables, never materializing a gathered logical view.

The gather path (kernels/paged.py ``gather_verify_attn``) rebuilds each
slot's contiguous ``[B, MAXB*bs, KVH, hd]`` KV view before running the
verify kernel over the copy — every paged verify step pays the pool's HBM
traffic twice (gather write + kernel read) and the transient view grows
linearly with batch size, exactly the regime where the paper's batching x
speculation synergy lives.  This kernel removes the copy: the grid is
``(batch, max_blocks_per_slot)`` and the k/v/pos BlockSpec index maps read
each tile *directly* from the shared pool through the slot's block-table
row, prefetched as a scalar (``PrefetchScalarGridSpec``) so the index maps
can consume it before the kernel body runs.

Tile-skip semantics (two layers, both ``@pl.when``):

* ``-1`` table entries (unallocated logical blocks — ragged slots, empty
  rows, mid-chunked-prefill pending slots) contribute nothing: the index
  map clips them to physical block 0 so the DMA address is always valid —
  consecutive dead entries then revisit the same block, which the Pallas
  pipeline recognizes and skips re-fetching — and the body skips the tile
  entirely, which is numerically identical to every key in it carrying
  position ``-1`` (the gather path's convention).
* live tiles whose positions are all outside the ``(q - window, q]``
  visibility range are skipped exactly like ``spec_verify_attn``'s
  flash-decode early exit.

Masking (q_pos/k_pos arithmetic, ``window``, ``prefix_len``) is the shared
position-mask contract of kernels/ref.py, evaluated against the pool's
per-row ``pos`` map — identical to gathering first, because a slot only
ever reaches its own blocks (ownership by construction of the table).

GQA: the pool keeps its ``[NB, bs, KVH, hd]`` layout (one DMA per owned
block covers every kv head — blocks are owned by exactly one slot, so each
pool row is read exactly once per step, the HBM floor), and the kernel
loops the kv heads as an unrolled static loop of 2D MXU dots.  The q block
is pre-folded to ``[B, KVH, G*Tq, hd]`` host-side (tiny) and stays VMEM-
resident across the whole block stream.

int8 KV (kv_quant): per-(row, kv-head) ``k_scale``/``v_scale`` pool arrays
ride the same block-table index maps; tiles stream from HBM at 1 B/elem and
dequantize in VMEM — the contiguous kernel's quant path, carried over.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(bt_ref, q_ref, k_ref, v_ref, qp_ref, pp_ref, *rest,
                  scale: float, window: Optional[int], prefix_len: int,
                  nb: int, kvh: int, quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    qp = qp_ref[0]                                       # [GT]
    kp = pp_ref[0]                                       # [bs]
    owned = bt_ref[b, j] >= 0

    # tile-level visibility (flash-decode early exit): any pool row in this
    # tile attendable by any query?  Dead tiles (unowned blocks) are skipped
    # outright — identical to every row reporting position -1.
    q_hi = qp.max()
    vis = (kp >= 0) & (kp <= q_hi)
    if window is not None:
        q_lo = jnp.where(qp < 0, jnp.iinfo(jnp.int32).max, qp).min()
        vis &= kp > q_lo - window
    if prefix_len:
        vis |= (kp >= 0) & (kp < prefix_len)

    @pl.when(owned & vis.any())
    def _compute():
        ok = (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])   # [GT, bs]
        if window is not None:
            ok &= kp[None, :] > qp[:, None] - window
        if prefix_len:
            ok |= (kp[None, :] >= 0) & (kp[None, :] < prefix_len)
        for h in range(kvh):                             # unrolled 2D dots
            q = q_ref[0, h].astype(jnp.float32)          # [GT, hd]
            k = k_ref[0, :, h, :].astype(jnp.float32)    # [bs, hd]
            v = v_ref[0, :, h, :].astype(jnp.float32)
            if ks_ref is not None:
                # int8 pool tiles: moved at 1 B/elem, dequantized in VMEM
                k = k * ks_ref[0, :, h].astype(jnp.float32)[:, None]
                v = v * vs_ref[0, :, h].astype(jnp.float32)[:, None]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
            s = jnp.where(ok, s, -jnp.inf)
            m_prev = m_ref[h]
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.where(ok, jnp.exp(s - m_safe[:, None]), 0.0)
            corr = jnp.where(jnp.isneginf(m_prev), 0.0,
                             jnp.exp(m_prev - m_safe))
            l_ref[h] = l_ref[h] * corr + p.sum(axis=-1)
            acc_ref[h] = acc_ref[h] * corr[:, None] + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())))
            m_ref[h] = m_new

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def paged_verify_attn_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                             q_pos: jax.Array, pos: jax.Array,
                             block_tables: jax.Array,
                             window: Optional[int] = None,
                             prefix_len: int = 0,
                             scale: Optional[float] = None,
                             k_scale: Optional[jax.Array] = None,
                             v_scale: Optional[jax.Array] = None,
                             interpret: bool = False) -> jax.Array:
    """Verify-step attention against the paged pool, fused.

    q: [B, T, H, hd] (tiny T = s+1, or a prefill chunk); k/v:
    [NB, bs, KVH, hd] pool; q_pos: [B, T]; pos: [NB, bs] (absolute position,
    -1 unwritten); block_tables: [B, MAXB] (physical block ids, -1 unused).
    Optional k_scale/v_scale: [NB, bs, KVH] per-(row, kv-head) dequant
    scales for an int8 pool.  Returns [B, T, H, hd].

    No ``[B, MAXB*bs, ...]`` logical view is ever built: tiles stream from
    the pool through the prefetched block table (module docstring).
    """
    B, T, H, hd = q.shape
    NB, bs, KVH = k.shape[0], k.shape[1], k.shape[2]
    MAXB = block_tables.shape[1]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # fold q per kv head: [B, T, H, hd] -> [B, KVH, G*T, hd] (rows (g, t),
    # matching ops._fold_gqa's ordering); q_pos repeats per group row.
    qf = (q.reshape(B, T, KVH, G, hd).transpose(0, 2, 3, 1, 4)
           .reshape(B, KVH, G * T, hd))
    qpf = jnp.broadcast_to(q_pos[:, None, :], (B, G, T)).reshape(B, G * T)
    rows = G * T
    pad = (-rows) % 8                       # TPU sublane multiple
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        qpf = jnp.pad(qpf, ((0, 0), (0, pad)), constant_values=-1)
    GT = rows + pad

    # index maps receive the prefetched block table; dead entries clip to
    # physical block 0 (valid address, body skips the tile — and repeated
    # dead entries revisit the same block, so the pipeline elides the DMA)
    def _kv_map(b, j, bt):
        return (jnp.maximum(bt[b, j], 0), 0, 0, 0)

    def _pos_map(b, j, bt):
        return (jnp.maximum(bt[b, j], 0), 0)

    def _scale_map(b, j, bt):
        return (jnp.maximum(bt[b, j], 0), 0, 0)

    quant = k_scale is not None
    in_specs = [
        pl.BlockSpec((1, KVH, GT, hd), lambda b, j, bt: (b, 0, 0, 0)),
        pl.BlockSpec((1, bs, KVH, hd), _kv_map),
        pl.BlockSpec((1, bs, KVH, hd), _kv_map),
        pl.BlockSpec((1, GT), lambda b, j, bt: (b, 0)),
        pl.BlockSpec((1, bs), _pos_map),
    ]
    args = [block_tables, qf, k, v, qpf, pos]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, KVH), _scale_map),
                     pl.BlockSpec((1, bs, KVH), _scale_map)]
        args += [k_scale, v_scale]
    kern = functools.partial(_fused_kernel, scale=scale, window=window,
                             prefix_len=prefix_len, nb=MAXB, kvh=KVH,
                             quant=quant)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, MAXB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, KVH, GT, hd),
                               lambda b, j, bt: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KVH, GT, hd), jnp.float32),
            pltpu.VMEM((KVH, GT), jnp.float32),
            pltpu.VMEM((KVH, GT), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, GT, hd), q.dtype),
        interpret=interpret,
    )(*args)
    if pad:
        o = o[:, :, :rows]
    # unfold: [B, KVH, G*T, hd] -> [B, T, H, hd]
    return (o.reshape(B, KVH, G, T, hd).transpose(0, 3, 1, 2, 4)
             .reshape(B, T, H, hd))
