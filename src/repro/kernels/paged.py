"""Block-table-aware attention gather for the paged KV pool.

The paged pool stores KV rows in fixed-size blocks shared by every slot:

    k_pool / v_pool : [num_blocks, block_size, KVH, hd]
    pos             : [num_blocks, block_size]   absolute position, -1 unwritten
    block_tables    : [B, max_blocks]            physical block ids, -1 unused

``gather_kv_blocks`` rebuilds each slot's *logical* contiguous view
[B, max_blocks * block_size, ...] from its block table — ownership is by
construction (a slot only gathers its own blocks), and entries behind a -1
table entry surface with key position -1, which the shared position mask
already treats as unattendable.  The gathered view then feeds the existing
:func:`~repro.kernels.ops.spec_verify_attn` wrapper, so the TPU Pallas
verify kernel (and its int8 path) keeps serving the hot loop unchanged; on
TPU the gather lowers to one dynamic-slice stream per block, which is the
same HBM traffic the contiguous ring paid for the identical logical length.

The win is in the *persistent* footprint: the pool holds ``num_blocks *
block_size`` KV rows total instead of ``capacity * cache_len`` worst-case
rows, so short requests stop paying for the longest one (BASS-style ragged
per-request KV, PAPERS.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ops import spec_verify_attn


def gather_kv_blocks(k: jax.Array, v: jax.Array, block_tables: jax.Array,
                     ) -> Tuple[jax.Array, jax.Array]:
    """Gather per-slot logical KV views from the shared block pool.

    k/v: [NB, bs, KVH, hd]; block_tables: [B, MAXB] (-1 = unallocated).
    Returns (k_slot, v_slot) of shape [B, MAXB * bs, KVH, hd].  Rows behind
    -1 table entries contain arbitrary pool data — callers must mask them
    via :func:`gather_key_positions` (which reports their position as -1).
    """
    B, MAXB = block_tables.shape
    bs = k.shape[1]
    safe = jnp.where(block_tables < 0, 0, block_tables)
    kg = k[safe].reshape(B, MAXB * bs, *k.shape[2:])
    vg = v[safe].reshape(B, MAXB * bs, *v.shape[2:])
    return kg, vg


def gather_key_positions(pos: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Per-slot logical key positions [B, MAXB * bs]; -1 where the table has
    no block (or the pool row is unwritten), i.e. never attendable."""
    B, MAXB = block_tables.shape
    bs = pos.shape[1]
    safe = jnp.where(block_tables < 0, 0, block_tables)
    kp = jnp.where((block_tables < 0)[:, :, None], -1, pos[safe])
    return kp.reshape(B, MAXB * bs)


def paged_verify_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, pos: jax.Array,
                      block_tables: jax.Array,
                      window: Optional[int] = None, prefix_len: int = 0,
                      scale: Optional[float] = None,
                      use_pallas: Optional[bool] = None) -> jax.Array:
    """Verify-step attention against the paged pool.

    q: [B, T, H, hd]; k/v: [NB, bs, KVH, hd]; q_pos: [B, T];
    pos: [NB, bs]; block_tables: [B, MAXB].  Returns [B, T, H, hd].

    Gather + the existing verify kernel: identical masking semantics to the
    contiguous ring at logical length MAXB * bs.
    """
    kg, vg = gather_kv_blocks(k, v, block_tables)
    kpos = gather_key_positions(pos, block_tables)
    return spec_verify_attn(q, kg, vg, q_pos, kpos, window=window,
                            prefix_len=prefix_len, scale=scale,
                            use_pallas=use_pallas)
