"""Block-table attention for the paged KV pool: fused streaming kernel on
TPU, materialized gather as the reference / CPU fallback.

The paged pool stores KV rows in fixed-size blocks shared by every slot:

    k_pool / v_pool : [num_blocks, block_size, KVH, hd]
    pos             : [num_blocks, block_size]   absolute position, -1 unwritten
    block_tables    : [B, max_blocks]            physical block ids, -1 unused

Two execution paths with identical masking semantics:

* **fused** (:mod:`repro.kernels.paged_verify_attn`, TPU native or
  ``interpret=True``): the Pallas kernel's BlockSpec index maps read
  k/v/pos tiles straight from the pool through the scalar-prefetched block
  table — no ``[B, MAXB*bs, ...]`` logical view ever exists, the pool's
  HBM rows move exactly once per step, and the transient footprint no
  longer grows with batch size.  ``-1`` table entries skip their tile in
  the kernel (``@pl.when``), which is numerically the same as gathering a
  key-position of ``-1``.
* **gather** (:func:`gather_verify_attn`, the ``use_pallas=False``
  reference and non-TPU fallback): rebuild each slot's logical contiguous
  view with one XLA gather, then run the shared
  :func:`~repro.kernels.ops.spec_verify_attn` wrapper over the copy.
  Ownership is by construction (a slot only gathers its own blocks), and
  rows behind a ``-1`` table entry surface with key position ``-1``, which
  the shared position mask treats as unattendable.

Either way the *persistent* footprint win of paging stands: the pool holds
``num_blocks * block_size`` KV rows total instead of ``capacity *
cache_len`` worst-case rows, so short requests stop paying for the longest
one (BASS-style ragged per-request KV, PAPERS.md).  The fused path
additionally removes the gather's transient double-buffering of the hot
verify step — the largest single-lever perf win on the serving path.

int8 pools (kv_quant) pass per-(row, kv-head) ``k_scale``/``v_scale``
``[NB, bs, KVH]``; both paths dequantize with them (the fused kernel in
VMEM after a 1 B/elem stream, the gather path before the shared wrapper).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ops import kernel_mode, spec_verify_attn
from repro.kernels.paged_verify_attn import (paged_verify_attn_pallas,
                                             ragged_paged_verify_attn_pallas)
from repro.kernels.tuning import RaggedConfig, lookup_config


def gather_kv_blocks(k: jax.Array, v: jax.Array, block_tables: jax.Array,
                     ) -> Tuple[jax.Array, jax.Array]:
    """Gather per-slot logical KV views from the shared block pool.

    k/v: [NB, bs, KVH, hd]; block_tables: [B, MAXB] (-1 = unallocated).
    Returns (k_slot, v_slot) of shape [B, MAXB * bs, KVH, hd].  Rows behind
    -1 table entries contain arbitrary pool data — callers must mask them
    via :func:`gather_key_positions` (which reports their position as -1).

    Fast path: with a one-block-per-slot table (MAXB == 1 — short-prompt
    traces sized to a single block) the gather+reshape collapses to a
    direct row index, keeping this reference path honest in the
    microbenchmark's smallest shapes.
    """
    B, MAXB = block_tables.shape
    bs = k.shape[1]
    safe = jnp.where(block_tables < 0, 0, block_tables)
    if MAXB == 1:
        return k[safe[:, 0]], v[safe[:, 0]]
    kg = k[safe].reshape(B, MAXB * bs, *k.shape[2:])
    vg = v[safe].reshape(B, MAXB * bs, *v.shape[2:])
    return kg, vg


def gather_key_positions(pos: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Per-slot logical key positions [B, MAXB * bs]; -1 where the table has
    no block (or the pool row is unwritten), i.e. never attendable."""
    B, MAXB = block_tables.shape
    bs = pos.shape[1]
    safe = jnp.where(block_tables < 0, 0, block_tables)
    if MAXB == 1:
        return jnp.where((block_tables < 0), -1, pos[safe[:, 0]])
    kp = jnp.where((block_tables < 0)[:, :, None], -1, pos[safe])
    return kp.reshape(B, MAXB * bs)


def gather_scales(scale: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Gather int8 dequant scales [NB, bs, KVH] -> per-slot [B, MAXB*bs, KVH].

    Rows behind -1 table entries carry arbitrary pool scales; they are
    harmless because their key positions gather as -1 (never attendable).
    """
    B, MAXB = block_tables.shape
    bs = scale.shape[1]
    safe = jnp.where(block_tables < 0, 0, block_tables)
    if MAXB == 1:
        return scale[safe[:, 0]]
    return scale[safe].reshape(B, MAXB * bs, scale.shape[2])


def gather_verify_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                       q_pos: jax.Array, pos: jax.Array,
                       block_tables: jax.Array,
                       window: Optional[int] = None, prefix_len: int = 0,
                       scale: Optional[float] = None,
                       k_scale: Optional[jax.Array] = None,
                       v_scale: Optional[jax.Array] = None,
                       use_pallas: Optional[bool] = None,
                       block_k: int = 512) -> jax.Array:
    """Gather + the shared verify kernel: the paged reference path.

    Materializes each slot's [MAXB * bs] logical view, then runs
    :func:`~repro.kernels.ops.spec_verify_attn` over the copy — identical
    masking semantics to the contiguous ring at logical length MAXB * bs.
    ``use_pallas`` is forwarded to the shared wrapper (the microbenchmark
    times gather+Pallas-verify against the fused kernel with it).
    """
    kg, vg = gather_kv_blocks(k, v, block_tables)
    kpos = gather_key_positions(pos, block_tables)
    ks = vs = None
    if k_scale is not None:
        ks = gather_scales(k_scale, block_tables)
        vs = gather_scales(v_scale, block_tables)
    return spec_verify_attn(q, kg, vg, q_pos, kpos, window=window,
                            prefix_len=prefix_len, scale=scale,
                            k_scale=ks, v_scale=vs, use_pallas=use_pallas,
                            block_k=block_k)


def paged_verify_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, pos: jax.Array,
                      block_tables: jax.Array,
                      window: Optional[int] = None, prefix_len: int = 0,
                      scale: Optional[float] = None,
                      k_scale: Optional[jax.Array] = None,
                      v_scale: Optional[jax.Array] = None,
                      use_pallas: Optional[bool] = None,
                      cu_blocks: Optional[jax.Array] = None,
                      config: Optional[RaggedConfig] = None) -> jax.Array:
    """Verify-step attention against the paged pool.

    q: [B, T, H, hd]; k/v: [NB, bs, KVH, hd]; q_pos: [B, T];
    pos: [NB, bs]; block_tables: [B, MAXB].  Optional k_scale/v_scale
    [NB, bs, KVH] for int8 pools.  Returns [B, T, H, hd].

    Dispatch (:func:`~repro.kernels.ops.kernel_mode` policy): a fused
    streaming kernel natively on TPU (or interpreted when forced with
    ``use_pallas=True`` off-TPU — tests and the microbenchmark), the
    gather path otherwise.  ``use_pallas`` here selects *which paged path*
    runs; the gather path's inner verify kernel keeps its own auto policy
    (Pallas on TPU, reference on CPU), so forcing the gather — e.g. the
    sharded-pool pin — never silently downgrades a TPU run to the pure-jnp
    attention.  Both paths are numerically parity-checked in
    tests/test_paged_fused_kernel.py.

    ``cu_blocks [B + 1]`` (host-computed cumulative grid-step counts,
    ``kernels/tuning.py host_cu_blocks``) upgrades the fused path to the
    **ragged** kernel: grid steps = sum of live blocks instead of
    ``B * MAXB``, launch knobs resolved per ``(B, T, MAXB)`` cell from the
    autotune cache (``config`` overrides the lookup — tests and the
    benchmark pin exact knobs with it).  Without ``cu_blocks`` the dense
    fused kernel runs; the gather reference ignores both (its semantics
    are already length-exact).  All three agree bit-for-bit per row
    across every raggedness pattern (tests/test_ragged_paged_attn.py).
    """
    m = kernel_mode(use_pallas)
    if m == "ref":
        return gather_verify_attn(q, k, v, q_pos, pos, block_tables,
                                  window=window, prefix_len=prefix_len,
                                  scale=scale, k_scale=k_scale,
                                  v_scale=v_scale, use_pallas=None)
    if cu_blocks is not None:
        if config is None:
            # static shapes -> one cache lookup per trace, never per step
            config = lookup_config(q.shape[0], q.shape[1],
                                   block_tables.shape[1])
        return ragged_paged_verify_attn_pallas(
            q, k, v, q_pos, pos, block_tables, cu_blocks,
            window=window, prefix_len=prefix_len, scale=scale,
            k_scale=k_scale, v_scale=v_scale,
            num_buffers=config.num_buffers,
            vmem_limit_bytes=config.vmem_limit_bytes,
            interpret=(m == "interpret"))
    return paged_verify_attn_pallas(q, k, v, q_pos, pos, block_tables,
                                    window=window, prefix_len=prefix_len,
                                    scale=scale, k_scale=k_scale,
                                    v_scale=v_scale,
                                    interpret=(m == "interpret"))
