"""Pallas TPU kernels for the speculation hot spots, with jnp oracles.

Each kernel module pairs a Pallas body with a pure-jnp reference in
``ref.py`` that the interpret-mode parity tests (``citier kernels``)
check against: ``spec_verify_attn`` (the batched s-token verify
attention), ``paged_verify_attn`` (the fused variant that streams KV
through the scalar-prefetched block table — no materialized gather),
``flash_attn``, ``rmsnorm``, and ``ssd_chunk``.  ``ops.py`` is the
dispatch layer (``kernel_mode``) that picks kernel vs reference.

BlockSpec index maps in this package are pure block-address arithmetic
over grid indices and scalar-prefetch refs — enforced by repro-lint's
``pallas-index-map`` rule.
"""
