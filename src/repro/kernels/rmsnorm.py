"""RMSNorm Pallas kernel: row-tiled, fp32 reduction in VMEM.

Rows are tiled in blocks of ``block_rows``; the full feature dim stays
resident in VMEM (d_model <= 7168 * 4 B = 28 KiB per row, well under the
~16 MiB v5e VMEM at our block sizes).  Feature dims should be multiples of
128 for lane alignment (all assigned d_models are).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                   # [block_rows, d]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = (x * jax.lax.rsqrt(var + eps)).astype(o_ref.dtype) * g_ref[...]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
                   block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: [..., d]; gamma: [d].  Returns same shape/dtype as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    n = 1
    for s in orig_shape[:-1]:
        n *= s
    x2 = x.reshape(n, d)
    block_rows = min(block_rows, n)
    while n % block_rows:
        block_rows -= 1
    grid = (n // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x2, gamma)
    return out.reshape(orig_shape)
