"""Ambient mesh context: lets model code place sharding constraints without
threading the mesh through every call.  When no mesh is active (CPU tests),
constraints are no-ops.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: list = []


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    _ACTIVE.append(mesh)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _ACTIVE.pop()


def current_mesh() -> Optional[Mesh]:
    return _ACTIVE[-1] if _ACTIVE else None


def shard(x: jax.Array, *axes) -> jax.Array:
    """Apply a sharding constraint if a mesh is active; drop axis names the
    mesh does not have (lets the same model run single-pod and multi-pod)."""
    mesh = current_mesh()
    if mesh is None:
        return x

    def keep(a):
        if a is None:
            return None
        if isinstance(a, (tuple, list)):
            kept = tuple(x_ for x_ in a if x_ in mesh.axis_names)
            return kept if kept else None
        return a if a in mesh.axis_names else None

    spec = P(*(keep(a) for a in axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(spec: P, mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    return None if mesh is None else NamedSharding(mesh, spec)
