"""Production meshes (TPU v5e).  Single pod: 256 chips as (data=16,
model=16); two pods: (pod=2, data=16, model=16) with the pod axis as an
outer data-parallel dimension (cross-pod traffic = gradient all-reduce only).

Functions, not module constants — importing this module never touches jax
device state (smoke tests must keep seeing 1 CPU device; only
launch/dryrun.py forces 512 virtual devices, in its first two lines).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small mesh for CI-scale pjit tests (8 virtual devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(n_data: Optional[int] = None):
    """Data-only mesh for sharded continuous serving (replicated params,
    slot-pool capacity axis sharded over ``data``).  Defaults to every
    visible device.  On CPU, force multiple host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax is
    imported (see tests/test_sharded_serving.py)."""
    n = n_data if n_data is not None else len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch-sharding axes of a mesh ('pod' folds into data-parallel)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
