import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):   # CI-scale override (tests only)
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and extract the roofline ingredients.

MUST be run as its own process (the two lines above force 512 virtual CPU
devices *before any jax import*; smoke tests and benchmarks must keep seeing
one device, so never import this module from them).

Per (arch, shape, mesh) it records into results/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis(): per-device argument/output/temp bytes (proves fit);
  * cost_analysis(): raw HLO flops/bytes (undercounts scanned layers; kept
    for the record);
  * collective bytes: parsed from the compiled HLO, depth-extrapolated
    (collectives live at layer granularity, so out + L*per_layer is exact);
  * analytic step cost (launch/costs.py) and MODEL_FLOPS = 6*N*D;
  * the roofline terms vs TPU v5e peaks (197e12 bf16 FLOP/s, 819e9 B/s HBM,
    50e9 B/s ICI per link).

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape decode_32k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.configs import registry as R
from repro.configs.base import SHAPES, param_count
from repro.launch import costs as C
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_plan
from repro.runtime.meshctx import use_mesh

V5E = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+((?:\()?[a-z0-9:\[\]{},\s]*?(?:\))?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across jax versions: newer jax
    returns one dict, older returns a one-element list of per-device dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _shape_bytes(shape_str: str) -> float:
    """Sum bytes over every tensor in an HLO result-shape string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-kind collective result-bytes in one HLO module (flat count: each
    while-body op counted once; callers depth-extrapolate)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:        # start/done pairs: count the start only
            continue
        kind = m.group(2).lower()
        out[kind] = out.get(kind, 0.0) + _shape_bytes(m.group(1))
    return out


# ---------------------------------------------------------------------------
# depth variants for collective extrapolation


def _depth_knobs(arch: str, kind: str) -> Dict[str, int]:
    """Full values of each depth knob for this (arch, kind)."""
    cfg = R.get_config(arch)
    if cfg.family in ("encdec", "audio"):
        if kind == "prefill":
            return {"enc": cfg.enc_layers, "dec": cfg.n_layers}
        if kind == "train":
            return {"enc": cfg.enc_layers, "dec": cfg.n_layers}
        return {"dec": cfg.n_layers, "draft": 4}
    if cfg.rglru is not None:
        blocks = cfg.n_layers / len(cfg.rglru.pattern)   # fractional tail ok
        k = {"blocks": blocks}
    else:
        k = {"layers": cfg.n_layers}
    if kind == "spec_decode":
        k["draft"] = 4
    return k


def _cfg_with_depth(arch: str, knob_vals: Dict[str, float]):
    """(target_cfg_override, draft_layers) with the given knob values."""
    cfg = R.get_config(arch)
    if cfg.family in ("encdec", "audio"):
        t = cfg.with_(enc_layers=int(knob_vals.get("enc", 1)),
                      n_layers=int(knob_vals.get("dec", 1)))
    elif cfg.rglru is not None:
        t = cfg.with_(n_layers=int(knob_vals["blocks"]) * len(cfg.rglru.pattern))
    else:
        t = cfg.with_(n_layers=int(knob_vals["layers"]))
    return t, int(knob_vals.get("draft", 1))


def _compile_variant(arch: str, shape_name: str, mesh, knob_vals, plan_kw):
    """Compile a small-depth variant and return its collective byte dict."""
    import repro.launch.specs as S
    tcfg, dlayers = _cfg_with_depth(arch, knob_vals)
    orig_cfg, orig_draft = R.get_config, R.get_draft_config
    R.get_config = lambda a, _t=tcfg, _o=orig_cfg: _t if R._norm(a) == R._norm(arch) else _o(a)
    base_d = orig_draft(arch)
    R.get_draft_config = (lambda a, _d=base_d.with_(n_layers=dlayers), _o=orig_draft:
                          _d if R._norm(a) == R._norm(arch) else _o(a))
    try:
        plan = build_plan(arch, shape_name, mesh, **plan_kw)
        with use_mesh(mesh):
            compiled = plan.lower().compile()
        return collective_bytes(compiled.as_text())
    finally:
        R.get_config, R.get_draft_config = orig_cfg, orig_draft


def extrapolated_collectives(arch: str, shape_name: str, mesh, plan_kw,
                             ) -> Tuple[Dict[str, float], Dict[str, Any]]:
    """Solve collective_bytes = base + sum_k knob_k * per_knob_k from
    (n_knobs + 1) small-depth compiles, then evaluate at the full depths."""
    kind = ("train" if SHAPES[shape_name].kind == "train"
            else "prefill" if SHAPES[shape_name].kind == "prefill"
            else "spec_decode")
    knobs = _depth_knobs(arch, kind)
    names = list(knobs)
    base_vals = {k: 1 for k in names}
    measures = [("base", dict(base_vals))]
    for k in names:
        v = dict(base_vals)
        v[k] = 2
        measures.append((k, v))
    colls = {}
    for tag, vals in measures:
        colls[tag] = _compile_variant(arch, shape_name, mesh, vals, plan_kw)
    kinds = sorted({k for c in colls.values() for k in c})
    total: Dict[str, float] = {}
    per_knob_log: Dict[str, Any] = {}
    for ck in kinds:
        base = colls["base"].get(ck, 0.0)
        t = base
        for k in names:
            slope = colls[k].get(ck, 0.0) - base
            t += slope * (knobs[k] - 1)
            per_knob_log.setdefault(k, {})[ck] = slope
        total[ck] = max(t, 0.0)
    return total, {"knobs": knobs, "flat_base": colls["base"],
                   "per_knob": per_knob_log}


# ---------------------------------------------------------------------------
# one dry-run cell


def run_one(arch: str, shape_name: str, mesh_name: str,
            plan_kw: Optional[Dict[str, Any]] = None,
            skip_collectives: bool = False) -> Dict[str, Any]:
    plan_kw = plan_kw or {}
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = int(np.prod(list(mesh.shape.values())))
    shape = SHAPES[shape_name]
    t0 = time.time()
    plan = build_plan(arch, shape_name, mesh, **plan_kw)
    with use_mesh(mesh):
        lowered = plan.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": plan.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_total": (ma.argument_size_in_bytes
                                 + ma.temp_size_in_bytes),
        },
        "hlo_cost_analysis": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
            "note": "scan bodies counted once by XLA; see analytic block",
        },
    }

    # collectives (depth-extrapolated)
    if not skip_collectives:
        coll, coll_log = extrapolated_collectives(arch, shape_name, mesh, plan_kw)
        rec["collectives"] = coll
        rec["collectives_debug"] = coll_log
        coll_total = sum(coll.values())
    else:
        flat = collective_bytes(compiled.as_text())
        rec["collectives"] = flat
        rec["collectives_note"] = "flat (no depth extrapolation)"
        coll_total = sum(flat.values())

    # analytic cost + roofline
    tcfg = plan.meta["cfg"]
    dcfg = plan.meta.get("draft_cfg")
    Lt = plan.meta.get("cache_len", shape.seq_len)
    from repro.launch.specs import _cache_len
    Ld = _cache_len(dcfg, shape.seq_len) if dcfg is not None else 0
    cost = C.step_cost(tcfg, dcfg, shape, plan.kind, s=plan.meta.get("s", 4),
                       cache_len_t=Lt, cache_len_d=Ld)
    n_tok = (shape.global_batch * shape.seq_len if plan.kind == "train"
             else shape.global_batch * shape.seq_len if plan.kind == "prefill"
             else shape.global_batch * (plan.meta.get("s", 4) + 1))
    # MODEL_FLOPS: 6 N D for training (fwd+bwd), 2 N D for inference steps
    mf = C.model_flops_6nd(tcfg, n_tok)
    if plan.kind != "train":
        mf /= 3.0
    compute_s = cost.flops / (chips * V5E["peak_flops"])
    memory_s = cost.hbm_bytes / (chips * V5E["hbm_bw"])
    coll_s = coll_total / (chips * V5E["ici_bw"])
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", coll_s)), key=lambda kv: kv[1])
    rec["analytic"] = {
        "flops": cost.flops, "hbm_bytes": cost.hbm_bytes,
        "collective_bytes": coll_total,
        "detail": cost.detail,
        "model_flops_6nd": mf,
        "useful_compute_ratio": mf / cost.flops if cost.flops else 0.0,
        "tokens_per_step": n_tok,
    }
    rec["roofline"] = {
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dom[0], "step_s_lower_bound": dom[1],
        "params": param_count(tcfg),
        "params_active": param_count(tcfg, active_only=True),
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-collectives", action="store_true",
                    help="flat HLO collective count only (faster)")
    ap.add_argument("--spec-s", type=int, default=None)
    args = ap.parse_args(argv)

    archs = R.ASSIGNED if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                tag = f"{arch}__{shape}__{mesh_name}"
                plan_kw = {}
                if args.spec_s is not None and SHAPES[shape].kind == "decode":
                    plan_kw["s"] = args.spec_s
                try:
                    rec = run_one(arch, shape, mesh_name, plan_kw,
                                  skip_collectives=args.skip_collectives)
                    path = os.path.join(args.out, tag + ".json")
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1, default=float)
                    r = rec["roofline"]
                    print(f"[OK] {tag}: dominant={r['dominant']} "
                          f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
                          f"coll={r['collective_s']:.2e}s "
                          f"mem/dev={rec['memory']['per_device_total']/2**30:.2f}GiB "
                          f"compile={rec['compile_s']:.0f}s", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e)
        sys.exit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
