"""Training launcher: data pipeline -> sharded train loop -> checkpoint.

CPU-runnable with --smoke (reduced config, handful of steps); the production
path jits through launch/specs with the mesh's shardings (same step code).

  python -m repro.launch.train --arch qwen3-8b --smoke --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.training import (AdamWConfig, DataConfig, batch_at, init_adamw,
                            make_train_step, save)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = R.get_smoke_config(args.arch) if args.smoke else R.get_config(args.arch)
    model = R.build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    opt_state = init_adamw(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.2f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")

    extra = ()
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq,
                    seed=args.seed)
    step_fn = jax.jit(make_train_step(model, cfg, opt_cfg, extra_keys=extra),
                      donate_argnums=(0, 1))

    def with_modality(batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family in ("encdec", "audio"):
            b["src_embeds"] = jnp.zeros((args.batch, 8, cfg.d_model))
        elif cfg.family == "vlm":
            b["prefix_embeds"] = jnp.zeros((args.batch, cfg.prefix_len, cfg.d_model))
        return b

    if cfg.family in ("encdec", "audio"):
        extra = ("src_embeds",)
        step_fn = jax.jit(make_train_step(model, cfg, opt_cfg, extra_keys=extra),
                          donate_argnums=(0, 1))
    elif cfg.family == "vlm":
        extra = ("prefix_embeds",)
        step_fn = jax.jit(make_train_step(model, cfg, opt_cfg, extra_keys=extra),
                          donate_argnums=(0, 1))

    t0 = time.time()
    losses = []
    for i in range(args.steps):
        params, opt_state, m = step_fn(params, opt_state,
                                       with_modality(batch_at(dc, i)))
        losses.append(float(m["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:4d}  loss {losses[-1]:.4f}  ce {float(m['ce']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f}  ({dt:.1f}s)")
    assert losses[-1] < losses[0], "loss did not decrease"
    if args.ckpt:
        save(args.ckpt, params, opt_state, step=args.steps)
        print("checkpoint ->", args.ckpt)
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
