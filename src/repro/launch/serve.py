"""Serving launcher: profile -> LUT -> adaptive serving loop.

CPU-runnable end to end with the smoke-scale models (the paper's pipeline at
laptop scale); on a TPU mesh the same flow runs the full configs — the mesh
context and sharded params drop in through launch/specs.

  python -m repro.launch.serve --arch yi-9b --smoke --requests 64
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import registry as R
from repro.core.adaptive import (AdaptiveController, fixed_controller,
                                 measure_acceptance, profile_engine)
from repro.core.spec_decode import SpecDecodeEngine
from repro.serving.metrics import summarize, timeline_groups
from repro.serving.server import EngineBackend, serve
from repro.serving.traffic import synthetic_prompts, uniform_traffic


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-6.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU scale)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--interval", type=float, default=0.5)
    ap.add_argument("--cv", type=float, default=1.0)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--profile-bs", default="1,2,4,8")
    ap.add_argument("--s-max", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    tcfg = R.get_smoke_config(args.arch) if args.smoke else R.get_config(args.arch)
    dcfg = R.get_draft_config(args.arch)
    if args.smoke:
        dcfg = dataclasses.replace(
            dcfg, n_layers=2, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
            attn=dataclasses.replace(dcfg.attn, n_heads=2, n_kv_heads=2,
                                     head_dim=32))
    engine = SpecDecodeEngine(tcfg, dcfg, max_new=args.max_new)
    key = jax.random.PRNGKey(args.seed)
    tparams = engine.target.init(key)
    dparams = engine.draft.init(jax.random.fold_in(key, 1))

    # ---- profiling stage (paper §4) ----
    rng = np.random.default_rng(args.seed + 1)
    sample = synthetic_prompts(8, tcfg.vocab_size, rng, 8, 16)
    P = max(len(p) for p in sample)
    toks = np.zeros((len(sample), P), np.int32)
    lens = np.zeros((len(sample),), np.int32)
    for i, p in enumerate(sample):
        toks[i, :len(p)] = p
        lens[i] = len(p)
    bs = [int(x) for x in args.profile_bs.split(",")]
    t0 = time.time()
    lut = profile_engine(engine, tparams, dparams, toks, lens,
                         batch_sizes=bs, s_values=range(0, args.s_max + 1),
                         gen_tokens=16, cache_len=args.cache_len)
    print(f"profiling took {time.time()-t0:.1f}s; LUT: {lut.table} "
          f"(monotone={lut.is_monotone()})")

    # ---- execution stage ----
    reqs = uniform_traffic(args.requests, args.interval, args.cv,
                           tcfg.vocab_size, seed=args.seed + 2,
                           max_new=args.max_new)
    backend = EngineBackend(engine, tparams, dparams, cache_len=args.cache_len)
    res = serve([dataclasses.replace(r) for r in reqs],
                backend, AdaptiveController(lut=lut), max_batch=args.max_batch)
    print("adaptive:", summarize(res))
    res0 = serve([dataclasses.replace(r) for r in reqs],
                 backend, fixed_controller(0), max_batch=args.max_batch)
    print("no-spec :", summarize(res0))
    print(f"speedup: {res0.mean_latency / res.mean_latency:.2f}x")


if __name__ == "__main__":
    main()
