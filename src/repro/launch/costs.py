"""Analytic per-step FLOP / HBM-byte model for every (arch x shape) pair.

Why analytic: XLA's ``compiled.cost_analysis()`` counts each ``while`` body
ONCE, and our models scan over layers (and flash attention scans over block
pairs), so raw HLO numbers undercount by ~n_layers (validated empirically in
EXPERIMENTS.md §Dry-run: a scan-of-4 matmuls reports 1x the body flops).
Matmul-dominated cost is exact arithmetic from the config, so the roofline's
compute/memory terms are derived here; the dry-run's cost_analysis and
depth-variant deltas cross-check these numbers, and collective bytes come
from the compiled HLO (launch/dryrun.py) where depth extrapolation IS exact.

Conventions:
  * FLOPs: 2 * m * n * k per matmul; elementwise ops are ignored (<1%).
  * train = fwd + 2x bwd (+1x fwd recompute under remat) on matmul flops.
  * HBM bytes per step: parameter bytes streamed once per step (the decode
    regime that makes speculation profitable), plus KV-cache traffic, plus
    the activation working set where it matters (train).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs.base import InputShape, ModelConfig, pad_vocab, param_count


@dataclass(frozen=True)
class StepCost:
    flops: float            # total FLOPs of one step (whole batch, all chips)
    hbm_bytes: float        # total HBM traffic of one step
    detail: Dict[str, float]

    def __add__(self, o: "StepCost") -> "StepCost":
        d = dict(self.detail)
        for k, v in o.detail.items():
            d[k] = d.get(k, 0.0) + v
        return StepCost(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes, d)

    def scale(self, f: float) -> "StepCost":
        return StepCost(self.flops * f, self.hbm_bytes * f,
                        {k: v * f for k, v in self.detail.items()})


def _bytes_per(dtype: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}.get(dtype, 2)


# ---------------------------------------------------------------------------
# per-layer matmul flops for n tokens


def _attn_proj_flops(cfg: ModelConfig, n: float) -> float:
    a, d = cfg.attn, cfg.d_model
    if a.kind == "mla":
        rd, lr, vd = a.rope_head_dim, a.kv_lora_rank, a.vdim
        q = (2 * n * (d * a.q_lora_rank + a.q_lora_rank * a.n_heads * (a.head_dim + rd))
             if a.q_lora_rank else 2 * n * d * a.n_heads * (a.head_dim + rd))
        kv = 2 * n * d * (lr + rd)
        up = 2 * n * lr * a.n_heads * (a.head_dim + vd)    # w_uk + w_uv
        o = 2 * n * a.n_heads * vd * d
        return q + kv + up + o
    qkv = 2 * n * d * a.head_dim * (a.n_heads + 2 * a.n_kv_heads)
    o = 2 * n * a.n_heads * a.head_dim * d
    return qkv + o


def _attn_score_flops(cfg: ModelConfig, n: float, kv_len: float) -> float:
    """Score + weighted-value matmuls: 2 matmuls x 2 flops = 4 n K H hd."""
    a = cfg.attn
    hd = a.head_dim + (a.rope_head_dim if a.kind == "mla" else 0)
    vd = a.vdim if a.kind == "mla" else a.head_dim
    return 2 * n * kv_len * a.n_heads * (hd + vd)


def _mlp_flops(cfg: ModelConfig, n: float) -> Tuple[float, float]:
    """Returns (expert/dense mlp flops, moe dispatch-overhead flops)."""
    d = cfg.d_model
    if cfg.moe is None:
        return 6 * n * d * cfg.d_ff, 0.0
    m = cfg.moe
    expert = 6 * n * d * m.d_ff_expert * m.top_k
    shared = 6 * n * d * (m.n_shared * (m.d_ff_shared or m.d_ff_expert))
    router = 2 * n * d * m.n_experts
    if m.dispatch == "gather":
        # stable-sort ragged dispatch: data movement only (validated: 3.7x
        # compiled-flop drop on a synthetic layer vs the einsum path)
        return expert + shared + router, 0.0
    # GShard one-hot dispatch/combine einsums: E*C ~= tg*k*cf slots per group
    # -> 4 n (tg k cf) d.  Real compiled cost (hillclimb target, DESIGN §8.4).
    tg = 1024.0
    slots = tg * m.top_k * m.capacity_factor
    dispatch = 4 * n * slots * d
    return expert + shared + router, dispatch


def _ssm_flops(cfg: ModelConfig, n: float, decode: bool) -> float:
    s = cfg.ssm
    d = cfg.d_model
    din = s.expand * d
    bc = s.n_groups * s.d_state
    H = din // s.head_dim
    P, N = s.head_dim, s.d_state
    proj = 2 * n * d * (2 * din + 2 * bc + H) + 2 * n * din * d
    conv = 2 * n * s.d_conv * (din + 2 * bc)
    if decode:
        mix = n * H * 5 * P * N                       # sequential state updates
    else:
        Q = min(s.chunk, n)
        mix = n * H * (2 * Q * (N + P) + 4 * P * N)   # chunked SSD
    return proj + conv + mix


def _rglru_rec_flops(cfg: ModelConfig, n: float) -> float:
    d, w = cfg.d_model, (cfg.rglru.lru_width or cfg.d_model)
    return 2 * n * d * w * 2 + 2 * n * w * d + 12 * n * w


def layer_flops(cfg: ModelConfig, n: float, kv_len: float,
                decode: bool = False, full_pairs: bool = False,
                ) -> Dict[str, float]:
    """FLOPs of one *decoder* layer over n tokens with kv_len visible keys.

    ``full_pairs=True`` models the training attention path
    (flash_attention_train), which computes every (q, k) score and masks —
    window/causality then do NOT reduce score flops (documented trade-off;
    the TPU Pallas kernel and the inference tri variant do exploit them).
    """
    out: Dict[str, float] = {}
    if cfg.family == "ssm":
        out["ssm"] = _ssm_flops(cfg, n, decode)
        return out
    if cfg.rglru is not None:
        # per-layer average over the (rec, rec, attn) pattern
        pat = cfg.rglru.pattern
        n_rec = sum(p == "rec" for p in pat) / len(pat)
        n_att = 1.0 - n_rec
        w_kv = kv_len if full_pairs else min(kv_len, cfg.rglru.window)
        out["rec"] = n_rec * _rglru_rec_flops(cfg, n)
        out["attn_proj"] = n_att * _attn_proj_flops(cfg, n)
        out["attn_score"] = n_att * _attn_score_flops(cfg, n, w_kv)
        mlp, _ = _mlp_flops(cfg, n)
        out["mlp"] = mlp
        return out
    a = cfg.attn
    kv = kv_len if full_pairs else (min(kv_len, a.window) if a.window else kv_len)
    out["attn_proj"] = _attn_proj_flops(cfg, n)
    out["attn_score"] = _attn_score_flops(cfg, n, kv)
    mlp, dispatch = _mlp_flops(cfg, n)
    out["mlp"] = mlp
    if dispatch:
        out["moe_dispatch"] = dispatch
    return out


def _sum(d: Dict[str, float]) -> float:
    return float(sum(d.values()))


# ---------------------------------------------------------------------------
# cache sizing (bytes)


def kv_cache_bytes(cfg: ModelConfig, batch: int, cache_len: int,
                   dtype_bytes: int = 2) -> float:
    a = cfg.attn
    if cfg.family == "ssm":
        s = cfg.ssm
        din = s.expand * cfg.d_model
        H = din // s.head_dim
        state = batch * H * s.head_dim * s.d_state * 4           # fp32 state
        conv = batch * (s.d_conv - 1) * (din + 2 * s.n_groups * s.d_state) * dtype_bytes
        return cfg.n_layers * (state + conv)
    if a is None:
        return 0.0
    if a.kind == "mla":
        per_row_bytes = (a.kv_lora_rank + a.rope_head_dim) * dtype_bytes
    elif cfg.kv_quant:
        # int8 payload + one scale per (row, kv-head) for k and v
        per_row_bytes = 2 * a.n_kv_heads * (a.head_dim * 1 + dtype_bytes)
    else:
        per_row_bytes = 2 * a.n_kv_heads * a.head_dim * dtype_bytes
    per_layer = batch * cache_len * per_row_bytes
    if cfg.rglru is not None:
        pat = cfg.rglru.pattern
        n_att = sum(p == "attn" for p in pat) / len(pat)
        w = cfg.rglru.lru_width or cfg.d_model
        rec_state = batch * w * 4 + batch * (cfg.rglru.d_conv - 1) * w * dtype_bytes
        win = min(cache_len, cfg.rglru.window)
        att_rows = batch * win * per_row_bytes
        return cfg.n_layers * ((1 - n_att) * rec_state + n_att * att_rows)
    layers = cfg.n_layers
    total = layers * per_layer
    if cfg.cross_attn:  # encdec: cross-KV for the (fixed) encoder output
        total += cfg.n_layers * batch * 1024 * per_row_bytes
    return total


# ---------------------------------------------------------------------------
# step-level costs


def decode_step_cost(tcfg: ModelConfig, dcfg: Optional[ModelConfig],
                     shape: InputShape, s: int, cache_len_t: int,
                     cache_len_d: int) -> StepCost:
    """One speculative step: draft s tokens sequentially + verify s+1."""
    B = shape.global_batch
    wb = _bytes_per(tcfg.dtype)
    detail: Dict[str, float] = {}

    # --- verify: B*(s+1) tokens, each seeing ~cache_len keys
    n_ver = B * (s + 1)
    lf = layer_flops(tcfg, n_ver, cache_len_t, decode=True)
    flops = _sum(lf) * tcfg.n_layers
    detail.update({f"verify_{k}": v * tcfg.n_layers for k, v in lf.items()})
    vocab = pad_vocab(tcfg.vocab_size)
    detail["verify_unembed"] = 2 * n_ver * tcfg.d_model * vocab
    flops += detail["verify_unembed"]

    # --- draft: s sequential single-token calls (first feeds 2 tokens)
    if dcfg is not None and s > 0:
        n_d = B * (s + 1)          # total drafted token-positions
        lfd = layer_flops(dcfg, n_d, cache_len_d, decode=True)
        dflops = _sum(lfd) * dcfg.n_layers
        dvocab = pad_vocab(dcfg.vocab_size)
        dun = 2 * n_d * dcfg.d_model * dvocab
        detail["draft"] = dflops + dun
        flops += detail["draft"]

    # --- HBM bytes
    tparams = param_count(tcfg, active_only=tcfg.moe is not None)
    # MoE: verify touches up to n_ver*top_k experts per layer; with
    # n_ver >> E the whole expert bank streams -> use full params then
    if tcfg.moe is not None:
        full = param_count(tcfg, active_only=False)
        touched = min(1.0, n_ver * tcfg.moe.top_k / tcfg.moe.n_experts)
        tparams = tparams + (full - tparams) * touched
    w_bytes = tparams * wb
    cache_rd = kv_cache_bytes(tcfg, B, cache_len_t, wb)      # full sweep / step
    detail["weights_bytes"] = w_bytes
    detail["cache_bytes"] = cache_rd
    hbm = w_bytes + cache_rd
    if dcfg is not None and s > 0:
        dw = param_count(dcfg) * wb * s                      # streamed per call
        dcache = kv_cache_bytes(dcfg, B, cache_len_d, wb) * s
        detail["draft_bytes"] = dw + dcache
        hbm += dw + dcache
    return StepCost(flops, hbm, detail)


def prefill_step_cost(cfg: ModelConfig, shape: InputShape, cache_len: int,
                      ) -> StepCost:
    B, T = shape.global_batch, shape.seq_len
    wb = _bytes_per(cfg.dtype)
    n = B * T
    detail: Dict[str, float] = {}
    # causal average context = (T+1)/2, clipped by any window
    a = cfg.attn
    kv_avg = (T + 1) / 2
    if cfg.family in ("encdec", "audio"):
        # prefill_32k: encoder over T frames + short decoder prompt
        enc = layer_flops(cfg, n, kv_avg)
        flops = _sum(enc) * cfg.enc_layers
        detail["encoder"] = flops
        n_dec = B * 16
        dec = layer_flops(cfg, n_dec, 16 / 2)
        cross = _attn_score_flops(cfg, n_dec, T) + _attn_proj_flops(cfg, n_dec)
        detail["decoder"] = (_sum(dec) + cross) * cfg.n_layers
        flops += detail["decoder"]
    else:
        lf = layer_flops(cfg, n, kv_avg)
        flops = _sum(lf) * cfg.n_layers
        detail.update({k: v * cfg.n_layers for k, v in lf.items()})
    vocab = pad_vocab(cfg.vocab_size)
    detail["unembed"] = 2 * B * cfg.d_model * vocab          # last token only
    flops += detail["unembed"]

    params = param_count(cfg, active_only=False)
    act = n * cfg.d_model * wb * 12                          # per-layer IO est.
    cache_wr = kv_cache_bytes(cfg, B, min(cache_len, T), wb)
    detail["weights_bytes"] = params * wb
    detail["act_bytes"] = act * (cfg.n_layers + cfg.enc_layers)
    detail["cache_bytes"] = cache_wr
    hbm = detail["weights_bytes"] + detail["act_bytes"] + cache_wr
    return StepCost(flops, hbm, detail)


def train_step_cost(cfg: ModelConfig, shape: InputShape, remat: bool = True,
                    ) -> StepCost:
    B, T = shape.global_batch, shape.seq_len
    wb = _bytes_per(cfg.dtype)
    detail: Dict[str, float] = {}
    # the train attention path computes all (q, k) pairs (full_pairs):
    # score flops use full T, not the causal (T+1)/2
    if cfg.family in ("encdec", "audio"):
        n_enc = B * (T // 4)
        n_dec = B * T
        enc = _sum(layer_flops(cfg, n_enc, T // 4, full_pairs=True)) * cfg.enc_layers
        dec = (_sum(layer_flops(cfg, n_dec, T, full_pairs=True))
               + _attn_proj_flops(cfg, n_dec)
               + _attn_score_flops(cfg, n_dec, T // 4)) * cfg.n_layers
        fwd = enc + dec
        n_tok = n_dec
    elif cfg.family == "vlm":
        n_tok = B * T                                        # prefix + text
        fwd = _sum(layer_flops(cfg, n_tok, T, full_pairs=True)) * cfg.n_layers
    else:
        n_tok = B * T
        fwd = _sum(layer_flops(cfg, n_tok, T, full_pairs=True)) * cfg.n_layers
    vocab = pad_vocab(cfg.vocab_size)
    fwd += 2 * n_tok * cfg.d_model * vocab
    mult = 4.0 if remat else 3.0                             # fwd+recompute+2bwd
    detail["matmul"] = fwd * mult
    flops = fwd * mult

    params = param_count(cfg, active_only=False)
    # params bf16 read (fwd+bwd) + grads + fp32 m/v read+write
    detail["weights_bytes"] = params * (2 * wb + wb + 16 + 2 * wb)
    # remat: store/read one residual per layer boundary
    layers = cfg.n_layers + cfg.enc_layers
    detail["act_bytes"] = n_tok * cfg.d_model * wb * 2 * layers
    detail["logits_bytes"] = n_tok * vocab * 4 * 2           # fp32 logits r/w
    hbm = detail["weights_bytes"] + detail["act_bytes"] + detail["logits_bytes"]
    return StepCost(flops, hbm, detail)


def model_flops_6nd(cfg: ModelConfig, n_tokens: float) -> float:
    """The reference MODEL_FLOPS = 6 N D (active params for MoE)."""
    n_params = param_count(cfg, active_only=cfg.moe is not None)
    return 6.0 * n_params * n_tokens


def step_cost(arch_cfg: ModelConfig, draft_cfg: Optional[ModelConfig],
              shape: InputShape, kind: str, *, s: int = 4,
              cache_len_t: int = 0, cache_len_d: int = 0) -> StepCost:
    if kind == "train":
        return train_step_cost(arch_cfg, shape)
    if kind == "prefill":
        return prefill_step_cost(arch_cfg, shape, cache_len_t)
    return decode_step_cost(arch_cfg, draft_cfg, shape, s, cache_len_t,
                            cache_len_d)
