"""Step plans for the multi-pod dry-run and the launchers.

For every (architecture x input shape) this module builds:
  * ``input_specs(arch, shape)``  — ShapeDtypeStruct stand-ins for every step
    input (weak-type-correct, shardable, no device allocation);
  * ``build_plan(arch, shape, mesh)`` — the jittable step function plus the
    matching in/out sharding trees (NamedShardings on ``mesh``).

Shape -> step mapping (DESIGN §5):
  train_4k     -> train_step        (loss + grads + AdamW, remat'd scan)
  prefill_32k  -> prefill_step      (flash forward + KV-cache build)
  decode_32k   -> spec_decode_step  (draft s + verify s+1 — the paper's
  long_500k    -> spec_decode_step   technique; s = 4, the adaptive default)

long_500k runs every architecture: SSM/hybrid natively (O(1) state), all
attention families through their sliding-window variant (cfg.windowed(),
ring-buffer cache of window+pad rows) — the sub-quadratic carve-out of
DESIGN §4.

Modality frontends are stubs per the assignment: audio supplies
``src_embeds`` [B, S, d] frame embeddings, VLM supplies ``prefix_embeds``
[B, prefix, d] patch embeddings, both as ShapeDtypeStructs here.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry as R
from repro.configs.base import SHAPES, InputShape, ModelConfig
from repro.core.spec_decode import make_spec_step
from repro.launch.mesh import data_axes, model_axis_size
from repro.models import common as cm
from repro.training.optimizer import AdamWConfig, AdamWState, init_adamw
from repro.training.train_step import make_train_step

# ring-buffer slack rows beyond the attention window for windowed decode
# (must cover s+1 in-flight rows; padded to keep kernel-block divisibility)
_RING_PAD = 64
DEFAULT_SPEC_S = 4
MAX_NEW = 128
# fixed modality-frontend lengths (DESIGN §10): audio source frames for
# decode shapes, and the encoder length used at train time
AUDIO_DECODE_SRC = 1024
AUDIO_TRAIN_SRC_FRACTION = 4      # train src_len = seq_len // 4


def _arch_cfg(arch: str, shape: InputShape, transform=None) -> ModelConfig:
    cfg = R.get_config(arch)
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        cfg = cfg.windowed()      # sliding-window sub-quadratic variant
    if transform is not None:     # hillclimb lever (e.g. MoE gather dispatch)
        cfg = transform(cfg)
    return cfg


def _draft_cfg(arch: str, tcfg: ModelConfig) -> ModelConfig:
    d = R.get_draft_config(arch)
    if tcfg.attn is not None and tcfg.attn.window is not None:
        # draft inherits the (possibly long-context-windowed) target window
        if d.attn.window is None or d.attn.window > tcfg.attn.window:
            d = d.with_(attn=dataclasses.replace(d.attn, window=tcfg.attn.window))
    return d


def _cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer length for an attention KV cache."""
    a = cfg.attn
    if a is not None and a.window is not None and a.window + _RING_PAD < seq_len:
        return a.window + _RING_PAD
    return seq_len


# ---------------------------------------------------------------------------
# batch / token shardings


def _batch_spec(mesh: Mesh, batch: int, *rest) -> P:
    """Shard the leading batch dim over as many data axes as divide it."""
    axes = [a for a in data_axes(mesh)]
    keep = []
    n = 1
    for a in reversed(axes):          # prefer inner 'data' before 'pod'
        sz = mesh.shape[a]
        if batch % (n * sz) == 0:
            keep.append(a)
            n *= sz
    keep = tuple(reversed(keep))
    first = keep if keep else None
    return P(first, *rest)


def _sds(shape, dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


# ---------------------------------------------------------------------------
# serving slot-pool shardings (continuous batching on the production mesh)


@dataclass(frozen=True)
class SlotPoolSpecs:
    """PartitionSpec trees for a continuous-batching slot pool on ``mesh``.

    The pool's batch (= slot capacity) axis is sharded over the mesh's data
    axes exactly like a decode plan's batch dim (:func:`_batch_spec`), so the
    serving step is the same SPMD program the dry-run lowers; params stay
    replicated (data-parallel serving).  For a paged pool the shared block
    arrays shard along ``num_blocks`` — each data shard owns a contiguous
    range of physical KV blocks — while the per-slot block *tables* shard
    along capacity with the slots they describe.  Host block accounting
    (:class:`~repro.serving.slots.PagedKVTables`) is untouched: block ids
    stay global, the NamedSharding maps them to devices.

    ``n_shards`` is the number of distinct data shards of the capacity axis
    (1 when capacity does not divide the data axes) — the scheduler's
    per-host admission queue round-robins slot claims across these shards.
    """
    tcache: Any                       # P tree matching DecodeState.tcache
    dcache: Any                       # P tree for the draft cache (or None)
    seq_lens: P
    last2: P
    out: P
    n_generated: P
    done: P
    batch_axes: Any                   # mesh axes the capacity dim shards over
    n_shards: int
    # the ragged-grid scalar operands (cu_blocks [capacity + 1] on the step,
    # cu_row [2] on the chunk forward) are host-built per dispatch and tiny:
    # they ride explicitly REPLICATED so every shard sees the full grid plan
    # its scalar-prefetched block table describes
    cu_blocks: P = P()


def slot_pool_specs(mesh: Mesh, target, draft, capacity: int, *,
                    paged_num_blocks: Optional[int] = None) -> SlotPoolSpecs:
    """Build the sharding-spec trees for a serving slot pool.

    ``target`` / ``draft`` are model objects exposing ``cache_specs`` (every
    decode family does — the same machinery the decode plans use).  With
    ``paged_num_blocks`` set, the target KV specs describe the paged block
    pool (k/v/pos sharded over blocks + a capacity-sharded ``bt`` table)
    instead of per-slot contiguous rings.
    """
    if not hasattr(target, "cache_specs"):
        raise NotImplementedError(
            f"{type(target).__name__} has no cache_specs; cannot shard its "
            f"slot pool over a mesh")
    bspec = _batch_spec(mesh, capacity)
    baxes = bspec[0] if len(bspec) else None
    n_shards = 1
    if baxes:
        for a in (baxes if isinstance(baxes, (tuple, list)) else (baxes,)):
            n_shards *= mesh.shape[a]
    elif any(mesh.shape[a] > 1 for a in data_axes(mesh)):
        import warnings
        warnings.warn(
            f"slot pool capacity {capacity} does not divide the mesh's "
            f"data axes {dict(mesh.shape)}; the pool will be REPLICATED "
            f"(n_shards=1) — every device computes the full batch. Pick a "
            f"capacity divisible by the data-axis product to actually "
            f"shard.", stacklevel=3)
    if paged_num_blocks is None:
        tc = target.cache_specs({}, batch_axis=baxes, seq_axis=None)
    else:
        nspec = _batch_spec(mesh, paged_num_blocks)
        naxes = nspec[0] if len(nspec) else None
        # k/v: [nL, num_blocks, block_size, KVH, hd]; pos: [NB, bs];
        # bt: [capacity, max_blocks] (added by SpecDecodeEngine.init_slots).
        # The block axis shards with the same machinery as the capacity
        # axis, so the fused paged kernel's scalar-prefetched block table
        # lines up with the pool placement (kernels/paged_verify_attn.py)
        tc = {"k": P(None, naxes), "v": P(None, naxes), "pos": P(naxes),
              "bt": P(baxes)}
        if getattr(getattr(target, "cfg", None), "kv_quant", False):
            # int8 pool: per-(row, kv-head) dequant scales ride the block axis
            tc["k_scale"] = P(None, naxes)
            tc["v_scale"] = P(None, naxes)
    dc = (draft.cache_specs({}, batch_axis=baxes, seq_axis=None)
          if draft is not None else None)
    return SlotPoolSpecs(
        tcache=tc, dcache=dc,
        seq_lens=P(baxes), last2=P(baxes), out=P(baxes),
        n_generated=P(baxes), done=P(baxes),
        batch_axes=baxes, n_shards=n_shards, cu_blocks=P())


# ---------------------------------------------------------------------------
# input specs (deliverable: allocation-free stand-ins for every model input)


def input_specs(arch: str, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the *data* inputs of the step this shape runs.

    train  -> tokens/labels (+ modality embeds)
    prefill-> tokens/prompt_lens (+ modality embeds)
    decode -> seq_lens/last2/out/n_generated/done (caches & params come from
              the plan, which owns their shardings).
    """
    shape = SHAPES[shape_name]
    cfg = _arch_cfg(arch, shape)
    B, T, d = shape.global_batch, shape.seq_len, cfg.d_model
    if shape.kind == "train":
        toks = T
        out: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.family in ("encdec", "audio"):
            src = T // AUDIO_TRAIN_SRC_FRACTION
            out["src_embeds"] = _sds((B, src, d), jnp.bfloat16)
        elif cfg.family == "vlm":
            toks = T - cfg.prefix_len
            out["prefix_embeds"] = _sds((B, cfg.prefix_len, d), jnp.bfloat16)
        out["tokens"] = _sds((B, toks), jnp.int32)
        out["labels"] = _sds((B, toks - 1), jnp.int32)
        return out
    if shape.kind == "prefill":
        out = {}
        toks = T
        if cfg.family in ("encdec", "audio"):
            out["src_embeds"] = _sds((B, T, d), jnp.bfloat16)   # long audio in
            toks = 16                                            # short tgt prompt
        elif cfg.family == "vlm":
            toks = T - cfg.prefix_len
            out["prefix_embeds"] = _sds((B, cfg.prefix_len, d), jnp.bfloat16)
        out["tokens"] = _sds((B, toks), jnp.int32)
        out["prompt_lens"] = _sds((B,), jnp.int32)
        return out
    # decode: per-request control state (caches come from the plan)
    return {
        "seq_lens": _sds((B,), jnp.int32),
        "last2": _sds((B, 2), jnp.int32),
        "out": _sds((B, MAX_NEW + 9), jnp.int32),
        "n_generated": _sds((B,), jnp.int32),
        "done": _sds((B,), bool),
    }


# ---------------------------------------------------------------------------
# plans


@dataclass
class StepPlan:
    arch: str
    shape: InputShape
    kind: str
    fn: Callable                      # pure step function
    args: Tuple[Any, ...]             # ShapeDtypeStruct pytrees, fn(*args)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any                # None = compiler-chosen
    meta: Dict[str, Any]

    donate: Tuple[int, ...] = ()

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate)
        return jitted.lower(*self.args)


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _train_plan(arch: str, shape: InputShape, mesh: Mesh,
                rules_overrides=None, remat: bool = True,
                transform=None) -> StepPlan:
    cfg = _arch_cfg(arch, shape, transform)
    model = R.build_model(cfg)
    msize = model_axis_size(mesh)
    rules = cm.resolve_rules(cfg, msize, rules_overrides)
    pspecs = model.specs(rules)
    params = model.shapes(jnp.bfloat16)
    opt_specs = AdamWState(step=P(), m=pspecs, v=jax.tree.map(lambda s: s, pspecs))
    opt_shapes = jax.eval_shape(init_adamw, params)

    ins = input_specs(arch, shape.name)
    extra = tuple(k for k in ("src_embeds", "prefix_embeds") if k in ins)
    batch_specs = {"tokens": _batch_spec(mesh, shape.global_batch, None),
                   "labels": _batch_spec(mesh, shape.global_batch, None)}
    for k in extra:
        batch_specs[k] = _batch_spec(mesh, shape.global_batch, None, None)

    opt = AdamWConfig()
    step = make_train_step(model, cfg, opt, remat=remat, extra_keys=extra)
    in_sh = (_ns(mesh, pspecs), _ns(mesh, opt_specs), _ns(mesh, batch_specs))
    out_sh = (_ns(mesh, pspecs), _ns(mesh, opt_specs), None)
    return StepPlan(arch, shape, "train", step, (params, opt_shapes, ins),
                    in_sh, out_sh, {"cfg": cfg, "rules": rules}, donate=(0, 1))


def _prefill_plan(arch: str, shape: InputShape, mesh: Mesh,
                  rules_overrides=None, transform=None) -> StepPlan:
    cfg = _arch_cfg(arch, shape, transform)
    model = R.build_model(cfg)
    msize = model_axis_size(mesh)
    rules = cm.resolve_rules(cfg, msize, rules_overrides)
    pspecs = model.specs(rules)
    params = model.shapes(jnp.bfloat16)
    ins = input_specs(arch, shape.name)
    B = shape.global_batch
    L = _cache_len(cfg, shape.seq_len)
    bspec = _batch_spec(mesh, B)

    ins_specs = {"tokens": _batch_spec(mesh, B, None),
                 "prompt_lens": bspec}
    if "src_embeds" in ins:
        ins_specs["src_embeds"] = _batch_spec(mesh, B, None, None)
    if "prefix_embeds" in ins:
        ins_specs["prefix_embeds"] = _batch_spec(mesh, B, None, None)

    batch_axis = ins_specs["tokens"][0]

    def fn(params, inputs):
        kw = {}
        if cfg.family in ("encdec", "audio"):
            cache = model.init_cache(B, cache_len=L, dtype=jnp.bfloat16,
                                     src_len=inputs["src_embeds"].shape[1])
            kw["src_embeds"] = inputs["src_embeds"]
        elif cfg.family == "ssm":
            cache = model.init_cache(B, dtype=jnp.bfloat16)
        else:
            cache = model.init_cache(B, cache_len=L, dtype=jnp.bfloat16)
            if cfg.family == "vlm":
                kw["prefix_embeds"] = inputs["prefix_embeds"]
        pre = getattr(model, "prefill_flash", model.prefill)
        logits, cache, lens = pre(params, inputs["tokens"], cache,
                                  prompt_lens=inputs["prompt_lens"], **kw)
        return logits, cache, lens

    in_sh = (_ns(mesh, pspecs), _ns(mesh, ins_specs))
    return StepPlan(arch, shape, "prefill", fn, (params, ins), in_sh, None,
                    {"cfg": cfg, "rules": rules, "cache_len": L,
                     "batch_axis": batch_axis})


def _decode_plan(arch: str, shape: InputShape, mesh: Mesh,
                 s: int = DEFAULT_SPEC_S, rules_overrides=None,
                 draft_rules_overrides=None, seq_axis=None,
                 donate: bool = True, transform=None,
                 draft_transform=None) -> StepPlan:
    tcfg = _arch_cfg(arch, shape, transform)
    dcfg = _draft_cfg(arch, tcfg)
    if draft_transform is not None:   # hillclimb lever (window/quant drafts)
        dcfg = draft_transform(dcfg)
    target, draft = R.build_model(tcfg), R.build_model(dcfg)
    msize = model_axis_size(mesh)
    trules = cm.resolve_rules(tcfg, msize, rules_overrides)
    # draft is small: replicate its weights by default (DESIGN §8.5)
    drules = {k: None for k in cm.resolve_rules(dcfg, msize)}
    if draft_rules_overrides:
        drules.update(draft_rules_overrides)
    B = shape.global_batch
    tp_specs, dp_specs = target.specs(trules), draft.specs(drules)
    tparams, dparams = target.shapes(jnp.bfloat16), draft.shapes(jnp.bfloat16)

    Lt = _cache_len(tcfg, shape.seq_len)
    Ld = _cache_len(dcfg, shape.seq_len)
    ckw: Dict[str, Any] = {}
    if tcfg.family in ("encdec", "audio"):
        ckw["src_len"] = AUDIO_DECODE_SRC
    if tcfg.family == "ssm":
        tcache = jax.eval_shape(partial(target.init_cache, B, dtype=jnp.bfloat16))
    else:
        tcache = jax.eval_shape(partial(target.init_cache, B, cache_len=Lt,
                                        dtype=jnp.bfloat16, **ckw))
    dcache = jax.eval_shape(partial(draft.init_cache, B, cache_len=Ld,
                                    dtype=jnp.bfloat16))

    bspec = _batch_spec(mesh, B)
    batch_axis = bspec[0]
    tc_specs = target.cache_specs(trules, batch_axis=batch_axis, seq_axis=seq_axis)
    dc_specs = draft.cache_specs(drules, batch_axis=batch_axis, seq_axis=seq_axis)

    ins = input_specs(arch, shape.name)
    ctrl_specs = {"seq_lens": bspec, "last2": _batch_spec(mesh, B, None),
                  "out": _batch_spec(mesh, B, None),
                  "n_generated": bspec, "done": bspec}

    prefix_offset = tcfg.prefix_len if tcfg.family == "vlm" else 0
    fn = make_spec_step(target, draft, B, s, eos_id=-1, max_new=MAX_NEW,
                        prefix_offset=prefix_offset)

    args = (tparams, dparams, tcache, dcache, ins["seq_lens"], ins["last2"],
            ins["out"], ins["n_generated"], ins["done"])
    in_sh = (_ns(mesh, tp_specs), _ns(mesh, dp_specs), _ns(mesh, tc_specs),
             _ns(mesh, dc_specs), _ns(mesh, ctrl_specs["seq_lens"]),
             _ns(mesh, ctrl_specs["last2"]), _ns(mesh, ctrl_specs["out"]),
             _ns(mesh, ctrl_specs["n_generated"]), _ns(mesh, ctrl_specs["done"]))
    # outputs: (tcache', dcache', seq_lens', last2', out', n_gen', done', a, n_commit)
    out_sh = (_ns(mesh, tc_specs), _ns(mesh, dc_specs),
              _ns(mesh, ctrl_specs["seq_lens"]), _ns(mesh, ctrl_specs["last2"]),
              _ns(mesh, ctrl_specs["out"]), _ns(mesh, ctrl_specs["n_generated"]),
              _ns(mesh, ctrl_specs["done"]), _ns(mesh, bspec), _ns(mesh, bspec))
    donate_args: Tuple[int, ...] = (2, 3, 4, 5, 6, 7, 8)
    if tcfg.family in ("ssm", "hybrid"):
        # commit() restores the base cache structure, so output specs equal
        # the input cache specs; leaving them compiler-chosen replicated the
        # committed state at small depths and poisoned the collective
        # extrapolation (EXPERIMENTS §Perf C1/C2).  tcache stays undonated
        # (the checkpoint selection makes buffer reuse shape-incompatible).
        out_sh = (_ns(mesh, tc_specs), *out_sh[1:])
        donate_args = (3, 4, 5, 6, 7, 8)
    if not donate:
        donate_args = ()
    return StepPlan(arch, shape, "spec_decode", fn, args, in_sh, out_sh,
                    {"cfg": tcfg, "draft_cfg": dcfg, "rules": trules, "s": s,
                     "cache_len": Lt, "batch_axis": batch_axis},
                    donate=donate_args)


def build_plan(arch: str, shape_name: str, mesh: Mesh, **kw) -> StepPlan:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return _train_plan(arch, shape, mesh, **kw)
    if shape.kind == "prefill":
        return _prefill_plan(arch, shape, mesh, **kw)
    return _decode_plan(arch, shape, mesh, **kw)
