"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
llama-arch GQA.  [arXiv:2403.04652]"""
from repro.configs.base import AttnConfig, ModelConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "yi-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=60, d_model=7168, d_ff=20_480, vocab_size=64_000,
        attn=AttnConfig(n_heads=56, n_kv_heads=8, head_dim=128, rope_theta=5e6),
        source="arXiv:2403.04652",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=224, d_ff=640, vocab_size=512,
        attn=AttnConfig(n_heads=7, n_kv_heads=1, head_dim=32, rope_theta=5e6),
        dtype="float32",
        source="reduced yi family variant for CPU smoke tests",
    )


def draft_config() -> ModelConfig:
    return dense_draft(config())
