"""yi-9b [dense]: 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA.  [arXiv:2403.04652]"""
from repro.configs.base import AttnConfig, ModelConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "yi-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=48, d_model=4096, d_ff=11_008, vocab_size=64_000,
        attn=AttnConfig(n_heads=32, n_kv_heads=4, head_dim=128, rope_theta=5e6),
        source="arXiv:2403.04652",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, d_ff=352, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32, rope_theta=5e6),
        dtype="float32",
        source="reduced yi family variant for CPU smoke tests",
    )


def draft_config() -> ModelConfig:
    return dense_draft(config())
