"""Architecture registry: ``--arch <id>`` resolution for every launcher,
benchmark and test.  Maps assigned arch ids to their config modules and
model families to model classes.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

# assigned architectures (10) + the paper's own evaluation pair
_MODULES: Dict[str, str] = {
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "yi-9b": "repro.configs.yi_9b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "yi-34b": "repro.configs.yi_34b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "opt-6.7b": "repro.configs.opt_pair",
}

ASSIGNED: List[str] = [k for k in _MODULES if k != "opt-6.7b"]


def _norm(arch_id: str) -> str:
    a = arch_id.lower().replace("_", "-")
    if a not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return a


def get_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[_norm(arch_id)]).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[_norm(arch_id)]).smoke_config()


def get_draft_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[_norm(arch_id)]).draft_config()


def build_model(cfg: ModelConfig):
    """Instantiate the model class for a config's family."""
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import DecoderLM
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        from repro.models.mamba2 import Mamba2LM
        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        from repro.models.rglru import RGLRUHybridLM
        return RGLRUHybridLM(cfg)
    if cfg.family in ("encdec", "audio"):
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
