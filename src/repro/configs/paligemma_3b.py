"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216,
SigLIP + gemma.  [arXiv:2407.07726]

The SigLIP vision tower + projector are a stub: ``input_specs`` supplies 256
precomputed patch embeddings [B, 256, 2048] that form a bidirectional prefix
(PaliGemma's prefix-LM masking); speculation operates on the text suffix.
"""
from repro.configs.base import AttnConfig, ModelConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "paligemma-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        n_layers=18, d_model=2048, d_ff=16_384, vocab_size=257_216,
        attn=AttnConfig(n_heads=8, n_kv_heads=1, head_dim=256, rope_theta=1e4),
        prefix_len=256, bidirectional_prefix=True,
        source="arXiv:2407.07726",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        n_layers=2, d_model=128, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=1, head_dim=32, rope_theta=1e4),
        prefix_len=8, bidirectional_prefix=True,
        dtype="float32",
        source="reduced paligemma family variant for CPU smoke tests",
    )


def draft_config() -> ModelConfig:
    return dense_draft(config())
