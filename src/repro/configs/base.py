"""Configuration dataclasses for all model families and experiment shapes.

Every assigned architecture gets one module in this package exposing:
  ``config()``        -- the full-size config (exact numbers from the assignment)
  ``smoke_config()``  -- a reduced same-family variant for CPU smoke tests
  ``draft_config()``  -- the small speculative model (SSM in the paper's terms)
                         paired with the target for speculative decoding.

Configs are plain frozen dataclasses; models consume them functionally.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# attention


@dataclass(frozen=True)
class AttnConfig:
    kind: str = "gqa"  # "gqa" | "mla"
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 128
    qk_norm: bool = False          # Qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10_000.0
    window: Optional[int] = None   # sliding-window size; None = full causal
    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0          # compressed KV dim (c_kv)
    q_lora_rank: int = 0           # 0 = full-rank q projection
    rope_head_dim: int = 64        # decoupled RoPE key/query dim
    v_head_dim: int = 0            # defaults to head_dim when 0

    @property
    def vdim(self) -> int:
        return self.v_head_dim or self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # DeepSeek-style always-on shared experts
    d_ff_shared: int = 0           # d_ff of the shared-expert block
    capacity_factor: float = 1.25  # GShard-style dispatch capacity
    router_aux_weight: float = 1e-2
    # dispatch implementation: "einsum" = GShard one-hot matmuls (baseline,
    # costs ~4·n·tg·k·cf·d extra flops); "gather" = stable-sort ragged
    # dispatch (pure data movement, §Perf hillclimb)
    dispatch: str = "einsum"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD parameters."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256               # SSD chunk length
    d_conv: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma temporal-mixing block parameters."""
    lru_width: int = 0             # 0 -> d_model
    d_conv: int = 4
    window: int = 2048             # local-attention window of the attn blocks
    # layer pattern, repeated: RecurrentGemma-2B uses (rec, rec, attn)
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # enc-dec only
    enc_layers: int = 0
    cross_attn: bool = False
    # vlm / audio: number of modality-prefix embedding positions supplied by
    # the (stubbed) frontend, and whether the prefix mask is bidirectional
    prefix_len: int = 0
    bidirectional_prefix: bool = False
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # sliding-window override applied when running the long_500k shape on an
    # otherwise full-attention architecture (sub-quadratic variant; DESIGN §4)
    long_context_window: int = 8192
    # int8 KV cache with per-(row, kv-head) scales (GQA caches only; MLA's
    # cache is already rank-compressed).  §Perf lever: halves the decode
    # cache sweep, the dominant memory term at 32k context.
    kv_quant: bool = False
    # paged-attention kernel routing for the block-pool decode path:
    # None = auto (fused Pallas kernel on TPU, gather+verify reference on
    # CPU), True = force the fused kernel (interpret mode off-TPU — tests /
    # microbench), False = force the gather+verify reference.  Trace-time
    # static: changing it requires rebuilding the model's jits
    # (SpecDecodeEngine.set_paged_fused handles both).
    paged_fused: Optional[bool] = None
    source: str = ""               # citation from the assignment

    # ---- derived ----
    @property
    def d_head_total(self) -> int:
        a = self.attn
        return 0 if a is None else a.n_heads * a.head_dim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def windowed(self, window: Optional[int] = None) -> "ModelConfig":
        """Return a copy whose attention uses a sliding window (for long_500k)."""
        if self.attn is None:
            return self
        w = window or self.long_context_window
        cur = self.attn.window
        w = min(cur, w) if cur else w
        return self.with_(attn=dataclasses.replace(self.attn, window=w))


# ---------------------------------------------------------------------------
# parameter counting (used for MODEL_FLOPS = 6·N·D in the roofline)


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Approximate parameter count; ``active_only`` counts top-k routed experts
    only (for MoE active-FLOPs accounting)."""
    d = cfg.d_model
    n_emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)

    def attn_params() -> int:
        a = cfg.attn
        if a is None:
            return 0
        if a.kind == "mla":
            qp = d * a.q_lora_rank + a.q_lora_rank * a.n_heads * (a.head_dim + a.rope_head_dim) \
                if a.q_lora_rank else d * a.n_heads * (a.head_dim + a.rope_head_dim)
            kvp = d * (a.kv_lora_rank + a.rope_head_dim) \
                + a.kv_lora_rank * a.n_heads * (a.head_dim + a.vdim)
            op = a.n_heads * a.vdim * d
            return qp + kvp + op
        return d * a.head_dim * (a.n_heads + 2 * a.n_kv_heads) + a.n_heads * a.head_dim * d

    def mlp_params() -> int:
        if cfg.moe is not None:
            m = cfg.moe
            n_e = m.top_k if active_only else m.n_experts
            routed = n_e * 3 * d * m.d_ff_expert + d * m.n_experts  # + router
            shared = m.n_shared * 3 * d * (m.d_ff_shared or m.d_ff_expert)
            return routed + shared
        return 3 * d * cfg.d_ff  # SwiGLU: gate, up, down

    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        nheads = d_in // s.head_dim
        per_layer = (
            d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
            + s.d_conv * (d_in + 2 * s.n_groups * s.d_state)      # conv
            + d_in * d                                            # out_proj
            + 2 * nheads                                          # A_log, D
        )
        return n_emb + cfg.n_layers * per_layer

    per_layer = attn_params() + mlp_params()
    n_layers = cfg.n_layers + cfg.enc_layers
    if cfg.cross_attn:
        per_layer_dec_extra = attn_params()  # cross-attention block
        return n_emb + cfg.enc_layers * (attn_params() + mlp_params()) \
            + cfg.n_layers * (per_layer + per_layer_dec_extra)
    if cfg.rglru is not None:
        # rec blocks replace attention with RG-LRU mixing of similar size
        r = cfg.rglru
        w = r.lru_width or d
        rec = 2 * d * w + w * d + 2 * w + w * r.d_conv
        pat = r.pattern
        n_rec = sum(1 for p in pat if p == "rec") * (cfg.n_layers // len(pat))
        n_att = cfg.n_layers - n_rec
        return n_emb + n_rec * (rec + mlp_params()) + n_att * per_layer
    return n_emb + n_layers * per_layer


# ---------------------------------------------------------------------------
# experiment input shapes (the four assigned shapes)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def pad_vocab(v: int, multiple: int = 512) -> int:
    """Vocab padded for model-axis sharding; logits at padded ids are masked."""
    return ((v + multiple - 1) // multiple) * multiple
