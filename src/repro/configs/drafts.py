"""Draft (SSM — "small speculative model") config builders.

The paper pairs OPT-6.7B with OPT-125M.  We follow the same recipe for every
assigned target: the draft is a small dense GQA decoder sharing the target's
vocabulary (a requirement — the draft and target must emit the same token
ids).  For recurrent/hybrid targets the draft inherits a sliding window so
long-context decode stays sub-quadratic end to end.
"""
from __future__ import annotations

from repro.configs.base import AttnConfig, ModelConfig


def dense_draft(target: ModelConfig, *, n_layers: int = 4, d_model: int = 512,
                n_heads: int = 8, d_ff: int = 2048, window=None) -> ModelConfig:
    if window is None and target.attn is not None:
        window = target.attn.window
    if window is None and target.family in ("ssm", "hybrid"):
        window = 4096  # keep the draft sub-quadratic next to an O(1) target
    return ModelConfig(
        name=f"{target.name}-draft",
        family="dense",
        n_layers=n_layers,
        d_model=d_model,
        d_ff=d_ff,
        vocab_size=target.vocab_size,
        attn=AttnConfig(n_heads=n_heads, n_kv_heads=n_heads, head_dim=d_model // n_heads,
                        rope_theta=1e6, window=window),
        norm_eps=target.norm_eps,
        dtype=target.dtype,
        source="draft model (paper §2: SSM), OPT-125M-scale dense decoder",
    )
