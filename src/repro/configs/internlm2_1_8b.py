"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544, GQA.  [arXiv:2403.17297]"""
from repro.configs.base import AttnConfig, ModelConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "internlm2-1.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=24, d_model=2048, d_ff=8192, vocab_size=92_544,
        attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=128, rope_theta=1e6),
        source="arXiv:2403.17297",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, d_ff=256, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32, rope_theta=1e6),
        dtype="float32",
        source="reduced internlm2 family variant for CPU smoke tests",
    )


def draft_config() -> ModelConfig:
    return dense_draft(config())
