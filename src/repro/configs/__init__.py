from repro.configs.base import (AttnConfig, InputShape, ModelConfig, MoEConfig,
                                RGLRUConfig, SHAPES, SSMConfig, param_count)
from repro.configs.registry import (ASSIGNED, build_model, get_config,
                                    get_draft_config, get_smoke_config)
