"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936,
qk_norm, GQA.  [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import AttnConfig, ModelConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "qwen3-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=36, d_model=4096, d_ff=12_288, vocab_size=151_936,
        attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                        qk_norm=True, rope_theta=1e6),
        source="hf:Qwen/Qwen3-8B",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, d_ff=384, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        qk_norm=True, rope_theta=1e6),
        dtype="float32",
        source="reduced qwen3 family variant for CPU smoke tests",
    )


def draft_config() -> ModelConfig:
    return dense_draft(config())
