"""mamba2-1.3b [ssm]: 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "mamba2-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=48, d_model=2048, d_ff=0, vocab_size=50_280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256, d_conv=4, n_groups=1),
        source="arXiv:2405.21060",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        n_layers=2, d_model=128, d_ff=0, vocab_size=512,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk=8, d_conv=4, n_groups=1),
        dtype="float32",
        source="reduced mamba2 family variant for CPU smoke tests",
    )


def draft_config() -> ModelConfig:
    return dense_draft(config())
