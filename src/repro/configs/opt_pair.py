"""The paper's own evaluation pair (§5.1): OPT-6.7B target + OPT-125M draft.

OPT uses ReLU MLPs and learned positional embeddings; we realize both models
in this framework's llama-style substrate (SwiGLU + RoPE) at matching
dimensions — the paper's claims concern relative speedups and the b/s
interaction, which are architecture-shape-level properties (DESIGN §10).
"""
from repro.configs.base import AttnConfig, ModelConfig

ARCH_ID = "opt-6.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=4096, d_ff=16_384, vocab_size=50_272,
        attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=128, rope_theta=1e4),
        source="arXiv:2205.01068 (paper §5.1 target LLM)",
    )


def draft_config() -> ModelConfig:
    return ModelConfig(
        name="opt-125m", family="dense",
        n_layers=12, d_model=768, d_ff=3072, vocab_size=50_272,
        attn=AttnConfig(n_heads=12, n_kv_heads=12, head_dim=64, rope_theta=1e4),
        source="arXiv:2205.01068 (paper §5.1 draft SSM)",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, d_ff=512, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32, rope_theta=1e4),
        dtype="float32",
        source="reduced OPT-pair variant for CPU smoke tests",
    )
