"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206, encoder-decoder, multimodal.  [arXiv:2308.11596]

Realized as 24 encoder + 24 decoder layers (the HF card's text-decoder depth;
DESIGN §10).  The speech frontend (mel-spectrogram + conv feature extractor)
is a stub: ``input_specs`` supplies precomputed frame embeddings [B, S, 1024].
"""
from repro.configs.base import AttnConfig, ModelConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "seamless-m4t-large-v2"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        n_layers=24, enc_layers=24, cross_attn=True,
        d_model=1024, d_ff=8192, vocab_size=256_206,
        attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=64, rope_theta=1e4),
        source="arXiv:2308.11596",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="audio",
        n_layers=2, enc_layers=2, cross_attn=True,
        d_model=128, d_ff=256, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32, rope_theta=1e4),
        dtype="float32",
        source="reduced seamless family variant for CPU smoke tests",
    )


def draft_config() -> ModelConfig:
    # drafting happens on the decoder; a dense decoder-only draft predicts
    # target tokens from the committed prefix (cross-attention omitted in the
    # draft — it only proposes, the enc-dec target verifies).
    return dense_draft(config())
