"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536 vocab=102400,
MLA kv_lora=512, MoE 2 shared + 160 routed top-6.  [arXiv:2405.04434]

MLA dims per the paper: qk_nope 128, decoupled-RoPE 64, v 128, q_lora 1536.
All layers are MoE (DeepSeek-V2 keeps layer 1 dense; DESIGN §10 deviation).
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "deepseek-v2-236b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=60, d_model=5120, d_ff=1536, vocab_size=102_400,
        attn=AttnConfig(kind="mla", n_heads=128, n_kv_heads=128, head_dim=128,
                        kv_lora_rank=512, q_lora_rank=1536,
                        rope_head_dim=64, v_head_dim=128, rope_theta=1e4),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                      n_shared=2, d_ff_shared=1536),
        source="arXiv:2405.04434",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=128, d_ff=96, vocab_size=512,
        attn=AttnConfig(kind="mla", n_heads=4, n_kv_heads=4, head_dim=32,
                        kv_lora_rank=32, q_lora_rank=48,
                        rope_head_dim=16, v_head_dim=32, rope_theta=1e4),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96,
                      n_shared=1, d_ff_shared=96, capacity_factor=2.0),
        dtype="float32",
        source="reduced deepseek-v2 family variant for CPU smoke tests",
    )


def draft_config() -> ModelConfig:
    return dense_draft(config())
