"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attention, 1 attention per 2 recurrent.
[arXiv:2402.19427]"""
from repro.configs.base import AttnConfig, ModelConfig, RGLRUConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "recurrentgemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=26, d_model=2560, d_ff=7680, vocab_size=256_000,
        attn=AttnConfig(n_heads=10, n_kv_heads=1, head_dim=256,
                        rope_theta=1e4, window=2048),
        rglru=RGLRUConfig(lru_width=2560, d_conv=4, window=2048,
                          pattern=("rec", "rec", "attn")),
        source="arXiv:2402.19427",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        n_layers=2, d_model=128, d_ff=256, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=1, head_dim=32,
                        rope_theta=1e4, window=32),
        rglru=RGLRUConfig(lru_width=128, d_conv=4, window=32,
                          pattern=("rec", "attn")),
        dtype="float32",
        source="reduced recurrentgemma family variant (1 rec + 1 attn)",
    )


def draft_config() -> ModelConfig:
    return dense_draft(config())
