"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig
from repro.configs.drafts import dense_draft

ARCH_ID = "qwen3-moe-30b-a3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=48, d_model=2048, d_ff=768, vocab_size=151_936,
        attn=AttnConfig(n_heads=32, n_kv_heads=4, head_dim=128,
                        qk_norm=True, rope_theta=1e6),
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=128, d_ff=96, vocab_size=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        qk_norm=True, rope_theta=1e6),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96, capacity_factor=2.0),
        dtype="float32",
        source="reduced qwen3-moe family variant for CPU smoke tests",
    )


def draft_config() -> ModelConfig:
    return dense_draft(config())
