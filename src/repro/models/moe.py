"""Mixture-of-Experts layer: top-k routing with GShard-style capacity-bounded
einsum dispatch (expert-parallel over the 'model' mesh axis; XLA inserts the
all-to-alls), plus DeepSeek-style always-on shared experts.

Used by qwen3-moe-30b-a3b (128e top-8) and deepseek-v2-236b (160e top-6 +
2 shared).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, swiglu
from repro.runtime.meshctx import shard


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    m, d = cfg.moe, cfg.d_model
    defs = {
        "router": ParamDef((d, m.n_experts), ("d_model", None), scale=0.02, stacked=True),
        "w_gate": ParamDef((m.n_experts, d, m.d_ff_expert), ("experts", "d_model", "expert_ff"), stacked=True),
        "w_up": ParamDef((m.n_experts, d, m.d_ff_expert), ("experts", "d_model", "expert_ff"), stacked=True),
        "w_down": ParamDef((m.n_experts, m.d_ff_expert, d), ("experts", "expert_ff", "d_model"), stacked=True),
    }
    if m.n_shared:
        ff_sh = m.n_shared * (m.d_ff_shared or m.d_ff_expert)
        defs["sh_gate"] = ParamDef((d, ff_sh), ("d_model", "ffn"), stacked=True)
        defs["sh_up"] = ParamDef((d, ff_sh), ("d_model", "ffn"), stacked=True)
        defs["sh_down"] = ParamDef((ff_sh, d), ("ffn", "d_model"), stacked=True)
    return defs


def group_size(n_tokens: int, target: int = 1024) -> int:
    """Largest divisor of n_tokens that is <= target (dispatch group length)."""
    g = min(n_tokens, target)
    while n_tokens % g:
        g -= 1
    return g


def capacity(tg: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(tg * top_k * factor / n_experts))
    return max(4, -(-c // 4) * 4)  # >=4, rounded up to a multiple of 4


def moe_forward(cfg: ModelConfig, params: Dict[str, Any], x: jax.Array,
                ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, T, d] -> (out [B, T, d], load-balance aux loss scalar).

    Routing/capacity/drop semantics are IDENTICAL between the two dispatch
    implementations (tested); they differ only in how tokens reach their
    expert slot:
      * einsum: GShard one-hot dispatch/combine matmuls — simple, but costs
        4·n·(tg·k·cf)·d real flops (comparable to the experts themselves at
        small top_k·d_ff);
      * gather: stable-sort ragged dispatch — pure data movement.
    """
    m = cfg.moe
    B, T, d = x.shape
    N = B * T
    tg = group_size(N)
    G = N // tg
    xg = x.reshape(G, tg, d)
    xg = shard(xg, "data", None, None)

    logits = jnp.einsum("gtd,de->gte", xg, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)                 # [G,tg,k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    C = capacity(tg, m.top_k, m.n_experts, m.capacity_factor)
    dt = x.dtype

    if m.dispatch == "gather":
        xe, combine_idx, combine_w, f_e = _dispatch_gather(
            m, xg, top_i, top_p, C)
        xe = shard(xe, "data", "model", None, None)
        g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
        u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
        ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, params["w_down"])
        yf = ye.reshape(G, m.n_experts * C, d)
        # combine: per (token, k) gather its slot's output and weight it
        gath = jnp.take_along_axis(
            yf, combine_idx.reshape(G, tg * m.top_k)[..., None], axis=1)
        y = (gath.reshape(G, tg, m.top_k, d)
             * combine_w[..., None].astype(dt)).sum(axis=2)
    else:
        assign = jax.nn.one_hot(top_i, m.n_experts, dtype=jnp.float32)  # [G,tg,k,E]
        # position of each (token, k) among the tokens routed to that expert,
        # ordered by (t, k); tokens beyond capacity C are dropped.
        flat = assign.reshape(G, tg * m.top_k, m.n_experts)
        pos = (jnp.cumsum(flat, axis=1) * flat - 1.0).astype(jnp.int32)  # [G,tg*k,E]
        pos = pos.reshape(G, tg, m.top_k, m.n_experts)
        keep = (pos >= 0) & (pos < C)
        pos_c = jax.nn.one_hot(jnp.where(keep, pos, -1), C, dtype=jnp.float32)  # [G,tg,k,E,C]
        dispatch = (assign[..., None] * pos_c).sum(axis=2)           # [G,tg,E,C]
        combine = (top_p[..., None, None] * assign[..., None] * pos_c).sum(axis=2)
        xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dt), xg)   # [G,E,C,d]
        xe = shard(xe, "data", "model", None, None)
        g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
        u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
        ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, params["w_down"])
        y = jnp.einsum("gtec,gecd->gtd", combine.astype(dt), ye)
        f_e = dispatch.sum(axis=(1, 3)) / tg                         # [G,E]

    y = y.reshape(B, T, d)
    # switch-transformer load-balance loss
    p_e = probs.mean(axis=1)                                         # [G,E]
    aux = m.n_experts * jnp.mean(jnp.sum(f_e * p_e, axis=-1)) * m.router_aux_weight

    if m.n_shared:
        y = y + swiglu(x.reshape(B, T, d), params["sh_gate"], params["sh_up"], params["sh_down"])
    return y, aux


def _dispatch_gather(m, xg: jax.Array, top_i: jax.Array, top_p: jax.Array,
                     C: int):
    """Stable-sort ragged dispatch with GShard-identical drop semantics.

    Returns (xe [G,E,C,d], combine_idx [G,tg,k] flat slot ids (E*C = dropped
    sentinel row), combine_w [G,tg,k] fp32, f_e [G,E] routed fraction).
    """
    G, tg, d = xg.shape
    k, E = m.top_k, m.n_experts
    e_flat = top_i.reshape(G, tg * k)                       # (t, k)-major
    order = jnp.argsort(e_flat, axis=1, stable=True)        # [G, tg*k]
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    # rank of each sorted element within its expert segment
    idx = jnp.arange(tg * k)[None]
    is_start = jnp.concatenate(
        [jnp.ones((G, 1), bool), e_sorted[:, 1:] != e_sorted[:, :-1]], axis=1)
    seg_start = jax.lax.cummax(jnp.where(is_start, idx, 0), axis=1)
    rank_sorted = idx - seg_start                           # [G, tg*k]
    # scatter ranks back to (t, k) order
    rank = jnp.zeros_like(rank_sorted).at[
        jnp.arange(G)[:, None], order].set(rank_sorted)
    keep = rank < C
    slot = jnp.where(keep, e_flat * C + rank, E * C)        # flat slot id
    # scatter tokens into the padded slot buffer (one sentinel drop row)
    tok = jnp.broadcast_to(jnp.arange(tg)[None, :, None], (G, tg, k)
                           ).reshape(G, tg * k)
    buf = jnp.zeros((G, E * C + 1, d), xg.dtype)
    xe = buf.at[jnp.arange(G)[:, None], slot].set(
        jnp.take_along_axis(xg, tok[..., None], axis=1),
        mode="drop")[:, :-1].reshape(G, E, C, d)
    combine_idx = jnp.where(keep, slot, E * C - 1)          # safe gather id
    combine_w = jnp.where(keep, top_p.reshape(G, tg * k), 0.0)
    # routed fraction per expert (kept tokens only), for the aux loss
    f_e = (jax.nn.one_hot(jnp.where(keep, e_flat, E), E, dtype=jnp.float32)
           .sum(axis=1) / tg)
    return (xe, combine_idx.reshape(G, tg, k),
            combine_w.reshape(G, tg, k).astype(jnp.float32), f_e)
