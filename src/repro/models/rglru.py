"""RecurrentGemma / Griffin-style hybrid LM (arXiv:2402.19427):
repeating (recurrent, recurrent, local-attention) blocks, each followed by an
MLP.  The temporal mixer is an RG-LRU: a gated diagonal linear recurrence
  r_t = sigmoid(g_a . x_t + b_a);  i_t = sigmoid(g_x . x_t + b_x)
  a_t = exp(-c . softplus(lam) . r_t)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t . x_t)
preceded by a short causal depthwise conv.  (We use diagonal gate weights —
Griffin uses block-diagonal; documented deviation.)

Full-sequence paths use ``jax.lax.associative_scan`` (log-depth).  Decode
checkpoints the recurrent state per verified position for speculative
rollback, exactly like the Mamba2 module; the local-attention KV cache is a
window-sized ring buffer whose rollback is a free length update.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, pad_vocab
from repro.models import common as cm
from repro.models.common import ParamDef
from repro.runtime.meshctx import shard

Params = Any
_C = 8.0  # RG-LRU decay sharpness constant (Griffin)


class RGLRUHybridLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.rglru is not None and cfg.attn is not None
        self.cfg = cfg
        r = cfg.rglru
        self.w = r.lru_width or cfg.d_model
        pat = r.pattern
        self.n_full = cfg.n_layers // len(pat)
        tail = cfg.n_layers % len(pat)
        assert all(p == "rec" for p in pat[:tail]), "tail layers must be recurrent"
        self.n_tail = tail
        self.n_rec = sum(p == "rec" for p in pat) * self.n_full + tail
        self.n_attn = sum(p == "attn" for p in pat) * self.n_full
        self.rec_per_block = sum(p == "rec" for p in pat)
        self.padded_vocab = pad_vocab(cfg.vocab_size)

    # ------------------------------------------------------------------
    def param_defs(self) -> Dict:
        c, a, r = self.cfg, self.cfg.attn, self.cfg.rglru
        d, w, hd = c.d_model, self.w, a.head_dim
        mlp = lambda: {
            "mlp_norm": ParamDef((d,), ("d_model",), init="ones", stacked=True),
            "w_gate": ParamDef((d, c.d_ff), ("d_model", "ffn"), stacked=True),
            "w_up": ParamDef((d, c.d_ff), ("d_model", "ffn"), stacked=True),
            "w_down": ParamDef((c.d_ff, d), ("ffn", "d_model"), stacked=True),
        }
        rec = {
            "norm": ParamDef((d,), ("d_model",), init="ones", stacked=True),
            "w_b1": ParamDef((d, w), ("d_model", "ffn"), stacked=True),
            "w_b2": ParamDef((d, w), ("d_model", "ffn"), stacked=True),
            "conv_w": ParamDef((r.d_conv, w), (None, "ffn"), scale=0.5, stacked=True),
            "conv_b": ParamDef((w,), ("ffn",), init="zeros", stacked=True),
            "lam": ParamDef((w,), ("ffn",), init="ones", stacked=True),
            "g_a": ParamDef((w,), ("ffn",), init="ones", stacked=True),
            "b_a": ParamDef((w,), ("ffn",), init="zeros", stacked=True),
            "g_x": ParamDef((w,), ("ffn",), init="ones", stacked=True),
            "b_x": ParamDef((w,), ("ffn",), init="zeros", stacked=True),
            "w_out": ParamDef((w, d), ("ffn", "d_model"), stacked=True),
            **mlp(),
        }
        attn = {
            "norm": ParamDef((d,), ("d_model",), init="ones", stacked=True),
            "wq": ParamDef((d, a.n_heads, hd), ("d_model", "heads", "head_dim"), stacked=True),
            "wk": ParamDef((d, a.n_kv_heads, hd), ("d_model", "kv_heads", "head_dim"), stacked=True),
            "wv": ParamDef((d, a.n_kv_heads, hd), ("d_model", "kv_heads", "head_dim"), stacked=True),
            "wo": ParamDef((a.n_heads, hd, d), ("heads", "head_dim", "d_model"), stacked=True),
            **mlp(),
        }
        return {
            "embed": ParamDef((self.padded_vocab, d), ("vocab", "d_model"), scale=0.02),
            "final_norm": ParamDef((d,), ("d_model",), init="ones"),
            "unembed": ParamDef((self.padded_vocab, d), ("vocab", "d_model"), scale=0.02),
            "rec": rec,    # stacked n_rec
            "attn": attn,  # stacked n_attn
        }

    def init(self, key, dtype=jnp.float32) -> Params:
        defs = self.param_defs()
        rec = cm.init_params(defs["rec"], jax.random.fold_in(key, 1), self.n_rec, dtype)
        attn = cm.init_params(defs["attn"], jax.random.fold_in(key, 2), self.n_attn, dtype)
        top = cm.init_params({k: v for k, v in defs.items() if isinstance(v, ParamDef)},
                             jax.random.fold_in(key, 0), 0, dtype)
        # lam init so decay a spans (0.9, 0.999) at r=0.5
        lam0 = jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, self.w)) * 2.0 / _C))
        rec["lam"] = jnp.broadcast_to(lam0, (self.n_rec, self.w)).astype(dtype)
        return dict(top, rec=rec, attn=attn)

    def shapes(self, dtype=jnp.bfloat16) -> Params:
        defs = self.param_defs()
        out = cm.param_shapes({k: v for k, v in defs.items() if isinstance(v, ParamDef)}, 0, dtype)
        out["rec"] = cm.param_shapes(defs["rec"], self.n_rec, dtype)
        out["attn"] = cm.param_shapes(defs["attn"], self.n_attn, dtype)
        return out

    def specs(self, rules) -> Params:
        defs = self.param_defs()
        out = cm.param_specs({k: v for k, v in defs.items() if isinstance(v, ParamDef)}, rules)
        out["rec"] = cm.param_specs(defs["rec"], rules)
        out["attn"] = cm.param_specs(defs["attn"], rules)
        return out

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, dtype=jnp.float32) -> Dict:
        c, a, r = self.cfg, self.cfg.attn, self.cfg.rglru
        L = min(cache_len, r.window)
        return {
            "k": jnp.zeros((self.n_attn, batch, L, a.n_kv_heads, a.head_dim), dtype),
            "v": jnp.zeros((self.n_attn, batch, L, a.n_kv_heads, a.head_dim), dtype),
            "pos": jnp.full((batch, L), -1, jnp.int32),
            "state": jnp.zeros((self.n_rec, batch, self.w), jnp.float32),
            "conv": jnp.zeros((self.n_rec, batch, r.d_conv - 1, self.w), dtype),
        }

    def cache_specs(self, rules, batch_axis="data", seq_axis=None) -> Dict:
        kv, hd, f = rules.get("kv_heads"), rules.get("head_dim"), rules.get("ffn")
        return {
            "k": P(None, batch_axis, seq_axis, kv, hd),
            "v": P(None, batch_axis, seq_axis, kv, hd),
            "pos": P(batch_axis, seq_axis),
            "state": P(None, batch_axis, f),
            "conv": P(None, batch_axis, None, f),
        }

    def ckpt_cache_specs(self, rules, batch_axis="data") -> Dict:
        """Output-cache specs of decode_step (see mamba2.ckpt_cache_specs)."""
        base = self.cache_specs(rules, batch_axis)
        f = rules.get("ffn")
        return dict(base,
                    state_ckpt=P(None, batch_axis, None, f),
                    conv_ckpt=P(None, batch_axis, None, None, f))

    # ------------------------------------------------------------------
    # RG-LRU pieces

    def _gates(self, lp, xc):
        """xc: post-conv input [.., w] -> (log_a, bx) in fp32."""
        x32 = xc.astype(jnp.float32)
        r = jax.nn.sigmoid(x32 * lp["g_a"].astype(jnp.float32) + lp["b_a"].astype(jnp.float32))
        i = jax.nn.sigmoid(x32 * lp["g_x"].astype(jnp.float32) + lp["b_x"].astype(jnp.float32))
        log_a = -_C * jax.nn.softplus(lp["lam"].astype(jnp.float32)) * r
        a = jnp.exp(log_a)
        b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x32)
        return a, b

    @staticmethod
    def _conv_full(x, wk, bk):
        K = wk.shape[0]
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        return sum(xp[:, i:i + x.shape[1], :] * wk[i] for i in range(K)) + bk

    def _rec_full(self, lp, x, valid_mask, gather_idx):
        """Full-sequence recurrent mixer.  x: [B,T,d] (normed).
        Returns (out [B,T,d], lcache {state, conv})."""
        B, T, _ = x.shape
        b1 = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, lp["w_b1"]))
        b2_raw = jnp.einsum("btd,dw->btw", x, lp["w_b2"])
        xc = self._conv_full(b2_raw, lp["conv_w"], lp["conv_b"])
        a, b = self._gates(lp, xc)
        if valid_mask is not None:  # padded rows: identity element (a=1, b=0)
            a = jnp.where(valid_mask[..., None], a, 1.0)
            b = jnp.where(valid_mask[..., None], b, 0.0)
        A, Bc = jax.lax.associative_scan(
            lambda l, r_: (r_[0] * l[0], r_[0] * l[1] + r_[1]), (a, b), axis=1)
        h = Bc  # h0 = 0
        out = jnp.einsum("btw,wd->btd", (h.astype(x.dtype) * b1), lp["w_out"])
        bidx = jnp.arange(B)[:, None]
        state = jnp.take_along_axis(h, (gather_idx[:, -1:] )[..., None], axis=1)[:, 0] \
            if gather_idx is not None else h[:, -1]
        conv_rows = b2_raw[bidx, gather_idx] if gather_idx is not None \
            else b2_raw[:, T - (lp["conv_w"].shape[0] - 1):]
        return out, {"state": state, "conv": conv_rows.astype(x.dtype)}

    def _rec_step(self, lp, x, lstate, lconv):
        """Incremental recurrent mixer with per-position checkpoints.
        x: [B,T,d] normed. Returns (out, {state, conv}, ckpts)."""
        B, T, _ = x.shape
        K = lp["conv_w"].shape[0]
        w = K - 1
        b1 = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, lp["w_b1"]))
        b2_raw = jnp.einsum("btd,dw->btw", x, lp["w_b2"])
        full = jnp.concatenate([lconv, b2_raw.astype(lconv.dtype)], axis=1)
        xc = sum(full[:, w - (K - 1) + i: w - (K - 1) + i + T] * lp["conv_w"][i]
                 for i in range(K)) + lp["conv_b"]
        a, b = self._gates(lp, xc)

        def step(h, i):
            h = a[:, i] * h + b[:, i]
            return h, h

        h_fin, hs = jax.lax.scan(step, lstate, jnp.arange(T))
        h_all = jnp.moveaxis(hs, 0, 1)                          # [B,T,w]
        out = jnp.einsum("btw,wd->btd", h_all.astype(x.dtype) * b1, lp["w_out"])
        idx = jnp.arange(T)[:, None] + 1 + jnp.arange(w)[None]
        ckpts = {"state": h_all, "conv": full[:, idx]}          # [B,T,w],[B,T,w,ch]
        return out, {"state": h_fin, "conv": full[:, T:]}, ckpts

    def _mlp(self, lp, x):
        return cm.swiglu(cm.rms_norm(x, lp["mlp_norm"], self.cfg.norm_eps),
                         lp["w_gate"], lp["w_up"], lp["w_down"])

    # ------------------------------------------------------------------
    def _split(self, stacked, n_take, per_block):
        """Slice the first n_take entries of a stacked pytree into per-block
        groups: returns list of per_block trees each [n_full, ...]."""
        return [jax.tree.map(lambda p: p[j:n_take:per_block], stacked)
                for j in range(per_block)]

    def forward(self, params: Params, tokens: jax.Array,
                prefix_embeds: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
        c = self.cfg
        x = cm.embed(tokens, params["embed"])
        B, T, _ = x.shape
        x = shard(x, "data", "model", None)   # sequence-parallel residual
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

        def rec_layer(h, lp):
            o, _ = self._rec_full(lp, cm.rms_norm(h, lp["norm"], c.norm_eps), None, None)
            h = h + shard(o, "data", "model", None)
            return h + self._mlp(lp, h)

        def attn_layer(h, lp):
            hn = cm.rms_norm(h, lp["norm"], c.norm_eps)
            q = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["wq"]), positions, c.attn.rope_theta)
            k = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["wk"]), positions, c.attn.rope_theta)
            v = jnp.einsum("btd,dhk->bthk", hn, lp["wv"])
            o = cm.flash_attention_train(q, k, v, positions, positions,
                                         window=c.rglru.window)
            h = h + shard(jnp.einsum("bthk,hkd->btd", o, lp["wo"]), "data", "model", None)
            return h + self._mlp(lp, h)

        nb, rpb = self.n_full, self.rec_per_block
        rec_groups = self._split(params["rec"], nb * rpb, rpb)

        @jax.checkpoint                        # remat per superblock
        def superblock(h, xs):
            rec_ps, attn_p = xs
            for j in range(rpb):
                h = rec_layer(h, jax.tree.map(lambda p, jj=j: p[jj], rec_ps))
            return attn_layer(h, attn_p), None

        rec_stack = jax.tree.map(lambda *xs: jnp.stack(xs, 1), *rec_groups)  # [nb, rpb, ...]
        rec_stack = jax.tree.map(lambda p: jnp.moveaxis(p, 1, 1), rec_stack)
        x, _ = jax.lax.scan(
            superblock, x,
            (jax.tree.map(lambda p: jnp.moveaxis(p, 0, 0), rec_stack), params["attn"]))
        for t in range(self.n_tail):
            x = rec_layer(x, jax.tree.map(lambda p, i=nb * rpb + t: p[i], params["rec"]))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        return cm.unembed(x, params["unembed"], c.vocab_size), jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------------
    def prefill(self, params: Params, tokens: jax.Array, cache: Dict,
                prompt_lens: Optional[jax.Array] = None,
                prefix_embeds: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Dict, jax.Array]:
        c, r = self.cfg, self.cfg.rglru
        x = cm.embed(tokens, params["embed"])
        B, T, _ = x.shape
        x = shard(x, "data", None, None)
        if prompt_lens is None:
            prompt_lens = jnp.full((B,), T, jnp.int32)
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        valid = positions < prompt_lens[:, None]
        qk_pos = jnp.where(valid, positions, -1)
        L = cache["pos"].shape[1]
        rows = positions % L
        pos_arr = cache["pos"].at[jnp.arange(B)[:, None], rows].set(qk_pos, mode="drop")
        K = r.d_conv
        gather_idx = jnp.clip(prompt_lens[:, None] - (K - 1) + jnp.arange(K - 1)[None], 0, T - 1)
        conv_valid = (prompt_lens[:, None] - (K - 1) + jnp.arange(K - 1)[None]) >= 0

        nb, rpb = self.n_full, self.rec_per_block
        rec_groups = self._split(params["rec"], nb * rpb, rpb)
        rec_stack = jax.tree.map(lambda *xs: jnp.stack(xs, 1), *rec_groups)

        def rec_layer(h, lp):
            o, lc = self._rec_full(lp, cm.rms_norm(h, lp["norm"], c.norm_eps), valid, gather_idx)
            lc["conv"] = lc["conv"] * conv_valid[..., None].astype(lc["conv"].dtype)
            h = h + shard(o, "data", None, None)
            return h + self._mlp(lp, h), lc

        def attn_layer(h, lp, lk, lv):
            hn = cm.rms_norm(h, lp["norm"], c.norm_eps)
            q = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["wq"]), positions, c.attn.rope_theta)
            k = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["wk"]), positions, c.attn.rope_theta)
            v = jnp.einsum("btd,dhk->bthk", hn, lp["wv"])
            o = cm.flash_attention_tri(q, k, v, qk_pos, qk_pos, window=r.window)
            bidx = jnp.arange(B)[:, None]
            nk = lk.at[bidx, rows].set(k.astype(lk.dtype), mode="drop")
            nv = lv.at[bidx, rows].set(v.astype(lv.dtype), mode="drop")
            h = h + shard(jnp.einsum("bthk,hkd->btd", o, lp["wo"]), "data", None, None)
            return h + self._mlp(lp, h), nk, nv

        def superblock(h, xs):
            rec_ps, attn_p, lk, lv = xs
            lcs = []
            for j in range(rpb):
                h, lc = rec_layer(h, jax.tree.map(lambda p, jj=j: p[jj], rec_ps))
                lcs.append(lc)
            h, nk, nv = attn_layer(h, attn_p, lk, lv)
            lcs = jax.tree.map(lambda *ys: jnp.stack(ys, 0), *lcs)   # [rpb, ...]
            return h, (lcs, nk, nv)

        x, (rec_lcs, nk, nv) = jax.lax.scan(
            superblock, x, (rec_stack, params["attn"], cache["k"], cache["v"]))
        tail_lcs = []
        for t in range(self.n_tail):
            x, lc = rec_layer(x, jax.tree.map(lambda p, i=nb * rpb + t: p[i], params["rec"]))
            tail_lcs.append(lc)
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        last = jnp.take_along_axis(x, (prompt_lens - 1)[:, None, None], axis=1)[:, 0]
        logits = cm.unembed(last, params["unembed"], c.vocab_size)

        # reassemble [n_rec, ...] from [nb, rpb, ...] + tail
        def reasm(grouped, tails):
            flat = jnp.swapaxes(grouped, 0, 1).reshape(nb * rpb, *grouped.shape[2:])
            # interleave back: grouped[i, j] is rec index i*rpb+j -> need order by (i*rpb+j)?
            return jnp.concatenate([flat] + [t[None] for t in tails], 0)

        new_rec = jax.tree.map(
            lambda g, *ts: jnp.concatenate(
                [g.reshape(nb * rpb, *g.shape[2:])] + [t[None] for t in ts], 0),
            rec_lcs, *tail_lcs) if tail_lcs else jax.tree.map(
            lambda g: g.reshape(nb * rpb, *g.shape[2:]), rec_lcs)
        return logits, {"k": nk, "v": nv, "pos": pos_arr,
                        "state": new_rec["state"], "conv": new_rec["conv"]}, prompt_lens

    # ------------------------------------------------------------------
    def decode_step(self, params: Params, tokens: jax.Array, cache: Dict,
                    seq_lens: jax.Array) -> Tuple[jax.Array, Dict]:
        c, r = self.cfg, self.cfg.rglru
        B, T = tokens.shape
        x = cm.embed(tokens, params["embed"])
        x = shard(x, "data", None, None)
        L = cache["pos"].shape[1]
        positions = (seq_lens - 1)[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        rows = positions % L
        pos_arr = cache["pos"].at[jnp.arange(B)[:, None], rows].set(positions, mode="drop")

        nb, rpb = self.n_full, self.rec_per_block
        rec_groups = self._split(params["rec"], nb * rpb, rpb)
        rec_stack = jax.tree.map(lambda *xs: jnp.stack(xs, 1), *rec_groups)
        st_groups = self._split(cache["state"], nb * rpb, rpb)
        st_stack = jax.tree.map(lambda *xs: jnp.stack(xs, 1), *st_groups)
        cv_groups = self._split(cache["conv"], nb * rpb, rpb)
        cv_stack = jax.tree.map(lambda *xs: jnp.stack(xs, 1), *cv_groups)

        def rec_layer(h, lp, st, cv):
            o, lc, ck = self._rec_step(lp, cm.rms_norm(h, lp["norm"], c.norm_eps), st, cv)
            h = h + shard(o, "data", None, None)
            return h + self._mlp(lp, h), lc, ck

        def attn_layer(h, lp, lk, lv):
            hn = cm.rms_norm(h, lp["norm"], c.norm_eps)
            q = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["wq"]), positions, c.attn.rope_theta)
            k = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["wk"]), positions, c.attn.rope_theta)
            v = jnp.einsum("btd,dhk->bthk", hn, lp["wv"])
            bidx = jnp.arange(B)[:, None]
            nk = lk.at[bidx, rows].set(k.astype(lk.dtype), mode="drop")
            nv = lv.at[bidx, rows].set(v.astype(lv.dtype), mode="drop")
            mask = cm.position_mask(positions, pos_arr, r.window)
            o = cm.gqa_attention(q, nk, nv, mask)
            h = h + shard(jnp.einsum("bthk,hkd->btd", o, lp["wo"]), "data", None, None)
            return h + self._mlp(lp, h), nk, nv

        def superblock(h, xs):
            rec_ps, attn_p, sts, cvs, lk, lv = xs
            lcs, cks = [], []
            for j in range(rpb):
                h, lc, ck = rec_layer(h, jax.tree.map(lambda p, jj=j: p[jj], rec_ps),
                                      sts[j], cvs[j])
                lcs.append(lc); cks.append(ck)
            h, nk, nv = attn_layer(h, attn_p, lk, lv)
            stack = lambda seq: jax.tree.map(lambda *ys: jnp.stack(ys, 0), *seq)
            return h, (stack(lcs), stack(cks), nk, nv)

        x, (rec_lcs, rec_cks, nk, nv) = jax.lax.scan(
            superblock, x,
            (rec_stack, params["attn"], st_stack, cv_stack, cache["k"], cache["v"]))
        tail_lcs, tail_cks = [], []
        for t in range(self.n_tail):
            i = nb * rpb + t
            x, lc, ck = rec_layer(x, jax.tree.map(lambda p, ii=i: p[ii], params["rec"]),
                                  cache["state"][i], cache["conv"][i])
            tail_lcs.append(lc); tail_cks.append(ck)
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = cm.unembed(x, params["unembed"], c.vocab_size)

        def flatten(grouped, tails):
            return jax.tree.map(
                lambda g, *ts: jnp.concatenate(
                    [g.reshape(self.n_rec - self.n_tail, *g.shape[2:])]
                    + [tt[None] for tt in ts], 0),
                grouped, *tails) if tails else jax.tree.map(
                lambda g: g.reshape(self.n_rec, *g.shape[2:]), grouped)

        new_rec = flatten(rec_lcs, tail_lcs)
        cks = flatten(rec_cks, tail_cks)
        out_cache = {
            "k": nk, "v": nv, "pos": pos_arr,
            "state": new_rec["state"], "conv": new_rec["conv"],
            "state_ckpt": cks["state"],   # [n_rec,B,T,w]
            "conv_ckpt": cks["conv"],     # [n_rec,B,T,K-1,w]
        }
        return logits, out_cache

    @staticmethod
    def commit(cache_out: Dict, accept_idx: jax.Array) -> Dict:
        # one-hot masked sum over the s+1 checkpoint axis: GSPMD keeps it
        # local, whereas the batched gather replicated + all-reduced the
        # checkpoint stack (see mamba2.commit / EXPERIMENTS §Perf C2)
        T = cache_out["state_ckpt"].shape[2]
        onehot = (jnp.arange(T)[None] == accept_idx[:, None])    # [B, T]

        def sel(a):  # a: [nR, B, T, ...]
            oh = onehot.reshape(1, *onehot.shape,
                                *([1] * (a.ndim - 3))).astype(a.dtype)
            return (a * oh).sum(axis=2)

        return {
            "k": cache_out["k"], "v": cache_out["v"], "pos": cache_out["pos"],
            "state": sel(cache_out["state_ckpt"]),
            "conv": sel(cache_out["conv_ckpt"]),
        }
