"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060) language model.

Training / prefill use the chunked SSD algorithm (intra-chunk "attention-like"
quadratic term + inter-chunk diagonal recurrence, scanned over chunks).
Decode keeps a recurrent state per layer: state [H, P, N] + a causal-conv
buffer.

Speculative decoding on an SSM has no KV rows to mask; instead
``decode_step`` checkpoints the state after *every* verified position and
``commit`` gathers the state at the per-request acceptance index
(DESIGN §4).  Rollback is therefore exact, at O(T·state) transient memory.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, pad_vocab
from repro.models import common as cm
from repro.models.common import ParamDef
from repro.runtime.meshctx import shard

Params = Any


class Mamba2LM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.ssm is not None
        self.cfg = cfg
        s = cfg.ssm
        self.d_in = s.expand * cfg.d_model
        self.nheads = self.d_in // s.head_dim
        self.bc = s.n_groups * s.d_state         # B/C projection width (each)
        self.padded_vocab = pad_vocab(cfg.vocab_size)

    # ------------------------------------------------------------------
    def param_defs(self) -> Dict:
        c, s = self.cfg, self.cfg.ssm
        d, din, bc, H = c.d_model, self.d_in, self.bc, self.nheads
        layer = {
            "norm": ParamDef((d,), ("d_model",), init="ones", stacked=True),
            "in_z": ParamDef((d, din), ("d_model", "ssm_heads"), stacked=True),
            "in_x": ParamDef((d, din), ("d_model", "ssm_heads"), stacked=True),
            "in_b": ParamDef((d, bc), ("d_model", "conv_bc"), stacked=True),
            "in_c": ParamDef((d, bc), ("d_model", "conv_bc"), stacked=True),
            "in_dt": ParamDef((d, H), ("d_model", "ssm_heads"), stacked=True),
            "dt_bias": ParamDef((H,), ("ssm_heads",), init="zeros", stacked=True),
            "A_log": ParamDef((H,), ("ssm_heads",), init="zeros", stacked=True),
            "D": ParamDef((H,), ("ssm_heads",), init="ones", stacked=True),
            "conv_x": ParamDef((s.d_conv, din), (None, "ssm_heads"), scale=0.5, stacked=True),
            "conv_x_b": ParamDef((din,), ("ssm_heads",), init="zeros", stacked=True),
            "conv_b": ParamDef((s.d_conv, bc), (None, "conv_bc"), scale=0.5, stacked=True),
            "conv_b_b": ParamDef((bc,), ("conv_bc",), init="zeros", stacked=True),
            "conv_c": ParamDef((s.d_conv, bc), (None, "conv_bc"), scale=0.5, stacked=True),
            "conv_c_b": ParamDef((bc,), ("conv_bc",), init="zeros", stacked=True),
            "norm_y": ParamDef((din,), ("ssm_heads",), init="ones", stacked=True),
            "out": ParamDef((din, d), ("ssm_heads", "d_model"), stacked=True),
        }
        return {
            "embed": ParamDef((self.padded_vocab, c.d_model), ("vocab", "d_model"), scale=0.02),
            "final_norm": ParamDef((c.d_model,), ("d_model",), init="ones"),
            "unembed": ParamDef((self.padded_vocab, c.d_model), ("vocab", "d_model"), scale=0.02),
            "layers": layer,
        }

    def init(self, key, dtype=jnp.float32) -> Params:
        p = cm.init_params(self.param_defs(), key, self.cfg.n_layers, dtype)
        # dt bias init so softplus(dt) spans ~[1e-3, 1e-1]; A_log ~ log(1..16)
        nL, H = self.cfg.n_layers, self.nheads
        dt = jnp.exp(jnp.linspace(math.log(1e-3), math.log(1e-1), H))
        inv_softplus = jnp.log(jnp.expm1(dt))
        p["layers"]["dt_bias"] = jnp.broadcast_to(inv_softplus, (nL, H)).astype(dtype)
        p["layers"]["A_log"] = jnp.broadcast_to(
            jnp.log(jnp.linspace(1.0, 16.0, H)), (nL, H)).astype(dtype)
        return p

    def shapes(self, dtype=jnp.bfloat16) -> Params:
        return cm.param_shapes(self.param_defs(), self.cfg.n_layers, dtype)

    def specs(self, rules) -> Params:
        return cm.param_specs(self.param_defs(), rules)

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int = 0, dtype=jnp.float32) -> Dict:
        c, s = self.cfg, self.cfg.ssm
        nL, H, Pd, N = c.n_layers, self.nheads, s.head_dim, s.d_state
        w = s.d_conv - 1
        return {
            "state": jnp.zeros((nL, batch, H, Pd, N), jnp.float32),
            "conv_x": jnp.zeros((nL, batch, w, self.d_in), dtype),
            "conv_b": jnp.zeros((nL, batch, w, self.bc), dtype),
            "conv_c": jnp.zeros((nL, batch, w, self.bc), dtype),
        }

    def cache_specs(self, rules, batch_axis="data", seq_axis=None) -> Dict:
        h = rules.get("ssm_heads")
        return {
            "state": P(None, batch_axis, h, None, None),
            "conv_x": P(None, batch_axis, None, h),
            "conv_b": P(None, batch_axis, None, None),
            "conv_c": P(None, batch_axis, None, None),
        }

    def ckpt_cache_specs(self, rules, batch_axis="data") -> Dict:
        """Output-cache specs of decode_step (per-position checkpoints).
        Explicit so pjit never replicates the [nL,B,T,H,P,N] checkpoint
        stack (compiler-chosen output shardings did exactly that at small
        depths, poisoning collective extrapolation — EXPERIMENTS §Perf C1)."""
        h = rules.get("ssm_heads")
        return {
            "state": P(None, batch_axis, h, None, None),
            "state_ckpt": P(None, batch_axis, None, h, None, None),
            "conv_x_ckpt": P(None, batch_axis, None, None, h),
            "conv_b_ckpt": P(None, batch_axis, None, None, None),
            "conv_c_ckpt": P(None, batch_axis, None, None, None),
        }

    # ------------------------------------------------------------------
    # pieces

    @staticmethod
    def _conv_full(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
        """Causal depthwise conv over time. x: [B,T,C]; w: [K,C]."""
        K = w.shape[0]
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
        return jax.nn.silu(out + b)

    def _proj_in(self, lp: Dict, x: jax.Array):
        z = jnp.einsum("btd,de->bte", x, lp["in_z"])
        xc = jnp.einsum("btd,de->bte", x, lp["in_x"])
        bc_ = jnp.einsum("btd,de->bte", x, lp["in_b"])
        cc = jnp.einsum("btd,de->bte", x, lp["in_c"])
        dt = jnp.einsum("btd,dh->bth", x, lp["in_dt"])
        return z, xc, bc_, cc, dt

    def _ssd_chunked(self, lp: Dict, xh, B_, C_, dt, h0):
        """Chunked SSD scan.

        xh: [B,T,H,P]; B_/C_: [B,T,G,N]; dt: [B,T,H] (>=0, already softplus,
        zeroed on padding); h0: [B,H,P,N].  Returns (y [B,T,H,P], h_final).
        """
        c, s = self.cfg, self.cfg.ssm
        Bsz, T, H, Pd = xh.shape
        G, N = B_.shape[2], B_.shape[3]
        Q = min(s.chunk, T)
        while T % Q:          # largest divisor of T that is <= chunk
            Q -= 1
        nc = T // Q
        A = jnp.exp(lp["A_log"].astype(jnp.float32))              # [H]
        l = -dt * A                                               # [B,T,H] log-decay
        rep = H // G

        xq = xh.reshape(Bsz, nc, Q, H, Pd)
        Bq = B_.reshape(Bsz, nc, Q, G, N)
        Cq = C_.reshape(Bsz, nc, Q, G, N)
        dtq = dt.reshape(Bsz, nc, Q, H)
        lq = l.reshape(Bsz, nc, Q, H)

        def chunk(h, xs):
            xc_, bb, cc, dtc, lc = xs                             # [B,Q,...]
            cs = jnp.cumsum(lc, axis=1)                           # [B,Q,H] inclusive
            # intra-chunk: M[i,j] = (C_i·B_j) exp(cs_i - cs_j) dt_j, i>=j
            bbh = jnp.repeat(bb, rep, axis=2)                     # [B,Q,H,N]
            cch = jnp.repeat(cc, rep, axis=2)
            cb = jnp.einsum("bihn,bjhn->bhij", cch, bbh)
            dec = cs[:, :, None, :] - cs[:, None, :, :]           # [B,i,j,H]
            mask = jnp.tril(jnp.ones((Q, Q), bool))
            dec = jnp.where(mask[None, :, :, None], dec, -jnp.inf)
            M = cb * jnp.exp(dec).transpose(0, 3, 1, 2)           # [B,H,i,j]
            y_in = jnp.einsum("bhij,bjh,bjhp->bihp", M, dtc, xc_.astype(jnp.float32))
            # inter-chunk: contribution of carried-in state
            y_h = jnp.einsum("bihn,bhpn->bihp", cch * jnp.exp(cs)[:, :, :, None], h)
            # new carried state
            decay_end = jnp.exp(cs[:, -1:, :] - cs)               # [B,Q,H]
            contrib = jnp.einsum("bjh,bjhp,bjhn->bhpn",
                                 dtc * decay_end, xc_.astype(jnp.float32), bbh)
            h_new = jnp.exp(cs[:, -1])[:, :, None, None] * h + contrib
            return h_new, (y_in + y_h)

        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (xq, Bq, Cq, dtq, lq))
        h_fin, ys = jax.lax.scan(chunk, h0.astype(jnp.float32), xs)
        y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, T, H, Pd)
        return y, h_fin

    def _layer_full(self, lp: Dict, x: jax.Array, h0, dt_mask=None):
        """Full-sequence mixer. x: [B,T,d] (normed). Returns (out, h_final)."""
        c, s = self.cfg, self.cfg.ssm
        Bsz, T, _ = x.shape
        z, xc, bb, cc, dt = self._proj_in(lp, x)
        xc = self._conv_full(xc, lp["conv_x"], lp["conv_x_b"])
        bb = self._conv_full(bb, lp["conv_b"], lp["conv_b_b"])
        cc = self._conv_full(cc, lp["conv_c"], lp["conv_c_b"])
        dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
        if dt_mask is not None:
            dt = dt * dt_mask
        xh = xc.reshape(Bsz, T, self.nheads, s.head_dim)
        Bm = bb.reshape(Bsz, T, s.n_groups, s.d_state)
        Cm = cc.reshape(Bsz, T, s.n_groups, s.d_state)
        y, h_fin = self._ssd_chunked(lp, xh, Bm, Cm, dt, h0)
        y = y + lp["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(Bsz, T, self.d_in).astype(x.dtype)
        y = cm.rms_norm(y * jax.nn.silu(z), lp["norm_y"], c.norm_eps)
        return jnp.einsum("bte,ed->btd", y, lp["out"]), h_fin

    # ------------------------------------------------------------------
    def forward(self, params: Params, tokens: jax.Array,
                prefix_embeds: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
        c = self.cfg
        x = cm.embed(tokens, params["embed"])
        B, T, _ = x.shape
        x = shard(x, "data", "model", None)   # sequence-parallel residual
        h0 = jnp.zeros((B, self.nheads, c.ssm.head_dim, c.ssm.d_state), jnp.float32)

        @jax.checkpoint                        # remat per layer
        def layer(h, lp):
            out, _ = self._layer_full(lp, cm.rms_norm(h, lp["norm"], c.norm_eps), h0)
            return h + shard(out, "data", "model", None), None

        x, _ = jax.lax.scan(layer, x, params["layers"])
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        return cm.unembed(x, params["unembed"], c.vocab_size), jnp.zeros((), jnp.float32)

    def prefill(self, params: Params, tokens: jax.Array, cache: Dict,
                prompt_lens: Optional[jax.Array] = None,
                prefix_embeds: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Dict, jax.Array]:
        """Ragged prompts: positions >= prompt_lens contribute nothing
        (dt masked to 0) so the carried state is exact per request."""
        c, s = self.cfg, self.cfg.ssm
        x = cm.embed(tokens, params["embed"])
        B, T, _ = x.shape
        x = shard(x, "data", None, None)
        if prompt_lens is None:
            prompt_lens = jnp.full((B,), T, jnp.int32)
        pos = jnp.arange(T, dtype=jnp.int32)[None]
        dt_mask = (pos < prompt_lens[:, None]).astype(jnp.float32)[..., None]  # [B,T,1]
        h0 = jnp.zeros((B, self.nheads, s.head_dim, s.d_state), jnp.float32)
        w = s.d_conv - 1
        # conv buffers: last w *valid* raw inputs per request -> gather rows
        gather_idx = jnp.clip(prompt_lens[:, None] - w + jnp.arange(w)[None], 0, T - 1)

        def layer(h, lp):
            hn = cm.rms_norm(h, lp["norm"], c.norm_eps)
            # recompute raw conv inputs for the cache (cheap projections)
            _, xc_raw, bb_raw, cc_raw, _ = self._proj_in(lp, hn)
            out, h_fin = self._layer_full(lp, hn, h0, dt_mask=dt_mask)
            bidx = jnp.arange(B)[:, None]
            lcache = {
                "state": h_fin,
                "conv_x": xc_raw[bidx, gather_idx],
                "conv_b": bb_raw[bidx, gather_idx],
                "conv_c": cc_raw[bidx, gather_idx],
            }
            return h + shard(out, "data", None, None), lcache

        x, new_cache = jax.lax.scan(layer, x, params["layers"])
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        last = jnp.take_along_axis(x, (prompt_lens - 1)[:, None, None], axis=1)[:, 0]
        logits = cm.unembed(last, params["unembed"], c.vocab_size)
        # zero conv rows that fall before position 0 (short prompts)
        valid = (prompt_lens[:, None] - w + jnp.arange(w)[None]) >= 0   # [B,w]
        for k in ("conv_x", "conv_b", "conv_c"):
            new_cache[k] = new_cache[k] * valid[None, :, :, None].astype(new_cache[k].dtype)
        return logits, new_cache, prompt_lens

    # ------------------------------------------------------------------
    def decode_step(self, params: Params, tokens: jax.Array, cache: Dict,
                    seq_lens: jax.Array) -> Tuple[jax.Array, Dict]:
        """T-token incremental step with per-position state checkpoints."""
        c, s = self.cfg, self.cfg.ssm
        B, T = tokens.shape
        x = cm.embed(tokens, params["embed"])
        x = shard(x, "data", None, None)
        w = s.d_conv - 1
        H, Pd, N = self.nheads, s.head_dim, s.d_state

        def layer(h, xs):
            lp, lc = xs
            hn = cm.rms_norm(h, lp["norm"], c.norm_eps)
            z, xc_raw, bb_raw, cc_raw, dt = self._proj_in(lp, hn)
            # conv over [cached w rows | T new rows]
            full_x = jnp.concatenate([lc["conv_x"], xc_raw.astype(lc["conv_x"].dtype)], axis=1)
            full_b = jnp.concatenate([lc["conv_b"], bb_raw.astype(lc["conv_b"].dtype)], axis=1)
            full_c = jnp.concatenate([lc["conv_c"], cc_raw.astype(lc["conv_c"].dtype)], axis=1)

            def conv_at(full, wk, bk):
                K = wk.shape[0]
                out = sum(full[:, w - (K - 1) + i: w - (K - 1) + i + T] * wk[i]
                          for i in range(K))
                return jax.nn.silu(out + bk)

            xc = conv_at(full_x, lp["conv_x"], lp["conv_x_b"])
            bb = conv_at(full_b, lp["conv_b"], lp["conv_b_b"])
            cc = conv_at(full_c, lp["conv_c"], lp["conv_c_b"])
            dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
            A = jnp.exp(lp["A_log"].astype(jnp.float32))
            xh = xc.reshape(B, T, H, Pd).astype(jnp.float32)
            Bm = jnp.repeat(bb.reshape(B, T, s.n_groups, N), H // s.n_groups, 2)
            Cm = jnp.repeat(cc.reshape(B, T, s.n_groups, N), H // s.n_groups, 2)

            def step(hstate, i):
                a = jnp.exp(-dt[:, i] * A)                        # [B,H]
                contrib = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, i],
                                     xh[:, i], Bm[:, i].astype(jnp.float32))
                hstate = a[:, :, None, None] * hstate + contrib
                y_i = jnp.einsum("bhn,bhpn->bhp", Cm[:, i].astype(jnp.float32), hstate)
                return hstate, (y_i, hstate)

            h_fin, (ys, ckpts) = jax.lax.scan(step, lc["state"], jnp.arange(T))
            y = jnp.moveaxis(ys, 0, 1)                            # [B,T,H,P]
            state_ckpt = jnp.moveaxis(ckpts, 0, 1)                # [B,T,H,P,N]
            y = y + lp["D"].astype(jnp.float32)[None, None, :, None] * xh
            y = y.reshape(B, T, self.d_in).astype(x.dtype)
            y = cm.rms_norm(y * jax.nn.silu(z), lp["norm_y"], c.norm_eps)
            out = jnp.einsum("bte,ed->btd", y, lp["out"])
            # conv checkpoints: the w raw rows ending at each position
            idx = jnp.arange(T)[:, None] + 1 + jnp.arange(w)[None]  # [T,w] into full
            new_lc = {
                "state": h_fin,
                "conv_x": full_x[:, idx],    # placeholder; real per-pos ckpt below
                "conv_b": full_b[:, idx],
                "conv_c": full_c[:, idx],
            }
            # new_lc conv entries are [B,T,w,ch] checkpoints; 'state' final.
            return h + shard(out, "data", None, None), (new_lc, state_ckpt)

        layer_caches = {k: cache[k] for k in ("state", "conv_x", "conv_b", "conv_c")}
        x, (new_lcs, state_ckpts) = jax.lax.scan(layer, x, (params["layers"], layer_caches))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = cm.unembed(x, params["unembed"], c.vocab_size)
        out_cache = {
            "state": new_lcs["state"],            # [nL,B,H,P,N] (all T applied)
            "state_ckpt": state_ckpts,            # [nL,B,T,H,P,N]
            "conv_x_ckpt": new_lcs["conv_x"],     # [nL,B,T,w,ch]
            "conv_b_ckpt": new_lcs["conv_b"],
            "conv_c_ckpt": new_lcs["conv_c"],
        }
        return logits, out_cache

    @staticmethod
    def commit(cache_out: Dict, accept_idx: jax.Array) -> Dict:
        """Select the checkpoint at ``accept_idx`` [B] per request.

        Implemented as a one-hot masked sum over the (tiny, s+1-long) T axis
        rather than an advanced-indexing gather: GSPMD partitions the
        elementwise+reduce form locally, whereas the batched gather fell back
        to replicate-and-all-reduce of the whole checkpoint stack
        (EXPERIMENTS §Perf C2: 826 MB -> ~0 of per-step all-reduce)."""
        T = cache_out["state_ckpt"].shape[2]
        onehot = (jnp.arange(T)[None] == accept_idx[:, None])    # [B, T]

        def sel(a):  # a: [nL, B, T, ...]
            oh = onehot.reshape(1, *onehot.shape,
                                *([1] * (a.ndim - 3))).astype(a.dtype)
            return (a * oh).sum(axis=2)

        return {
            "state": sel(cache_out["state_ckpt"]),
            "conv_x": sel(cache_out["conv_x_ckpt"]),
            "conv_b": sel(cache_out["conv_b_ckpt"]),
            "conv_c": sel(cache_out["conv_c_ckpt"]),
        }
