"""Shared model machinery: ParamDef registry, sharding rules, norms, RoPE,
attention (reference and chunked-flash), embeddings.

Models in this package are *pure functions* over parameter pytrees.  Each
model exposes ``param_defs(cfg)`` returning a nested dict of :class:`ParamDef`;
from that single source of truth we derive initialized parameters, partition
specs and ShapeDtypeStructs (for the allocation-free dry-run).

Layer parameters are *stacked* along a leading ``n_layers`` axis and the
forward pass scans over them (``jax.lax.scan``), so HLO size and compile time
are depth-independent.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import AttnConfig, ModelConfig

Params = Any  # nested dict of jnp.ndarray


# ---------------------------------------------------------------------------
# ParamDef


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (no stacked dim)
    init: str = "normal"              # normal | zeros | ones
    scale: Optional[float] = None     # stddev for "normal" (default fan-in)
    stacked: bool = False             # leading n_layers dim added implicitly

    def full_shape(self, n_layers: int) -> Tuple[int, ...]:
        return (n_layers, *self.shape) if self.stacked else self.shape


def _iter_defs(defs: Dict, prefix=()):
    for k, v in defs.items():
        if isinstance(v, ParamDef):
            yield (*prefix, k), v
        else:
            yield from _iter_defs(v, (*prefix, k))


def _set_nested(tree: Dict, path, value):
    for k in path[:-1]:
        tree = tree.setdefault(k, {})
    tree[path[-1]] = value


def init_params(defs: Dict, key: jax.Array, n_layers: int, dtype=jnp.float32) -> Params:
    """Initialize a parameter pytree from defs (deterministic per path)."""
    out: Dict = {}
    flat = list(_iter_defs(defs))
    keys = jax.random.split(key, max(len(flat), 1))
    for (path, d), k in zip(flat, keys):
        shape = d.full_shape(n_layers)
        if d.init == "zeros":
            arr = jnp.zeros(shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(shape, dtype)
        else:
            fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[-1], 1)
            scale = d.scale if d.scale is not None else 1.0 / math.sqrt(fan_in)
            arr = (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)
        _set_nested(out, path, arr)
    return out


def param_shapes(defs: Dict, n_layers: int, dtype=jnp.bfloat16) -> Params:
    out: Dict = {}
    for path, d in _iter_defs(defs):
        _set_nested(out, path, jax.ShapeDtypeStruct(d.full_shape(n_layers), dtype))
    return out


def param_specs(defs: Dict, rules: Dict[str, Optional[str]]) -> Params:
    """PartitionSpec pytree from logical-axis rules ({logical: mesh_axis|None}).

    A mesh axis may appear at most once per tensor; when two logical axes of
    one tensor map to the same mesh axis (e.g. MLA's ``lora`` and ``heads``
    both on 'model'), the first occurrence wins and later ones replicate.
    """
    out: Dict = {}
    for path, d in _iter_defs(defs):
        axes = []
        seen = set()
        for a in d.axes:
            m = rules.get(a) if a else None
            if m is not None:
                parts = tuple(m) if isinstance(m, (tuple, list)) else (m,)
                kept = tuple(p for p in parts if p not in seen)
                seen.update(kept)
                m = (kept if len(kept) > 1 else kept[0] if kept else None)
            axes.append(m)
        if d.stacked:
            axes = [None, *axes]
        _set_nested(out, path, P(*axes))
    return out


def param_count_tree(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# sharding rules


def resolve_rules(cfg: ModelConfig, model_axis_size: int,
                  overrides: Optional[Dict[str, Optional[str]]] = None,
                  ) -> Dict[str, Optional[str]]:
    """Map logical parameter axes to mesh axes, with divisibility fallbacks.

    Attention sharding mode:
      * ``kv_head``  -- shard q-heads and kv-heads on 'model' (needs both divisible)
      * ``head_dim`` -- shard the head_dim (and MLA lora dim) on 'model';
                        heads replicated; induces a partial-score all-reduce.
    """
    m = "model"
    rules: Dict[str, Optional[str]] = {
        "vocab": m, "d_model": None, "ffn": m, "experts": m,
        "expert_ff": None,          # hillclimb lever: "data" = FSDP experts
        "heads": None, "kv_heads": None, "head_dim": None,
        "lora": None, "rope_dim": None,
        "ssm_heads": m, "state": None, "conv_bc": None,
    }
    a = cfg.attn
    if a is not None:
        q_ok = a.n_heads % model_axis_size == 0
        kv_ok = a.n_kv_heads % model_axis_size == 0
        if a.kind == "mla":
            # shard q heads if possible; shard the compressed-kv (lora) dim
            rules["heads"] = m if q_ok else None
            rules["lora"] = m if a.kv_lora_rank % model_axis_size == 0 else None
        elif q_ok and kv_ok:
            rules["heads"] = m
            rules["kv_heads"] = m
        elif a.head_dim % model_axis_size == 0:
            rules["head_dim"] = m          # fallback: shard the reduction dim
        elif q_ok:
            rules["heads"] = m             # replicate kv entirely
    if cfg.ssm is not None:
        d_in = cfg.ssm.expand * cfg.d_model
        nheads = d_in // cfg.ssm.head_dim
        rules["ssm_heads"] = m if nheads % model_axis_size == 0 else None
    if overrides:
        rules.update(overrides)
    return rules


def attn_mode(cfg: ModelConfig, model_axis_size: int) -> str:
    a = cfg.attn
    if a is None:
        return "none"
    if a.kind == "mla":
        return "mla"
    if a.n_heads % model_axis_size == 0 and a.n_kv_heads % model_axis_size == 0:
        return "kv_head"
    if a.head_dim % model_axis_size == 0:
        return "head_dim"
    return "replicate_kv"


# ---------------------------------------------------------------------------
# basic layers


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., T, n_heads, head_dim]; positions: broadcastable to [..., T]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention masks
#
# Mask semantics are position-based so that ragged batches and ring-buffer
# KV caches share one implementation (DESIGN §3): a key row is attendable iff
#   k_abs <= q_abs  and  k_abs > q_abs - window  and  k_abs >= 0 (written)
# plus an optional bidirectional prefix (PaliGemma): OR (k_abs < prefix_len
# and k_abs valid).


def position_mask(q_pos: jax.Array, k_pos: jax.Array, window: Optional[int],
                  prefix_len: int = 0) -> jax.Array:
    """q_pos: [..., Tq]; k_pos: [..., Tk] absolute positions (-1 = unwritten).
    Returns bool [..., Tq, Tk]."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    ok = (k >= 0) & (k <= q)
    if window is not None:
        ok &= k > q - window
    if prefix_len:
        ok |= (k >= 0) & (k < prefix_len)
    return ok


# ---------------------------------------------------------------------------
# attention computation


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
                  scale: Optional[float] = None) -> jax.Array:
    """Reference grouped-query attention.

    q: [B, Tq, H, hd]; k/v: [B, Tk, KVH, hd]; mask: [B, Tq, Tk] bool.
    Returns [B, Tq, H, hd].  Computes the full score matrix (memory O(Tq·Tk));
    use :func:`flash_attention_tri` for long sequences.
    """
    B, Tq, H, hd = q.shape
    KVH, vd = k.shape[2], v.shape[-1]
    G = H // KVH
    qg = q.reshape(B, Tq, KVH, G, hd)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", p.astype(v.dtype), v)
    return out.reshape(B, Tq, H, vd)


def flash_attention_tri(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_pos: jax.Array, k_pos: jax.Array,
                        window: Optional[int] = None, prefix_len: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        scale: Optional[float] = None) -> jax.Array:
    """Causal flash attention in pure jnp, scanning only the lower-triangular
    (q-block, k-block) pairs so compiled FLOPs are causal-optimal (~L²/2).

    Shapes as :func:`gqa_attention`; q_pos/k_pos: [B, Tq]/[B, Tk] absolute
    positions.  Online-softmax accumulation in fp32.
    """
    B, Tq, H, hd = q.shape
    Tk, KVH, vd = k.shape[1], k.shape[2], v.shape[-1]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    def fit(block, n):
        block = min(block, n)
        while n % block:
            block -= 1
        return block

    block_q, block_k = fit(block_q, Tq), fit(block_k, Tk)
    nq, nk = Tq // block_q, Tk // block_k

    # static list of blocks to visit: for self-attention with aligned q/k
    # (Tq == Tk) only the lower triangle (plus bidirectional-prefix blocks);
    # otherwise all pairs (masked).
    if Tq == Tk:
        def want(i, j):
            if prefix_len and j * block_k < prefix_len:
                return True
            if j > i:
                return False
            if window is not None:
                return j >= i - (-(-window // block_k) + 1)
            return True
        pairs = [(i, j) for i in range(nq) for j in range(nk) if want(i, j)]
    else:
        pairs = [(i, j) for i in range(nq) for j in range(nk)]
    pairs = jnp.asarray(pairs, jnp.int32)  # [n_pairs, 2], ordered by i then j

    qg = q.reshape(B, nq, block_q, KVH, G, hd)
    kb = k.reshape(B, nk, block_k, KVH, hd)
    vb = v.reshape(B, nk, block_k, KVH, vd)
    qp = q_pos.reshape(B, nq, block_q)
    kp = k_pos.reshape(B, nk, block_k)

    acc0 = jnp.zeros((B, nq, block_q, KVH, G, vd), jnp.float32)
    m0 = jnp.full((B, nq, block_q, KVH, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nq, block_q, KVH, G), jnp.float32)

    def body(carry, pair):
        acc, m, l = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False)   # [B,bq,KVH,G,hd]
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)   # [B,bk,KVH,hd]
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        qpi = jax.lax.dynamic_index_in_dim(qp, i, 1, keepdims=False)  # [B,bq]
        kpj = jax.lax.dynamic_index_in_dim(kp, j, 1, keepdims=False)  # [B,bk]
        s = jnp.einsum("bqkgh,bskh->bqkgs", qi, kj).astype(jnp.float32) * scale
        msk = position_mask(qpi, kpj, window, prefix_len)             # [B,bq,bk]
        s = jnp.where(msk[:, :, None, None, :], s, -jnp.inf)
        mi = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        acci = jax.lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(msk[:, :, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isneginf(mi), 0.0, jnp.exp(mi - m_safe))
        l_new = li * corr + p.sum(axis=-1)
        acc_new = acci * corr[..., None] + jnp.einsum(
            "bqkgs,bskh->bqkgh", p, vj.astype(jnp.float32))
        return (jax.lax.dynamic_update_index_in_dim(acc, acc_new, i, 1),
                jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1),
                jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), pairs)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Tq, H, vd).astype(q.dtype)


def flash_attention_train(q: jax.Array, k: jax.Array, v: jax.Array,
                          q_pos: jax.Array, k_pos: jax.Array,
                          window: Optional[int] = None, prefix_len: int = 0,
                          block_q: int = 512,
                          scale: Optional[float] = None) -> jax.Array:
    """Training-path attention: scan over q blocks, each block's body
    rematerialized (jax.checkpoint), scoring against ALL keys with the
    position mask.

    Memory-optimal for the backward pass: blocks are independent (no online
    softmax carry), so reverse-mode saves only per-block outputs — the
    per-pair residuals that make :func:`flash_attention_tri` untrainable at
    32k vanish.  The cost: masked-out upper-triangle scores are still
    computed (~2x causal-optimal FLOPs on the score term; the TPU Pallas
    kernel and the tri variant exploit causality — a documented trade-off in
    launch/costs.py, and a Perf-loop lever).
    """
    B, Tq, H, hd = q.shape
    Tk, KVH, vd = k.shape[1], k.shape[2], v.shape[-1]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    bq = min(block_q, Tq)
    while Tq % bq:
        bq -= 1
    nq = Tq // bq
    qb = q.reshape(B, nq, bq, KVH, G, hd)
    qp = q_pos.reshape(B, nq, bq)

    @jax.checkpoint
    def block(args):
        qi, qpi = args                                   # [B,bq,KVH,G,hd], [B,bq]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qi, k).astype(jnp.float32) * scale
        msk = position_mask(qpi, k_pos, window, prefix_len)   # [B,bq,Tk]
        s = jnp.where(msk[:, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(msk[:, None, None].any(-1, keepdims=True), p, 0.0)
        return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)

    out = jax.lax.map(block, (jnp.moveaxis(qb, 1, 0), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Tq, H, vd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# embeddings


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array, true_vocab: int) -> jax.Array:
    """Logits with padded vocab ids masked to -inf."""
    logits = jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)
    v = table.shape[0]
    if true_vocab < v:
        mask = jnp.arange(v) < true_vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits
