"""Encoder-decoder transformer backbone (SeamlessM4T-large-v2 assignment,
arXiv:2308.11596).  The modality frontend is a stub per the assignment: the
encoder consumes precomputed frame embeddings ``src_embeds`` [B, S, d]
(``input_specs`` provides ShapeDtypeStructs of the right shape).

Decoder = causal self-attention (ring-buffer KV cache, speculative rollback
free) + cross-attention to the encoder output (cross-KV computed once at
prefill, never rolled back) + SwiGLU MLP.

Bidirectional/cross visibility reuses the position-mask machinery: encoder
self-attention and cross-attention pass ``q_pos = S`` (a constant at least as
large as every key position) so ``k_pos <= q_pos`` admits all valid keys,
while padded source rows carry ``k_pos = -1`` and stay masked.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, pad_vocab
from repro.models import common as cm
from repro.models.common import ParamDef
from repro.runtime.meshctx import shard

Params = Any


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.attn is not None and cfg.enc_layers > 0
        self.cfg = cfg
        self.padded_vocab = pad_vocab(cfg.vocab_size)

    # ------------------------------------------------------------------
    def _attn_defs(self, rope_on_kv: bool = True) -> Dict[str, ParamDef]:
        a, d = self.cfg.attn, self.cfg.d_model
        return {
            "wq": ParamDef((d, a.n_heads, a.head_dim), ("d_model", "heads", "head_dim"), stacked=True),
            "wk": ParamDef((d, a.n_kv_heads, a.head_dim), ("d_model", "kv_heads", "head_dim"), stacked=True),
            "wv": ParamDef((d, a.n_kv_heads, a.head_dim), ("d_model", "kv_heads", "head_dim"), stacked=True),
            "wo": ParamDef((a.n_heads, a.head_dim, d), ("heads", "head_dim", "d_model"), stacked=True),
        }

    def _mlp_defs(self) -> Dict[str, ParamDef]:
        c = self.cfg
        return {
            "w_gate": ParamDef((c.d_model, c.d_ff), ("d_model", "ffn"), stacked=True),
            "w_up": ParamDef((c.d_model, c.d_ff), ("d_model", "ffn"), stacked=True),
            "w_down": ParamDef((c.d_ff, c.d_model), ("ffn", "d_model"), stacked=True),
        }

    def param_defs(self) -> Dict:
        c = self.cfg
        d = c.d_model
        norm = lambda: ParamDef((d,), ("d_model",), init="ones", stacked=True)
        enc = {"attn_norm": norm(), "mlp_norm": norm(), **self._attn_defs(), **self._mlp_defs()}
        dec = {
            "self_norm": norm(), "cross_norm": norm(), "mlp_norm": norm(),
            **{f"self_{k}": v for k, v in self._attn_defs().items()},
            **{f"cross_{k}": v for k, v in self._attn_defs().items()},
            **self._mlp_defs(),
        }
        return {
            "embed": ParamDef((self.padded_vocab, d), ("vocab", "d_model"), scale=0.02),
            "enc_final_norm": ParamDef((d,), ("d_model",), init="ones"),
            "final_norm": ParamDef((d,), ("d_model",), init="ones"),
            "unembed": ParamDef((self.padded_vocab, d), ("vocab", "d_model"), scale=0.02),
            "enc": enc,   # stacked enc_layers
            "dec": dec,   # stacked n_layers
        }

    def init(self, key, dtype=jnp.float32) -> Params:
        defs = self.param_defs()
        top = cm.init_params({k: v for k, v in defs.items() if isinstance(v, ParamDef)},
                             jax.random.fold_in(key, 0), 0, dtype)
        enc = cm.init_params(defs["enc"], jax.random.fold_in(key, 1), self.cfg.enc_layers, dtype)
        dec = cm.init_params(defs["dec"], jax.random.fold_in(key, 2), self.cfg.n_layers, dtype)
        return dict(top, enc=enc, dec=dec)

    def shapes(self, dtype=jnp.bfloat16) -> Params:
        defs = self.param_defs()
        out = cm.param_shapes({k: v for k, v in defs.items() if isinstance(v, ParamDef)}, 0, dtype)
        out["enc"] = cm.param_shapes(defs["enc"], self.cfg.enc_layers, dtype)
        out["dec"] = cm.param_shapes(defs["dec"], self.cfg.n_layers, dtype)
        return out

    def specs(self, rules) -> Params:
        defs = self.param_defs()
        out = cm.param_specs({k: v for k, v in defs.items() if isinstance(v, ParamDef)}, rules)
        out["enc"] = cm.param_specs(defs["enc"], rules)
        out["dec"] = cm.param_specs(defs["dec"], rules)
        return out

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, dtype=jnp.float32,
                   src_len: int = 0) -> Dict:
        c, a = self.cfg, self.cfg.attn
        L = min(cache_len, a.window) if a.window else cache_len
        return {
            "k": jnp.zeros((c.n_layers, batch, L, a.n_kv_heads, a.head_dim), dtype),
            "v": jnp.zeros((c.n_layers, batch, L, a.n_kv_heads, a.head_dim), dtype),
            "pos": jnp.full((batch, L), -1, jnp.int32),
            "xk": jnp.zeros((c.n_layers, batch, src_len, a.n_kv_heads, a.head_dim), dtype),
            "xv": jnp.zeros((c.n_layers, batch, src_len, a.n_kv_heads, a.head_dim), dtype),
            "xpos": jnp.full((batch, src_len), -1, jnp.int32),
        }

    def cache_specs(self, rules, batch_axis="data", seq_axis=None) -> Dict:
        kv, hd = rules.get("kv_heads"), rules.get("head_dim")
        return {
            "k": P(None, batch_axis, seq_axis, kv, hd),
            "v": P(None, batch_axis, seq_axis, kv, hd),
            "pos": P(batch_axis, seq_axis),
            "xk": P(None, batch_axis, None, kv, hd),
            "xv": P(None, batch_axis, None, kv, hd),
            "xpos": P(batch_axis, None),
        }

    # ------------------------------------------------------------------
    def encode(self, params: Params, src_embeds: jax.Array,
               src_lens: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
        """Returns (enc_out [B,S,d], src_pos [B,S] with -1 padding)."""
        c = self.cfg
        B, S, _ = src_embeds.shape
        x = shard(src_embeds.astype(jnp.dtype(c.dtype) if isinstance(c.dtype, str) else c.dtype),
                  "data", None, None)
        if src_lens is None:
            src_lens = jnp.full((B,), S, jnp.int32)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        src_pos = jnp.where(pos < src_lens[:, None], pos, -1)
        full_q = jnp.full((B, S), S, jnp.int32)  # bidirectional: see module docstring

        @jax.checkpoint                        # remat per layer
        def layer(h, lp):
            hn = cm.rms_norm(h, lp["attn_norm"], c.norm_eps)
            q = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["wq"]), pos, c.attn.rope_theta)
            k = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["wk"]), pos, c.attn.rope_theta)
            v = jnp.einsum("btd,dhk->bthk", hn, lp["wv"])
            # bidirectional: must visit ALL (q, k) blocks — the triangular
            # tri variant would silently skip the upper half
            o = cm.flash_attention_train(q, k, v, full_q, src_pos)
            h = h + shard(jnp.einsum("bthk,hkd->btd", o, lp["wo"]), "data", None, None)
            m = cm.swiglu(cm.rms_norm(h, lp["mlp_norm"], c.norm_eps),
                          lp["w_gate"], lp["w_up"], lp["w_down"])
            return h + shard(m, "data", None, None), None

        x, _ = jax.lax.scan(layer, x, params["enc"])
        return cm.rms_norm(x, params["enc_final_norm"], c.norm_eps), src_pos

    def _cross(self, lp, hn, xk, xv, xpos):
        """Cross-attention of decoder states hn [B,T,d] over cached encoder KV."""
        B, T, _ = hn.shape
        q = jnp.einsum("btd,dhk->bthk", hn, lp["cross_wq"])
        S = xk.shape[1]
        full_q = jnp.full((B, T), S, jnp.int32)
        mask = cm.position_mask(full_q, xpos, None)
        o = cm.gqa_attention(q, xk, xv, mask)
        return jnp.einsum("bthk,hkd->btd", o, lp["cross_wo"])

    # ------------------------------------------------------------------
    def forward(self, params: Params, tokens: jax.Array,
                src_embeds: Optional[jax.Array] = None,
                src_lens: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
        """Training forward: encode src, decode tgt causally. Returns logits."""
        c = self.cfg
        enc_out, src_pos = self.encode(params, src_embeds, src_lens)
        x = cm.embed(tokens, params["embed"])
        B, T, _ = x.shape
        x = shard(x, "data", "model", None)   # sequence-parallel residual
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

        @jax.checkpoint                        # remat per layer
        def layer(h, lp):
            hn = cm.rms_norm(h, lp["self_norm"], c.norm_eps)
            q = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["self_wq"]), pos, c.attn.rope_theta)
            k = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["self_wk"]), pos, c.attn.rope_theta)
            v = jnp.einsum("btd,dhk->bthk", hn, lp["self_wv"])
            o = cm.flash_attention_train(q, k, v, pos, pos, window=c.attn.window)
            h = h + shard(jnp.einsum("bthk,hkd->btd", o, lp["self_wo"]), "data", "model", None)
            hn = cm.rms_norm(h, lp["cross_norm"], c.norm_eps)
            xk = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_wk"])
            xv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_wv"])
            h = h + shard(self._cross(lp, hn, xk, xv, src_pos), "data", "model", None)
            m = cm.swiglu(cm.rms_norm(h, lp["mlp_norm"], c.norm_eps),
                          lp["w_gate"], lp["w_up"], lp["w_down"])
            return h + shard(m, "data", "model", None), None

        x, _ = jax.lax.scan(layer, x, params["dec"])
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        return cm.unembed(x, params["unembed"], c.vocab_size), jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------------
    def prefill(self, params: Params, tokens: jax.Array, cache: Dict,
                prompt_lens: Optional[jax.Array] = None,
                src_embeds: Optional[jax.Array] = None,
                src_lens: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Dict, jax.Array]:
        """Encode the source, fill cross-KV, prefill decoder self-KV on the
        (right-padded) target prompt."""
        c = self.cfg
        enc_out, src_pos = self.encode(params, src_embeds, src_lens)
        # cross-KV for every decoder layer, computed once
        def xkv(lp):
            xk = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_wk"])
            xv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_wv"])
            return xk, xv
        xks, xvs = jax.lax.map(xkv, params["dec"])

        x = cm.embed(tokens, params["embed"])
        B, T, _ = x.shape
        x = shard(x, "data", None, None)
        if prompt_lens is None:
            prompt_lens = jnp.full((B,), T, jnp.int32)
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        valid = pos < prompt_lens[:, None]
        qk_pos = jnp.where(valid, pos, -1)
        L = cache["pos"].shape[1]
        rows = pos % L
        pos_arr = cache["pos"].at[jnp.arange(B)[:, None], rows].set(qk_pos, mode="drop")

        def layer(h, xs):
            lp, lk, lv, xk, xv = xs
            hn = cm.rms_norm(h, lp["self_norm"], c.norm_eps)
            q = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["self_wq"]), pos, c.attn.rope_theta)
            k = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["self_wk"]), pos, c.attn.rope_theta)
            v = jnp.einsum("btd,dhk->bthk", hn, lp["self_wv"])
            bidx = jnp.arange(B)[:, None]
            nk = lk.at[bidx, rows].set(k.astype(lk.dtype), mode="drop")
            nv = lv.at[bidx, rows].set(v.astype(lv.dtype), mode="drop")
            o = cm.flash_attention_tri(q, k, v, qk_pos, qk_pos, window=c.attn.window)
            h = h + shard(jnp.einsum("bthk,hkd->btd", o, lp["self_wo"]), "data", None, None)
            hn = cm.rms_norm(h, lp["cross_norm"], c.norm_eps)
            h = h + shard(self._cross(lp, hn, xk, xv, src_pos), "data", None, None)
            m = cm.swiglu(cm.rms_norm(h, lp["mlp_norm"], c.norm_eps),
                          lp["w_gate"], lp["w_up"], lp["w_down"])
            return h + shard(m, "data", None, None), (nk, nv)

        x, (nks, nvs) = jax.lax.scan(layer, x, (params["dec"], cache["k"], cache["v"],
                                                xks, xvs))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        last = jnp.take_along_axis(x, (prompt_lens - 1)[:, None, None], axis=1)[:, 0]
        logits = cm.unembed(last, params["unembed"], c.vocab_size)
        dt = cache["xk"].dtype
        new_cache = {"k": nks, "v": nvs, "pos": pos_arr,
                     "xk": xks.astype(dt), "xv": xvs.astype(dt), "xpos": src_pos}
        return logits, new_cache, prompt_lens

    # ------------------------------------------------------------------
    def decode_step(self, params: Params, tokens: jax.Array, cache: Dict,
                    seq_lens: jax.Array) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        B, T = tokens.shape
        x = cm.embed(tokens, params["embed"])
        x = shard(x, "data", None, None)
        L = cache["pos"].shape[1]
        positions = (seq_lens - 1)[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        rows = positions % L
        pos_arr = cache["pos"].at[jnp.arange(B)[:, None], rows].set(positions, mode="drop")

        def layer(h, xs):
            lp, lk, lv, xk, xv = xs
            hn = cm.rms_norm(h, lp["self_norm"], c.norm_eps)
            q = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["self_wq"]),
                              positions, c.attn.rope_theta)
            k = cm.apply_rope(jnp.einsum("btd,dhk->bthk", hn, lp["self_wk"]),
                              positions, c.attn.rope_theta)
            v = jnp.einsum("btd,dhk->bthk", hn, lp["self_wv"])
            bidx = jnp.arange(B)[:, None]
            nk = lk.at[bidx, rows].set(k.astype(lk.dtype), mode="drop")
            nv = lv.at[bidx, rows].set(v.astype(lv.dtype), mode="drop")
            mask = cm.position_mask(positions, pos_arr, c.attn.window)
            o = cm.gqa_attention(q, nk, nv, mask)
            h = h + shard(jnp.einsum("bthk,hkd->btd", o, lp["self_wo"]), "data", None, None)
            hn = cm.rms_norm(h, lp["cross_norm"], c.norm_eps)
            h = h + shard(self._cross(lp, hn, xk, xv, cache["xpos"]), "data", None, None)
            m = cm.swiglu(cm.rms_norm(h, lp["mlp_norm"], c.norm_eps),
                          lp["w_gate"], lp["w_up"], lp["w_down"])
            return h + shard(m, "data", None, None), (nk, nv)

        x, (nks, nvs) = jax.lax.scan(
            layer, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = cm.unembed(x, params["unembed"], c.vocab_size)
        return logits, dict(cache, k=nks, v=nvs, pos=pos_arr)

    @staticmethod
    def commit(cache_out: Dict, accept_idx: jax.Array) -> Dict:
        return cache_out
