"""Decoder-only transformer covering the dense / MoE / MLA / VLM-backbone
families (yi-9b, yi-34b, qwen3-8b, internlm2-1.8b, qwen3-moe-30b-a3b,
deepseek-v2-236b, paligemma-3b) plus the paper's own OPT pair.

Three entry points, all pure:
  ``forward``      full-sequence causal forward (training / scoring)
  ``prefill``      full-sequence forward that also populates the KV cache
  ``decode_step``  incremental forward of T new tokens against the cache
                   (T = 1 for plain decode, T = s+1 for speculative verify)

The KV cache is a ring buffer indexed by absolute position modulo cache
length, with a per-row absolute-position array driving the attention mask
(DESIGN §3); rollback after a rejected speculation is a pure length update.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, pad_vocab
from repro.kernels.ops import spec_verify_attn
from repro.kernels.paged import paged_verify_attn
from repro.models import common as cm
from repro.models.common import ParamDef
from repro.models.moe import moe_defs, moe_forward
from repro.runtime.meshctx import shard

Params = Any


def _quant_rows(x: jax.Array):
    """Symmetric int8 per-(row, kv-head) quantization. x: [B,T,KVH,hd]."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0   # [B,T,KVH]
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            ).astype(dtype)


class DecoderLM:
    """Functional decoder-only LM; construct once per config, methods are pure."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.attn is not None, "DecoderLM needs an attention config"
        self.cfg = cfg
        self.padded_vocab = pad_vocab(cfg.vocab_size)

    # ------------------------------------------------------------------
    # parameters

    def param_defs(self) -> Dict:
        c, a = self.cfg, self.cfg.attn
        d, hd = c.d_model, a.head_dim
        H, KVH = a.n_heads, a.n_kv_heads
        defs: Dict[str, Any] = {
            "embed": ParamDef((self.padded_vocab, d), ("vocab", "d_model"), scale=0.02),
            "final_norm": ParamDef((d,), ("d_model",), init="ones"),
        }
        if not c.tie_embeddings:
            defs["unembed"] = ParamDef((self.padded_vocab, d), ("vocab", "d_model"), scale=0.02)
        layer: Dict[str, Any] = {
            "attn_norm": ParamDef((d,), ("d_model",), init="ones", stacked=True),
            "mlp_norm": ParamDef((d,), ("d_model",), init="ones", stacked=True),
        }
        if a.kind == "mla":
            rd, lr, vd = a.rope_head_dim, a.kv_lora_rank, a.vdim
            if a.q_lora_rank:
                layer["wq_a"] = ParamDef((d, a.q_lora_rank), ("d_model", None), stacked=True)
                layer["q_norm"] = ParamDef((a.q_lora_rank,), (None,), init="ones", stacked=True)
                layer["wq_b"] = ParamDef((a.q_lora_rank, H, hd + rd), (None, "heads", None), stacked=True)
            else:
                layer["wq"] = ParamDef((d, H, hd + rd), ("d_model", "heads", None), stacked=True)
            layer["w_dkv"] = ParamDef((d, lr), ("d_model", "lora"), stacked=True)
            layer["kv_norm"] = ParamDef((lr,), ("lora",), init="ones", stacked=True)
            layer["w_krope"] = ParamDef((d, rd), ("d_model", "rope_dim"), stacked=True)
            layer["w_uk"] = ParamDef((lr, H, hd), ("lora", "heads", None), stacked=True)
            layer["w_uv"] = ParamDef((lr, H, vd), ("lora", "heads", None), stacked=True)
            layer["wo"] = ParamDef((H, vd, d), ("heads", None, "d_model"), stacked=True)
        else:
            layer["wq"] = ParamDef((d, H, hd), ("d_model", "heads", None), stacked=True)
            layer["wk"] = ParamDef((d, KVH, hd), ("d_model", "kv_heads", "head_dim"), stacked=True)
            layer["wv"] = ParamDef((d, KVH, hd), ("d_model", "kv_heads", "head_dim"), stacked=True)
            layer["wo"] = ParamDef((H, hd, d), ("heads", None, "d_model"), stacked=True)
            if a.qk_norm:
                layer["q_norm"] = ParamDef((hd,), (None,), init="ones", stacked=True)
                layer["k_norm"] = ParamDef((hd,), (None,), init="ones", stacked=True)
        if c.moe is not None:
            layer["moe"] = moe_defs(c)
        else:
            layer["w_gate"] = ParamDef((d, c.d_ff), ("d_model", "ffn"), stacked=True)
            layer["w_up"] = ParamDef((d, c.d_ff), ("d_model", "ffn"), stacked=True)
            layer["w_down"] = ParamDef((c.d_ff, d), ("ffn", "d_model"), stacked=True)
        defs["layers"] = layer
        return defs

    def init(self, key: jax.Array, dtype=jnp.float32) -> Params:
        return cm.init_params(self.param_defs(), key, self.cfg.n_layers, dtype)

    def shapes(self, dtype=jnp.bfloat16) -> Params:
        return cm.param_shapes(self.param_defs(), self.cfg.n_layers, dtype)

    def specs(self, rules: Dict[str, Optional[str]]) -> Params:
        return cm.param_specs(self.param_defs(), rules)

    # ------------------------------------------------------------------
    # KV cache

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.float32) -> Dict:
        c, a = self.cfg, self.cfg.attn
        nL = c.n_layers
        if a.kind == "mla":
            return {
                "ckv": jnp.zeros((nL, batch, cache_len, a.kv_lora_rank), dtype),
                "krope": jnp.zeros((nL, batch, cache_len, a.rope_head_dim), dtype),
                "pos": jnp.full((batch, cache_len), -1, jnp.int32),
            }
        if c.kv_quant:
            return {
                "k": jnp.zeros((nL, batch, cache_len, a.n_kv_heads, a.head_dim), jnp.int8),
                "v": jnp.zeros((nL, batch, cache_len, a.n_kv_heads, a.head_dim), jnp.int8),
                "k_scale": jnp.zeros((nL, batch, cache_len, a.n_kv_heads), dtype),
                "v_scale": jnp.zeros((nL, batch, cache_len, a.n_kv_heads), dtype),
                "pos": jnp.full((batch, cache_len), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros((nL, batch, cache_len, a.n_kv_heads, a.head_dim), dtype),
            "v": jnp.zeros((nL, batch, cache_len, a.n_kv_heads, a.head_dim), dtype),
            "pos": jnp.full((batch, cache_len), -1, jnp.int32),
        }

    def init_paged_cache(self, num_blocks: int, block_size: int,
                         dtype=jnp.float32) -> Dict:
        """Paged KV pool shared by every slot (vLLM-style; DESIGN in
        core/spec_decode.py).  Rows live in fixed-size blocks addressed
        through per-slot block tables (the ``bt`` entry is added by
        :meth:`~repro.core.spec_decode.SpecDecodeEngine.init_slots`):

            k/v : [nL, num_blocks, block_size, KVH, hd]
            pos : [num_blocks, block_size]  absolute position, -1 unwritten
        """
        c, a = self.cfg, self.cfg.attn
        if a.kind == "mla":
            raise NotImplementedError(
                "paged KV does not support MLA's compressed cache yet")
        nL = c.n_layers
        if c.kv_quant:
            # int8 block pool with per-(row, kv-head) dequant scales: both
            # the fused kernel (VMEM dequant after a 1 B/elem stream) and
            # the gather reference consume them (kernels/paged.py)
            return {
                "k": jnp.zeros((nL, num_blocks, block_size, a.n_kv_heads,
                                a.head_dim), jnp.int8),
                "v": jnp.zeros((nL, num_blocks, block_size, a.n_kv_heads,
                                a.head_dim), jnp.int8),
                "k_scale": jnp.zeros((nL, num_blocks, block_size,
                                      a.n_kv_heads), dtype),
                "v_scale": jnp.zeros((nL, num_blocks, block_size,
                                      a.n_kv_heads), dtype),
                "pos": jnp.full((num_blocks, block_size), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros((nL, num_blocks, block_size, a.n_kv_heads,
                            a.head_dim), dtype),
            "v": jnp.zeros((nL, num_blocks, block_size, a.n_kv_heads,
                            a.head_dim), dtype),
            "pos": jnp.full((num_blocks, block_size), -1, jnp.int32),
        }

    def cache_shapes(self, batch: int, cache_len: int, dtype=jnp.bfloat16) -> Dict:
        return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            jax.eval_shape(lambda: self.init_cache(batch, cache_len, dtype)))

    def cache_specs(self, rules: Dict[str, Optional[str]],
                    batch_axis="data", seq_axis=None) -> Dict:
        a = self.cfg.attn
        if a.kind == "mla":
            return {
                "ckv": P(None, batch_axis, seq_axis, rules.get("lora")),
                "krope": P(None, batch_axis, seq_axis, rules.get("rope_dim")),
                "pos": P(batch_axis, seq_axis),
            }
        specs = {
            "k": P(None, batch_axis, seq_axis, rules.get("kv_heads"), rules.get("head_dim")),
            "v": P(None, batch_axis, seq_axis, rules.get("kv_heads"), rules.get("head_dim")),
            "pos": P(batch_axis, seq_axis),
        }
        if self.cfg.kv_quant:
            specs["k_scale"] = P(None, batch_axis, seq_axis, rules.get("kv_heads"))
            specs["v_scale"] = P(None, batch_axis, seq_axis, rules.get("kv_heads"))
        return specs

    # ------------------------------------------------------------------
    # attention blocks

    def _qkv_gqa(self, lp: Dict, x: jax.Array, positions: jax.Array):
        """x: [B,T,d] -> q,k,v with RoPE applied. positions: [B,T]."""
        a = self.cfg.attn
        q = jnp.einsum("btd,dhk->bthk", x, lp["wq"])
        k = jnp.einsum("btd,dhk->bthk", x, lp["wk"])
        v = jnp.einsum("btd,dhk->bthk", x, lp["wv"])
        if a.qk_norm:
            q = cm.rms_norm(q, lp["q_norm"], self.cfg.norm_eps)
            k = cm.rms_norm(k, lp["k_norm"], self.cfg.norm_eps)
        q = cm.apply_rope(q, positions, a.rope_theta)
        k = cm.apply_rope(k, positions, a.rope_theta)
        return q, k, v

    def _attn_full(self, lp: Dict, x: jax.Array, positions: jax.Array,
                   prefix_len: int, train: bool = False) -> jax.Array:
        """Full-sequence self attention.  ``train=True`` uses the q-block
        rematerializing attention (differentiable at 4k-32k without storing
        per-pair residuals); inference prefill keeps the causal-FLOPs-optimal
        tri variant."""
        c, a = self.cfg, self.cfg.attn
        attn = cm.flash_attention_train if train else cm.flash_attention_tri
        if a.kind == "mla":
            q_nope, q_rope, ckv, krope = self._mla_proj(lp, x, positions)
            k_nope = jnp.einsum("btl,lhk->bthk", ckv, lp["w_uk"])
            vv = jnp.einsum("btl,lhv->bthv", ckv, lp["w_uv"])
            H = a.n_heads
            q = jnp.concatenate([q_nope, q_rope], axis=-1)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(krope[:, :, None], (*k_nope.shape[:3], a.rope_head_dim))],
                axis=-1)
            scale = 1.0 / math.sqrt(a.head_dim + a.rope_head_dim)
            out = attn(q, k, vv, positions, positions,
                       window=a.window, prefix_len=prefix_len, scale=scale)
            return jnp.einsum("bthv,hvd->btd", out, lp["wo"])
        q, k, v = self._qkv_gqa(lp, x, positions)
        out = attn(q, k, v, positions, positions,
                   window=a.window, prefix_len=prefix_len)
        return jnp.einsum("bthk,hkd->btd", out, lp["wo"])

    def _mla_proj(self, lp: Dict, x: jax.Array, positions: jax.Array):
        a, eps = self.cfg.attn, self.cfg.norm_eps
        if a.q_lora_rank:
            qa = cm.rms_norm(jnp.einsum("btd,dr->btr", x, lp["wq_a"]), lp["q_norm"], eps)
            q = jnp.einsum("btr,rhk->bthk", qa, lp["wq_b"])
        else:
            q = jnp.einsum("btd,dhk->bthk", x, lp["wq"])
        q_nope, q_rope = q[..., :a.head_dim], q[..., a.head_dim:]
        q_rope = cm.apply_rope(q_rope, positions, a.rope_theta)
        ckv = cm.rms_norm(jnp.einsum("btd,dl->btl", x, lp["w_dkv"]), lp["kv_norm"], eps)
        krope = jnp.einsum("btd,dr->btr", x, lp["w_krope"])
        krope = cm.apply_rope(krope[:, :, None, :], positions, a.rope_theta)[:, :, 0, :]
        return q_nope, q_rope, ckv, krope

    def _attn_decode(self, lp: Dict, x: jax.Array, positions: jax.Array,
                     layer_cache: Dict, pos_arr: jax.Array, rows: jax.Array,
                     prefix_len: int, rows_limit: Optional[int] = None,
                     ) -> Tuple[jax.Array, Dict]:
        """Incremental attention: write new KV at ``rows`` then attend.

        x: [B,T,d]; positions: [B,T] absolute; rows: [B,T] ring-buffer rows;
        pos_arr: [B,L] updated row->abs-position map (already includes the
        new writes).  Returns (attn_out [B,T,d], updated layer cache).

        ``rows_limit`` (static) bounds the *attended* key rows to the first
        ``rows_limit`` of the cache — callers that know every visible key
        lives below a row bound (chunked prefill: rows < prefix + chunk)
        skip streaming the dead tail.  Rows beyond the bound are unwritten
        or stale-wiped (position -1, never attendable), so the bound is
        numerically free; writes still land in the full cache.
        """
        c, a = self.cfg, self.cfg.attn
        B, T, _ = x.shape
        bidx = jnp.arange(B)[:, None]
        R = rows_limit if rows_limit is not None else pos_arr.shape[1]
        if a.kind == "mla":
            q_nope, q_rope, ckv_new, krope_new = self._mla_proj(lp, x, positions)
            ckv = layer_cache["ckv"].at[bidx, rows].set(
                ckv_new.astype(layer_cache["ckv"].dtype), mode="drop")
            krope = layer_cache["krope"].at[bidx, rows].set(
                krope_new.astype(layer_cache["krope"].dtype), mode="drop")
            # absorbed attention: score via compressed cache
            q_abs = jnp.einsum("bthk,lhk->bthl", q_nope, lp["w_uk"])
            s1 = jnp.einsum("bthl,bsl->bhts", q_abs, ckv[:, :R])
            s2 = jnp.einsum("bthr,bsr->bhts", q_rope, krope[:, :R])
            scale = 1.0 / math.sqrt(a.head_dim + a.rope_head_dim)
            scores = (s1 + s2).astype(jnp.float32) * scale
            mask = cm.position_mask(positions, pos_arr[:, :R], a.window,
                                    prefix_len)
            scores = jnp.where(mask[:, None], scores, -1e30)
            p = jax.nn.softmax(scores, axis=-1)
            o_lora = jnp.einsum("bhts,bsl->bthl", p.astype(ckv.dtype),
                                ckv[:, :R])
            out = jnp.einsum("bthl,lhv->bthv", o_lora, lp["w_uv"])
            out = jnp.einsum("bthv,hvd->btd", out, lp["wo"])
            return out, {"ckv": ckv, "krope": krope}
        q, k_new, v_new = self._qkv_gqa(lp, x, positions)
        if c.kv_quant:
            kq, ks = _quant_rows(k_new)
            vq, vs = _quant_rows(v_new)
            new_lcache = {
                "k": layer_cache["k"].at[bidx, rows].set(kq, mode="drop"),
                "v": layer_cache["v"].at[bidx, rows].set(vq, mode="drop"),
                "k_scale": layer_cache["k_scale"].at[bidx, rows].set(
                    ks.astype(layer_cache["k_scale"].dtype), mode="drop"),
                "v_scale": layer_cache["v_scale"].at[bidx, rows].set(
                    vs.astype(layer_cache["v_scale"].dtype), mode="drop"),
            }
            # int8 tiles + scales go straight into the kernel wrapper: the
            # TPU kernel streams 1 B/elem and dequantizes in VMEM, the CPU
            # reference dequantizes up front (same numerics)
            out = spec_verify_attn(q, new_lcache["k"][:, :R],
                                   new_lcache["v"][:, :R],
                                   positions, pos_arr[:, :R], window=a.window,
                                   prefix_len=prefix_len,
                                   k_scale=new_lcache["k_scale"][:, :R],
                                   v_scale=new_lcache["v_scale"][:, :R])
            out = jnp.einsum("bthk,hkd->btd", out, lp["wo"])
            return out, new_lcache
        k = layer_cache["k"].at[bidx, rows].set(
            k_new.astype(layer_cache["k"].dtype), mode="drop")
        v = layer_cache["v"].at[bidx, rows].set(
            v_new.astype(layer_cache["v"].dtype), mode="drop")
        new_lcache = {"k": k, "v": v}
        # verify-step attention: s+1 tiny q rows vs the ragged ring-buffer
        # cache — the paper's hot spot (Pallas spec_verify_attn on TPU,
        # reference path on CPU; identical masking semantics)
        out = spec_verify_attn(q, k[:, :R], v[:, :R], positions,
                               pos_arr[:, :R],
                               window=a.window, prefix_len=prefix_len)
        out = jnp.einsum("bthk,hkd->btd", out, lp["wo"])
        return out, new_lcache

    def _attn_paged(self, lp: Dict, x: jax.Array, positions: jax.Array,
                    lcache: Dict, pos_arr: jax.Array, pb: jax.Array,
                    off: jax.Array, bt: jax.Array, prefix_len: int,
                    cu_blocks: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Dict]:
        """Paged incremental attention: scatter this step's KV rows through
        the block table (``pb``/``off`` physical addresses, out-of-range =>
        dropped write), then attend against the pool via
        :func:`~repro.kernels.paged.paged_verify_attn` — the fused streaming
        kernel or the gather reference per ``cfg.paged_fused``.  Shared by
        the paged decode step, the paged prefill-chunk (prefix-extension)
        forward, and the mixed verify+chunk launch, so all three ride the
        same kernel.  ``cu_blocks [B + 1]`` (host-computed cumulative
        grid-step counts) upgrades the fused path to the ragged grid —
        steps = sum of live blocks instead of ``B * MAXB``.
        """
        c, a = self.cfg, self.cfg.attn
        q, k_new, v_new = self._qkv_gqa(lp, x, positions)
        if c.kv_quant:
            kq, ks = _quant_rows(k_new)
            vq, vs = _quant_rows(v_new)
            new_lcache = {
                "k": lcache["k"].at[pb, off].set(kq, mode="drop"),
                "v": lcache["v"].at[pb, off].set(vq, mode="drop"),
                "k_scale": lcache["k_scale"].at[pb, off].set(
                    ks.astype(lcache["k_scale"].dtype), mode="drop"),
                "v_scale": lcache["v_scale"].at[pb, off].set(
                    vs.astype(lcache["v_scale"].dtype), mode="drop"),
            }
            out = paged_verify_attn(
                q, new_lcache["k"], new_lcache["v"], positions, pos_arr, bt,
                window=a.window, prefix_len=prefix_len,
                k_scale=new_lcache["k_scale"],
                v_scale=new_lcache["v_scale"], use_pallas=c.paged_fused,
                cu_blocks=cu_blocks)
        else:
            new_lcache = {
                "k": lcache["k"].at[pb, off].set(
                    k_new.astype(lcache["k"].dtype), mode="drop"),
                "v": lcache["v"].at[pb, off].set(
                    v_new.astype(lcache["v"].dtype), mode="drop"),
            }
            out = paged_verify_attn(
                q, new_lcache["k"], new_lcache["v"], positions, pos_arr, bt,
                window=a.window, prefix_len=prefix_len,
                use_pallas=c.paged_fused, cu_blocks=cu_blocks)
        return jnp.einsum("bthk,hkd->btd", out, lp["wo"]), new_lcache

    # ------------------------------------------------------------------
    # MLP

    def _mlp(self, lp: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Returns (out, aux_loss)."""
        if self.cfg.moe is not None:
            return moe_forward(self.cfg, lp["moe"], x)
        return cm.swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"]), jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------------
    # full-sequence forward (training / scoring)

    def forward(self, params: Params, tokens: jax.Array,
                prefix_embeds: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
        """tokens: [B, T] -> (logits [B, P+T, V], moe_aux_loss scalar)."""
        c = self.cfg
        x = cm.embed(tokens, params["embed"])
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        B, T, _ = x.shape
        # sequence-parallel residual stream: tokens sharded over 'model'
        # between layers, so per-device activations (and the remat residuals
        # the layer scan carries) shrink by the model-axis size
        x = shard(x, "data", "model", None)
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        prefix_len = c.prefix_len if (prefix_embeds is not None and c.bidirectional_prefix) else 0

        @partial(jax.checkpoint, static_argnums=())   # remat per layer
        def layer(carry, lp):
            h, aux = carry
            a_out = self._attn_full(lp, cm.rms_norm(h, lp["attn_norm"], c.norm_eps),
                                    positions, prefix_len, train=True)
            h = h + shard(a_out, "data", "model", None)
            m_out, l_aux = self._mlp(lp, cm.rms_norm(h, lp["mlp_norm"], c.norm_eps))
            h = h + shard(m_out, "data", "model", None)
            return (h, aux + l_aux), None

        (x, aux), _ = jax.lax.scan(layer, (x, jnp.zeros((), jnp.float32)), params["layers"])
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        table = params["embed"] if c.tie_embeddings else params["unembed"]
        return cm.unembed(x, table, c.vocab_size), aux

    # ------------------------------------------------------------------
    # prefill: forward + cache population

    def prefill(self, params: Params, tokens: jax.Array, cache: Dict,
                prompt_lens: Optional[jax.Array] = None,
                prefix_embeds: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Dict, jax.Array]:
        """Right-padded prompts [B, Tp] -> (last-token logits [B, V],
        populated cache, seq_lens [B])."""
        c = self.cfg
        x = cm.embed(tokens, params["embed"])
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        B, T, _ = x.shape
        x = shard(x, "data", None, None)
        L = cache["pos"].shape[1]
        if prompt_lens is None:
            prompt_lens = jnp.full((B,), tokens.shape[1], jnp.int32)
        total_lens = prompt_lens + (c.prefix_len if prefix_embeds is not None else 0)
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        valid = positions < total_lens[:, None]
        rows = positions % L
        pos_arr = cache["pos"].at[jnp.arange(B)[:, None], rows].set(
            jnp.where(valid, positions, -1), mode="drop")
        prefix_len = c.prefix_len if (prefix_embeds is not None and c.bidirectional_prefix) else 0

        def layer(carry, xs):
            h = carry
            lp, lcache = xs
            hn = cm.rms_norm(h, lp["attn_norm"], c.norm_eps)
            # full-sequence attention for compute; also write KV rows to cache
            a_out, new_lcache = self._attn_decode(lp, hn, positions, lcache,
                                                  pos_arr, rows, prefix_len)
            h = h + shard(a_out, "data", None, None)
            m_out, _ = self._mlp(lp, cm.rms_norm(h, lp["mlp_norm"], c.norm_eps))
            h = h + shard(m_out, "data", None, None)
            return h, new_lcache

        layer_caches = {k: v for k, v in cache.items() if k != "pos"}
        x, new_caches = jax.lax.scan(layer, x, (params["layers"], layer_caches))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        last = jnp.take_along_axis(x, (total_lens - 1)[:, None, None], axis=1)[:, 0]
        table = params["embed"] if c.tie_embeddings else params["unembed"]
        logits = cm.unembed(last, table, c.vocab_size)
        new_cache = dict(new_caches, pos=pos_arr)
        return logits, new_cache, total_lens

    # prefill uses the decode (materialized-score) attention path per layer,
    # which is O(T·L) memory; for the 32k prefill dry-run we use
    # ``prefill_flash`` below which runs flash attention and then writes KV.

    def prefill_flash(self, params: Params, tokens: jax.Array, cache: Dict,
                      prompt_lens: Optional[jax.Array] = None,
                      prefix_embeds: Optional[jax.Array] = None,
                      ) -> Tuple[jax.Array, Dict, jax.Array]:
        """Prefill with flash attention (memory-bounded at long context).

        Semantics match :meth:`prefill`; the KV rows are produced by the same
        projections, attention runs blockwise, and the cache is written once.
        """
        c, a = self.cfg, self.cfg.attn
        x = cm.embed(tokens, params["embed"])
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        B, T, _ = x.shape
        x = shard(x, "data", None, None)
        L = cache["pos"].shape[1]
        if prompt_lens is None:
            prompt_lens = jnp.full((B,), tokens.shape[1], jnp.int32)
        total_lens = prompt_lens + (c.prefix_len if prefix_embeds is not None else 0)
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        valid = positions < total_lens[:, None]
        qk_pos = jnp.where(valid, positions, -1)  # padded rows never attended
        rows = positions % L
        pos_arr = cache["pos"].at[jnp.arange(B)[:, None], rows].set(qk_pos, mode="drop")
        prefix_len = c.prefix_len if (prefix_embeds is not None and c.bidirectional_prefix) else 0

        def layer(carry, xs):
            h = carry
            lp, lcache = xs
            hn = cm.rms_norm(h, lp["attn_norm"], c.norm_eps)
            if a.kind == "mla":
                q_nope, q_rope, ckv_new, krope_new = self._mla_proj(lp, hn, positions)
                k_nope = jnp.einsum("btl,lhk->bthk", ckv_new, lp["w_uk"])
                vv = jnp.einsum("btl,lhv->bthv", ckv_new, lp["w_uv"])
                q = jnp.concatenate([q_nope, q_rope], axis=-1)
                k = jnp.concatenate(
                    [k_nope, jnp.broadcast_to(krope_new[:, :, None],
                                              (*k_nope.shape[:3], a.rope_head_dim))], axis=-1)
                scale = 1.0 / math.sqrt(a.head_dim + a.rope_head_dim)
                o = cm.flash_attention_tri(q, k, vv, qk_pos, qk_pos,
                                           window=a.window, prefix_len=prefix_len, scale=scale)
                a_out = jnp.einsum("bthv,hvd->btd", o, lp["wo"])
                bidx = jnp.arange(B)[:, None]
                new_lcache = {
                    "ckv": lcache["ckv"].at[bidx, rows].set(
                        ckv_new.astype(lcache["ckv"].dtype), mode="drop"),
                    "krope": lcache["krope"].at[bidx, rows].set(
                        krope_new.astype(lcache["krope"].dtype), mode="drop"),
                }
            else:
                q, k_new, v_new = self._qkv_gqa(lp, hn, positions)
                o = cm.flash_attention_tri(q, k_new, v_new, qk_pos, qk_pos,
                                           window=a.window, prefix_len=prefix_len)
                a_out = jnp.einsum("bthk,hkd->btd", o, lp["wo"])
                bidx = jnp.arange(B)[:, None]
                if c.kv_quant:
                    kq, ks = _quant_rows(k_new)
                    vq, vs = _quant_rows(v_new)
                    new_lcache = {
                        "k": lcache["k"].at[bidx, rows].set(kq, mode="drop"),
                        "v": lcache["v"].at[bidx, rows].set(vq, mode="drop"),
                        "k_scale": lcache["k_scale"].at[bidx, rows].set(
                            ks.astype(lcache["k_scale"].dtype), mode="drop"),
                        "v_scale": lcache["v_scale"].at[bidx, rows].set(
                            vs.astype(lcache["v_scale"].dtype), mode="drop"),
                    }
                else:
                    new_lcache = {
                        "k": lcache["k"].at[bidx, rows].set(
                            k_new.astype(lcache["k"].dtype), mode="drop"),
                        "v": lcache["v"].at[bidx, rows].set(
                            v_new.astype(lcache["v"].dtype), mode="drop"),
                    }
            h = h + shard(a_out, "data", None, None)
            m_out, _ = self._mlp(lp, cm.rms_norm(h, lp["mlp_norm"], c.norm_eps))
            h = h + shard(m_out, "data", None, None)
            return h, new_lcache

        layer_caches = {k: v for k, v in cache.items() if k != "pos"}
        x, new_caches = jax.lax.scan(layer, x, (params["layers"], layer_caches))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        last = jnp.take_along_axis(x, (total_lens - 1)[:, None, None], axis=1)[:, 0]
        table = params["embed"] if c.tie_embeddings else params["unembed"]
        return cm.unembed(last, table, c.vocab_size), dict(new_caches, pos=pos_arr), total_lens

    # ------------------------------------------------------------------
    # incremental decode

    def decode_step(self, params: Params, tokens: jax.Array, cache: Dict,
                    seq_lens: jax.Array,
                    cu_blocks: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Dict]:
        """tokens: [B, T] the last committed token followed by T-1 drafts;
        they occupy absolute positions (seq_lens-1) ... (seq_lens+T-2).
        Returns (logits [B, T, V], updated cache).

        A cache with a ``bt`` (block table) entry is a paged pool (see
        :meth:`init_paged_cache`) and takes the paged path — block-table
        scatter writes plus the fused streaming kernel or gather reference
        per ``cfg.paged_fused`` (kernels/paged.py); otherwise the per-row
        ring-buffer path below runs unchanged.  ``cu_blocks [B + 1]``
        (host cumulative grid-step counts; paged + fused only) selects the
        ragged grid — see :func:`~repro.kernels.paged.paged_verify_attn`.
        """
        if "bt" in cache:
            return self._decode_step_paged(params, tokens, cache, seq_lens,
                                           cu_blocks)
        c = self.cfg
        B, T = tokens.shape
        L = cache["pos"].shape[1]
        x = cm.embed(tokens, params["embed"])
        x = shard(x, "data", None, None)
        positions = (seq_lens - 1)[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        rows = positions % L
        pos_arr = cache["pos"].at[jnp.arange(B)[:, None], rows].set(positions, mode="drop")
        prefix_len = c.prefix_len if c.bidirectional_prefix else 0

        def layer(carry, xs):
            h = carry
            lp, lcache = xs
            hn = cm.rms_norm(h, lp["attn_norm"], c.norm_eps)
            a_out, new_lcache = self._attn_decode(lp, hn, positions, lcache,
                                                  pos_arr, rows, prefix_len)
            h = h + shard(a_out, "data", None, None)
            m_out, _ = self._mlp(lp, cm.rms_norm(h, lp["mlp_norm"], c.norm_eps))
            h = h + shard(m_out, "data", None, None)
            return h, new_lcache

        layer_caches = {k: v for k, v in cache.items() if k != "pos"}
        x, new_caches = jax.lax.scan(layer, x, (params["layers"], layer_caches))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        table = params["embed"] if c.tie_embeddings else params["unembed"]
        logits = cm.unembed(x, table, c.vocab_size)
        return logits, dict(new_caches, pos=pos_arr)

    def _decode_step_paged(self, params: Params, tokens: jax.Array,
                           cache: Dict, seq_lens: jax.Array,
                           cu_blocks: Optional[jax.Array] = None,
                           ) -> Tuple[jax.Array, Dict]:
        """Incremental decode against the paged KV pool.

        Token at absolute position p of slot b lives at physical row
        (bt[b, p // block_size], p % block_size).  Slots whose table has no
        block for a write position (empty or retired slots, bt = -1) have
        their writes dropped; their reads surface key position -1 and are
        masked out, so the same compiled step serves every occupancy level —
        exactly the contiguous slot-pool contract.
        """
        c, a = self.cfg, self.cfg.attn
        B, T = tokens.shape
        NB, bs = cache["pos"].shape
        bt = cache["bt"]                                        # [B, MAXB]
        x = cm.embed(tokens, params["embed"])
        x = shard(x, "data", None, None)
        positions = (seq_lens - 1)[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        blk = jnp.clip(positions // bs, 0, bt.shape[1] - 1)
        off = positions % bs
        pb = jnp.take_along_axis(bt, blk, axis=1)               # [B, T]
        pb = jnp.where(pb < 0, NB, pb)                          # NB => dropped
        pos_arr = cache["pos"].at[pb, off].set(positions, mode="drop")
        prefix_len = c.prefix_len if c.bidirectional_prefix else 0

        def layer(carry, xs):
            h = carry
            lp, lcache = xs
            hn = cm.rms_norm(h, lp["attn_norm"], c.norm_eps)
            a_out, new_lcache = self._attn_paged(lp, hn, positions, lcache,
                                                 pos_arr, pb, off, bt,
                                                 prefix_len, cu_blocks)
            h = h + shard(a_out, "data", None, None)
            m_out, _ = self._mlp(lp, cm.rms_norm(h, lp["mlp_norm"], c.norm_eps))
            h = h + shard(m_out, "data", None, None)
            return h, new_lcache

        layer_caches = {k: v for k, v in cache.items() if k not in ("pos", "bt")}
        x, new_caches = jax.lax.scan(layer, x, (params["layers"], layer_caches))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        table = params["embed"] if c.tie_embeddings else params["unembed"]
        logits = cm.unembed(x, table, c.vocab_size)
        return logits, dict(new_caches, pos=pos_arr, bt=bt)

    def decode_step_mixed(self, params: Params, tokens: jax.Array,
                          cache: Dict, seq_lens: jax.Array,
                          chunk_slot: jax.Array, chunk_tokens: jax.Array,
                          chunk_start: jax.Array, chunk_limit: jax.Array,
                          chunk_bt_row: jax.Array, verify_len: int,
                          cu_blocks: Optional[jax.Array] = None,
                          ) -> Tuple[jax.Array, Dict]:
        """One mixed verify+chunk launch against the paged pool.

        Row ``chunk_slot`` of the batch carries a chunk-prefill prefix
        extension (``chunk_tokens`` at absolute positions ``chunk_start ..
        chunk_limit - 1``, reading/writing through ``chunk_bt_row`` — the
        slot's host table row, which on device is still all ``-1`` while
        the slot is parked PREFILLING); every other row carries its usual
        verify feed (first ``verify_len`` columns; the rest is padding
        with position ``-1``, matching nothing and writing nowhere).  Both
        query kinds ride one ragged kernel call per layer — per-query-row
        masking plus per-row block tables make the kernel agnostic to
        which row is which, so a separate chunk launch (and its grid,
        weight re-streaming, and dispatch) disappears.

        The returned cache keeps the *original* device ``bt`` — the
        pending slot's table row stays ``-1`` until its final chunk
        commits, exactly like the standalone chunk forward.  Logits for
        the chunk row are meaningless (the engine's accept mask already
        zeroes pending slots); callers slice ``[:, :verify_len]``.
        """
        c = self.cfg
        B, T = tokens.shape
        NB, bs = cache["pos"].shape
        bt = cache["bt"]                                        # [B, MAXB]
        bt_eff = bt.at[chunk_slot].set(chunk_bt_row)
        toks = tokens.at[chunk_slot].set(chunk_tokens)
        x = cm.embed(toks, params["embed"])
        x = shard(x, "data", None, None)
        col = jnp.arange(T, dtype=jnp.int32)
        positions = jnp.where(col[None] < verify_len,
                              (seq_lens - 1)[:, None] + col[None], -1)
        cpos = chunk_start + col
        positions = positions.at[chunk_slot].set(
            jnp.where(cpos < chunk_limit, cpos, -1))
        valid = positions >= 0
        blk = jnp.clip(positions // bs, 0, bt.shape[1] - 1)
        off = positions % bs
        pb = jnp.take_along_axis(bt_eff, blk, axis=1)           # [B, T]
        pb = jnp.where((pb < 0) | ~valid, NB, pb)               # NB => dropped
        pos_arr = cache["pos"].at[pb, off].set(
            jnp.where(valid, positions, -1), mode="drop")
        prefix_len = c.prefix_len if c.bidirectional_prefix else 0

        def layer(carry, xs):
            h = carry
            lp, lcache = xs
            hn = cm.rms_norm(h, lp["attn_norm"], c.norm_eps)
            a_out, new_lcache = self._attn_paged(lp, hn, positions, lcache,
                                                 pos_arr, pb, off, bt_eff,
                                                 prefix_len, cu_blocks)
            h = h + shard(a_out, "data", None, None)
            m_out, _ = self._mlp(lp, cm.rms_norm(h, lp["mlp_norm"], c.norm_eps))
            h = h + shard(m_out, "data", None, None)
            return h, new_lcache

        layer_caches = {k: v for k, v in cache.items() if k not in ("pos", "bt")}
        x, new_caches = jax.lax.scan(layer, x, (params["layers"], layer_caches))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        table = params["embed"] if c.tie_embeddings else params["unembed"]
        logits = cm.unembed(x, table, c.vocab_size)
        return logits, dict(new_caches, pos=pos_arr, bt=bt)

    # ------------------------------------------------------------------
    # chunked prefill (prefix extension)

    def prefill_chunk(self, params: Params, tokens: jax.Array, cache: Dict,
                      offset: jax.Array, limit: jax.Array,
                      rows_limit: Optional[int] = None,
                      cu_blocks: Optional[jax.Array] = None,
                      ) -> Tuple[jax.Array, Dict]:
        """One prefill *chunk*: write ``tokens`` [B, T] at absolute positions
        ``offset .. offset+T-1``, attending over the already-written cache
        prefix plus the chunk itself (Sarathi-style chunked prefill).

        Positions at or beyond ``limit`` are bucket padding: their cache
        writes are routed out of bounds and dropped, so a ragged final chunk
        never clobbers live rows (including the ring-wrap case where the
        padded tail would alias row 0).  Attention reuses the verify-step
        position masking unchanged — a chunk query at position p sees exactly
        the keys with position <= p, which is what makes the chunked cache
        bit-compatible with a whole-prompt prefill.

        Returns (logits [B, T, V], updated cache); callers that only extend
        the cache can discard the logits (XLA dead-code-eliminates the
        unembed under jit).

        ``rows_limit`` (static) bounds the attended cache rows: during
        chunked prefill every visible key lives at a row below
        ``offset + T`` (positions equal rows until the first wrap, and
        chunks never wrap), so the engine passes a power-of-two bucket of
        it and the attention stops streaming the dead tail of the logical
        cache.  ``cu_blocks`` selects the ragged grid on the paged path.
        """
        if "bt" in cache:
            return self._prefill_chunk_paged(params, tokens, cache, offset,
                                             limit, cu_blocks)
        c = self.cfg
        B, T = tokens.shape
        L = cache["pos"].shape[1]
        x = cm.embed(tokens, params["embed"])
        x = shard(x, "data", None, None)
        positions = offset[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        valid = positions < limit[:, None]
        rows = jnp.where(valid, positions % L, L)       # L => dropped write
        pos_arr = cache["pos"].at[jnp.arange(B)[:, None], rows].set(
            jnp.where(valid, positions, -1), mode="drop")
        prefix_len = c.prefix_len if c.bidirectional_prefix else 0

        def layer(carry, xs):
            h = carry
            lp, lcache = xs
            hn = cm.rms_norm(h, lp["attn_norm"], c.norm_eps)
            a_out, new_lcache = self._attn_decode(lp, hn, positions, lcache,
                                                  pos_arr, rows, prefix_len,
                                                  rows_limit)
            h = h + shard(a_out, "data", None, None)
            m_out, _ = self._mlp(lp, cm.rms_norm(h, lp["mlp_norm"], c.norm_eps))
            h = h + shard(m_out, "data", None, None)
            return h, new_lcache

        layer_caches = {k: v for k, v in cache.items() if k != "pos"}
        x, new_caches = jax.lax.scan(layer, x, (params["layers"], layer_caches))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        table = params["embed"] if c.tie_embeddings else params["unembed"]
        return cm.unembed(x, table, c.vocab_size), dict(new_caches, pos=pos_arr)

    def _prefill_chunk_paged(self, params: Params, tokens: jax.Array,
                             cache: Dict, offset: jax.Array, limit: jax.Array,
                             cu_blocks: Optional[jax.Array] = None,
                             ) -> Tuple[jax.Array, Dict]:
        """Chunked prefill against the paged KV pool: chunk rows scatter
        block-wise through the slot's block table (padding and unallocated
        logical blocks are dropped), and attention reads the slot's prefix
        through the same table — the fused streaming kernel or the gather
        reference per ``cfg.paged_fused`` (kernels/paged.py), masking
        unchanged.  This is the fused prefix-extension chunk forward: the
        chunk's q rows stream the pool exactly like a verify step's."""
        c, a = self.cfg, self.cfg.attn
        B, T = tokens.shape
        NB, bs = cache["pos"].shape
        bt = cache["bt"]                                        # [B, MAXB]
        x = cm.embed(tokens, params["embed"])
        x = shard(x, "data", None, None)
        positions = offset[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        valid = positions < limit[:, None]
        blk = jnp.clip(positions // bs, 0, bt.shape[1] - 1)
        off = positions % bs
        pb = jnp.take_along_axis(bt, blk, axis=1)               # [B, T]
        pb = jnp.where((pb < 0) | ~valid, NB, pb)               # NB => dropped
        pos_arr = cache["pos"].at[pb, off].set(
            jnp.where(valid, positions, -1), mode="drop")
        prefix_len = c.prefix_len if c.bidirectional_prefix else 0

        def layer(carry, xs):
            h = carry
            lp, lcache = xs
            hn = cm.rms_norm(h, lp["attn_norm"], c.norm_eps)
            a_out, new_lcache = self._attn_paged(lp, hn, positions, lcache,
                                                 pos_arr, pb, off, bt,
                                                 prefix_len, cu_blocks)
            h = h + shard(a_out, "data", None, None)
            m_out, _ = self._mlp(lp, cm.rms_norm(h, lp["mlp_norm"], c.norm_eps))
            h = h + shard(m_out, "data", None, None)
            return h, new_lcache

        layer_caches = {k: v for k, v in cache.items() if k not in ("pos", "bt")}
        x, new_caches = jax.lax.scan(layer, x, (params["layers"], layer_caches))
        x = cm.rms_norm(x, params["final_norm"], c.norm_eps)
        table = params["embed"] if c.tie_embeddings else params["unembed"]
        logits = cm.unembed(x, table, c.vocab_size)
        return logits, dict(new_caches, pos=pos_arr, bt=bt)

    @staticmethod
    def commit(cache_out: Dict, accept_idx: jax.Array) -> Dict:
        """Attention-cache rollback is a pure length update done by the engine
        (stale ring rows are overwritten before they can be attended)."""
        return cache_out
