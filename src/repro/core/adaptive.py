"""Adaptive speculative decoding (paper §4): profile-then-serve.

Profiling stage: measure per-token latency on a small prompt sample over the
grid (b in powers of two up to b_max) x (s in 0..s_max), build a look-up
table b -> s_opt.  Execution stage: each formed batch looks up its optimal
speculation length; batch sizes that were not profiled take the *smaller*
speculation length of the two nearest profiled sizes (paper §4).

Two profiling backends share the LUT machinery:
  * :func:`profile_engine`   — wall-clock measurement of a live
    :class:`~repro.core.spec_decode.SpecDecodeEngine` (the paper's method);
  * :class:`~repro.core.analytical.LatencyModel` — fitted or roofline-derived
    analytical model (beyond-paper; lets us build the LUT for the production
    TPU mesh from dry-run cost analysis).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.analytical import (LatencyModel, acceptance_curve,
                                   fit_latency_model, fit_power_law)


# ---------------------------------------------------------------------------
# LUT


@dataclass(frozen=True)
class SpeculationLUT:
    """b -> s_opt table with the paper's nearest-profiled lookup rule."""
    table: Mapping[int, int]                 # profiled batch size -> s_opt
    per_token: Mapping[int, Mapping[int, float]] = field(default_factory=dict)

    @property
    def batch_sizes(self) -> List[int]:
        return sorted(self.table)

    def lookup(self, b: int) -> int:
        """Optimal s for batch size ``b``.

        Profiled sizes return their entry; unprofiled sizes take the smaller
        s of the two nearest profiled sizes (paper §4); out-of-range sizes
        clamp to the nearest profiled size.
        """
        bs = self.batch_sizes
        if not bs:
            raise ValueError("empty LUT")
        if b in self.table:
            return self.table[b]
        if b <= bs[0]:
            return self.table[bs[0]]
        if b >= bs[-1]:
            return self.table[bs[-1]]
        lo = max(x for x in bs if x < b)
        hi = min(x for x in bs if x > b)
        return min(self.table[lo], self.table[hi])

    def is_monotone(self) -> bool:
        """s_opt non-increasing in b — the paper's key observation."""
        vals = [self.table[b] for b in self.batch_sizes]
        return all(a >= b for a, b in zip(vals, vals[1:]))


def lut_from_model(model: LatencyModel, s_max: int = 8,
                   batch_sizes: Optional[Sequence[int]] = None) -> SpeculationLUT:
    bs = list(batch_sizes) if batch_sizes is not None else list(model.batch_sizes)
    table = {b: model.s_opt(b, s_max) for b in bs}
    per_token = {b: {s: model.per_token_time(b, s) for s in range(0, s_max + 1)}
                 for b in bs}
    return SpeculationLUT(table=table, per_token=per_token)


def lut_from_grid(per_token: Mapping[int, Mapping[int, float]]) -> SpeculationLUT:
    """LUT from a measured (b, s) -> per-token-latency grid (argmin over s)."""
    table = {b: min(d, key=d.get) for b, d in per_token.items()}
    return SpeculationLUT(table=table, per_token=dict(per_token))


# ---------------------------------------------------------------------------
# wall-clock profiling of a live engine (the paper's profiling stage)


def profile_engine(engine, tparams, dparams, prompts: np.ndarray,
                   prompt_lens: np.ndarray, *,
                   batch_sizes: Sequence[int] = (1, 2, 4, 8, 16),
                   s_values: Sequence[int] = tuple(range(0, 9)),
                   gen_tokens: int = 32, cache_len: int = 256,
                   repeats: int = 1) -> SpeculationLUT:
    """Measure per-token latency for every (b, s) grid point.

    ``prompts`` [P, Tp] / ``prompt_lens`` [P] is the profiling sample (the
    paper uses a held-out slice of the dataset).  Each grid point generates
    ``gen_tokens`` tokens per request and records wall time / tokens.
    """
    grid: Dict[int, Dict[int, float]] = {}
    P = prompts.shape[0]
    for b in batch_sizes:
        reps = int(np.ceil(b / P))
        toks = np.tile(prompts, (reps, 1))[:b]
        lens = np.tile(prompt_lens, reps)[:b]
        grid[b] = {}
        for s in s_values:
            best = float("inf")
            for _ in range(max(repeats, 1)):
                # compile outside the timed region (the paper's profiling is
                # steady-state serving latency)
                state = engine.prefill(tparams, dparams, toks, lens, cache_len)
                engine.step(tparams, dparams, state, s)
                state = engine.prefill(tparams, dparams, toks, lens, cache_len)
                t0 = time.perf_counter()
                total = 0
                while total < gen_tokens * b:
                    state, st = engine.step(tparams, dparams, state, s)
                    total += int(st.committed.sum())
                    if bool(np.asarray(state.done).all()):
                        break
                dt = time.perf_counter() - t0
                best = min(best, dt / max(total, 1))
            grid[b][s] = best
    return lut_from_grid(grid)


def measure_acceptance(engine, tparams, dparams, prompts: np.ndarray,
                       prompt_lens: np.ndarray, *, s: int = 8,
                       gen_tokens: int = 64, cache_len: int = 256,
                       ) -> List[int]:
    """Per-step accepted-run lengths (the l_i samples of paper Eq. 4)."""
    state = engine.prefill(tparams, dparams, prompts, prompt_lens, cache_len)
    runs: List[int] = []
    total = 0
    while total < gen_tokens * prompts.shape[0]:
        state, st = engine.step(tparams, dparams, state, s)
        runs.extend(int(a) for a in st.accepted)
        total += int(st.committed.sum())
        if bool(np.asarray(state.done).all()):
            break
    return runs


# ---------------------------------------------------------------------------
# the adaptive controller (execution stage + beyond-paper online refresh)


@dataclass
class AdaptiveController:
    """Serve-time speculation-length chooser.

    Paper behaviour: ``s = lut.lookup(batch_size)``.

    Beyond-paper (DESIGN §8.2): optionally tracks an EWMA of observed
    acceptance and rebuilds the LUT through the analytical model when the
    live acceptance drifts from the profiled c, gamma (e.g. the workload's
    draftability changed).  Disabled unless ``model`` is provided.
    """
    lut: SpeculationLUT
    model: Optional[LatencyModel] = None
    ewma_alpha: float = 0.05
    drift_threshold: float = 0.25
    s_max: int = 8
    # online state
    _ewma_accept: Optional[float] = None
    _profiled_accept: Optional[float] = None
    refreshes: int = 0

    def choose(self, batch_size: int) -> int:
        if batch_size <= 0:
            return 0
        return self.lut.lookup(batch_size)

    def observe(self, accepted: np.ndarray, s: int) -> None:
        """Feed per-request accepted counts from one step (optional)."""
        if self.model is None or s <= 0:
            return
        a = float(np.mean(accepted)) / max(s, 1)     # normalized acceptance
        if self._ewma_accept is None:
            self._ewma_accept = a
        else:
            self._ewma_accept += self.ewma_alpha * (a - self._ewma_accept)
        if self._profiled_accept is None:
            self._profiled_accept = min(self.model.l_of_s(s) / s, 1.0)
        drift = abs(self._ewma_accept - self._profiled_accept)
        if drift > self.drift_threshold:
            # rescale c so that l(s)/s matches the observed acceptance
            scale = max(self._ewma_accept, 1e-3) / max(self._profiled_accept, 1e-3)
            new_model = dataclasses.replace(self.model, c=self.model.c * scale)
            self.model = new_model
            self.lut = lut_from_model(new_model, self.s_max, self.lut.batch_sizes)
            self._profiled_accept = self._ewma_accept
            self.refreshes += 1


def fixed_controller(s: int, batch_sizes=(1, 2, 4, 8, 16, 32)) -> AdaptiveController:
    """Baseline: fixed speculation length for every batch size."""
    return AdaptiveController(lut=SpeculationLUT({b: s for b in batch_sizes}))
