"""Analytical model of batched speculative decoding (paper §3.3).

The paper models total generation time for ``N`` tokens at batch size ``b``
and speculation length ``s`` as

    T(b, s) = N / (l(s) + 1) * (t_L(b, s) + s * t_S(b, 1))          (Eq. 7)

with two fitted ingredients:

  * acceptance curve  l(s) ~= c * s**gamma   (gamma < 1, sub-linear, Fig. 2)
  * verify latency    t_L(b, s) ~= alpha_b * s + beta                (Fig. 3)

and the monotonicity result (Eq. 11-12): the stationarity residual

    delta(b, s) = K * alpha_b * s**gamma - L * s**(gamma-1) + alpha_b
    K = (1 - gamma) * c,   L = c * beta * gamma

is increasing in both ``b`` (through alpha_b) and ``s``, hence the optimal
speculation length ``s_opt`` is non-increasing in ``b``.

Everything here is plain numpy (it runs at profiling time, not in the jitted
serving path).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Mapping, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# acceptance curve l(s)


def acceptance_curve(run_lengths: Sequence[int], s_values: Sequence[int]) -> np.ndarray:
    """Empirical l(s) from per-prompt correct-run lengths (paper Eq. 4).

    ``run_lengths[i]`` is the number of leading draft tokens the target
    accepted for prompt i when the draft ran unconstrained; then
    l(s) ~= mean_i min(l_i, s).
    """
    li = np.asarray(run_lengths, dtype=np.float64)
    return np.array([np.mean(np.minimum(li, s)) for s in s_values])


def fit_power_law(s_values: Sequence[int], l_values: Sequence[float],
                  ) -> Tuple[float, float]:
    """Fit l(s) ~= c * s**gamma by least squares in log-log space.

    Returns (c, gamma).  Zero l-values are clamped to a small epsilon (they
    only occur when the draft never matches, where any fit is moot).
    """
    s = np.asarray(s_values, dtype=np.float64)
    l = np.maximum(np.asarray(l_values, dtype=np.float64), 1e-6)
    A = np.stack([np.ones_like(s), np.log(s)], axis=1)
    coef, *_ = np.linalg.lstsq(A, np.log(l), rcond=None)
    log_c, gamma = coef
    return float(np.exp(log_c)), float(gamma)


def power_law_r2(s_values, l_values, c: float, gamma: float) -> float:
    l = np.asarray(l_values, dtype=np.float64)
    pred = c * np.asarray(s_values, dtype=np.float64) ** gamma
    ss_res = float(np.sum((l - pred) ** 2))
    ss_tot = float(np.sum((l - l.mean()) ** 2))
    return 1.0 - ss_res / max(ss_tot, 1e-12)


# ---------------------------------------------------------------------------
# verify-latency curve t_L(b, s)


def fit_linear_latency(s_values: Sequence[int], t_values: Sequence[float],
                       ) -> Tuple[float, float]:
    """Fit t_L(s) ~= alpha * s + beta for one batch size.  Returns (alpha, beta)."""
    s = np.asarray(s_values, dtype=np.float64)
    t = np.asarray(t_values, dtype=np.float64)
    A = np.stack([s, np.ones_like(s)], axis=1)
    coef, *_ = np.linalg.lstsq(A, t, rcond=None)
    return float(coef[0]), float(coef[1])


# ---------------------------------------------------------------------------
# the full model


@dataclass(frozen=True)
class LatencyModel:
    """Fitted T(b, s) model for one (target, draft, hardware) triple.

    alpha/beta: per-batch-size linear verify-latency fits (seconds);
    t_s: per-batch-size draft per-token latency t_S(b, 1) (seconds);
    c/gamma: acceptance power law.
    """
    alpha: Mapping[int, float]
    beta: Mapping[int, float]
    t_s: Mapping[int, float]
    c: float
    gamma: float

    def l_of_s(self, s: float) -> float:
        return 0.0 if s <= 0 else self.c * float(s) ** self.gamma

    def t_verify(self, b: int, s: int) -> float:
        return self.alpha[b] * s + self.beta[b]

    def per_token_time(self, b: int, s: int) -> float:
        """Expected time per generated token (T / N), the paper's Eq. 8."""
        num = self.t_verify(b, s) + s * self.t_s[b]
        return num / (self.l_of_s(s) + 1.0)

    def total_time(self, N: int, b: int, s: int) -> float:
        return N * self.per_token_time(b, s)

    def s_opt(self, b: int, s_max: int = 8) -> int:
        """Integer grid minimiser of per-token time over s in 0..s_max."""
        times = [self.per_token_time(b, s) for s in range(0, s_max + 1)]
        return int(np.argmin(times))

    def delta(self, b: int, s: float) -> float:
        """Stationarity residual (Eq. 11) with the draft cost folded into
        alpha_b the way the paper does ("we merge it with alpha_b")."""
        a_b = self.alpha[b] + self.t_s[b]
        K = (1.0 - self.gamma) * self.c
        L = self.c * self.beta[b] * self.gamma
        return K * a_b * s ** self.gamma - L * s ** (self.gamma - 1.0) + a_b

    @property
    def batch_sizes(self) -> Tuple[int, ...]:
        return tuple(sorted(self.alpha))


def fit_latency_model(
    verify_times: Mapping[int, Mapping[int, float]],
    draft_times: Mapping[int, float],
    run_lengths: Sequence[int],
    s_fit_range: Sequence[int] = tuple(range(1, 9)),
) -> LatencyModel:
    """Build a :class:`LatencyModel` from raw profiling measurements.

    verify_times[b][s] = measured t_L(b, s) for one verify call (seconds);
    draft_times[b]     = measured draft per-token time t_S(b, 1);
    run_lengths        = per-prompt accepted-run lengths for the l(s) fit.
    """
    alpha: Dict[int, float] = {}
    beta: Dict[int, float] = {}
    for b, per_s in verify_times.items():
        ss = sorted(per_s)
        a_, b_ = fit_linear_latency(ss, [per_s[s] for s in ss])
        alpha[b] = max(a_, 1e-9)
        beta[b] = max(b_, 0.0)
    ls = acceptance_curve(run_lengths, list(s_fit_range))
    c, gamma = fit_power_law(list(s_fit_range), ls)
    # clamp into the paper's regime (sub-linear, non-negative)
    gamma = min(max(gamma, 1e-3), 0.999)
    return LatencyModel(alpha=alpha, beta=beta, t_s=dict(draft_times), c=c, gamma=gamma)


# ---------------------------------------------------------------------------
# roofline-driven analytical backend (beyond-paper: DESIGN §8.1)
#
# On hardware we do not have (the 256-chip v5e pod) the wall-clock profile is
# replaced by a roofline estimate: one verify step at (b, s) moves
# ``weight_bytes + cache_bytes(b)`` through HBM and performs
# ``2 * params * b * (s+1)`` FLOPs; its latency is the max of the three
# roofline terms.  The same b -> s_opt machinery then applies unchanged.


@dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peak numbers (defaults: TPU v5e)."""
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link
    chips: int = 1

    def step_time(self, flops: float, bytes_hbm: float, bytes_coll: float = 0.0,
                  ) -> float:
        """Roofline latency of one step whose totals are given across all chips."""
        n = self.chips
        return max(flops / (n * self.peak_flops),
                   bytes_hbm / (n * self.hbm_bw),
                   bytes_coll / (n * self.ici_bw))


def roofline_latency_model(
    target_params: int, draft_params: int, hw: HardwareSpec,
    c: float, gamma: float,
    batch_sizes: Iterable[int] = (1, 2, 4, 8, 16, 32),
    bytes_per_param: int = 2,
    cache_bytes_per_seq: float = 0.0,
    collective_bytes_per_step: float = 0.0,
    s_max: int = 8,
) -> LatencyModel:
    """Analytical LatencyModel from parameter counts + hardware peaks.

    A verify step at (b, s) costs
      FLOPs      ~= 2 * target_params * b * (s + 1)
      HBM bytes  ~= target_params * bytes_per_param + b * cache_bytes_per_seq
    and a draft token costs the same with draft_params and s = 0.  alpha_b /
    beta are recovered by evaluating the roofline at s in {0..s_max} and
    fitting the same linear form the paper uses, so downstream code is
    identical for measured and analytical backends.
    """
    alpha: Dict[int, float] = {}
    beta: Dict[int, float] = {}
    t_s: Dict[int, float] = {}
    w_bytes = target_params * bytes_per_param
    dw_bytes = draft_params * bytes_per_param
    for b in batch_sizes:
        ss = list(range(0, s_max + 1))
        ts = [hw.step_time(2.0 * target_params * b * (s + 1),
                           w_bytes + b * cache_bytes_per_seq,
                           collective_bytes_per_step) for s in ss]
        a_, b_ = fit_linear_latency(ss, ts)
        alpha[b] = max(a_, 1e-12)
        beta[b] = max(b_, 1e-12)
        t_s[b] = hw.step_time(2.0 * draft_params * b,
                              dw_bytes + b * cache_bytes_per_seq * 0.1)
    return LatencyModel(alpha=alpha, beta=beta, t_s=t_s, c=c, gamma=gamma)
