"""Core speculative-decoding engine: the paper's primary contribution.

``spec_decode`` holds the batched draft-then-verify engine
(``SpecDecodeEngine`` and the jitted ``make_spec_step`` body) with
continuous-batching slot reuse and paged-KV support; ``analytical`` is
the paper's throughput model (when does speculation beat plain batched
decoding at a given batch size and acceptance rate); and ``adaptive``
is the occupancy-aware controller that picks the speculation length
``s`` per iteration from live batch feedback.
"""
