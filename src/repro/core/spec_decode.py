"""Batched speculative decoding (paper §3, Algorithm 1).

One speculative step at speculation length ``s`` for a batch of ``b`` ragged
requests, entirely inside a single jitted computation:

  1. draft phase — the small model (SSM) proposes s tokens autoregressively;
     its first feed is always the *two* most recently committed tokens, which
     restores the draft cache invariant regardless of how much of the
     previous speculation was accepted (DESIGN §3);
  2. verify — the target model scores all b x (s+1) positions in one forward
     (this is the paper's masking trick realized as ragged ring-buffer
     writes + position-based masks);
  3. accept — per request, the longest draft prefix matching the target's
     argmax, plus the target's bonus/correction token (always >=1 token of
     progress per step);
  4. commit — pure length updates for attention caches; checkpoint selection
     for recurrent (SSM / RG-LRU) targets.

``s = 0`` degenerates to plain batched autoregressive decoding (the paper's
no-speculation baseline) with the identical code path.

The engine jit-caches one step function per (batch, s) pair — exactly the
grid the adaptive profiler (core/adaptive.py) measures.

Slot-level runtime support (continuous batching, serving/scheduler.py): a
fixed-capacity :class:`DecodeState` acts as a KV *slot pool*.  Empty slots
are simply rows with ``done = True`` (the step function already masks them
out), so the same compiled step serves every occupancy level.
:meth:`SpecDecodeEngine.init_slots` allocates the pool,
:meth:`SpecDecodeEngine.prefill_into` injects one new request into a live
batch — a jit-cached B=1 prefill followed by a jit-cached per-capacity
scatter into the slot — and :meth:`SpecDecodeEngine.retire_slot` frees a
row, all without recompiling the (capacity, s) step function.

Paged KV design note (vLLM-style, enabling the paper's synergy at high
occupancy): passing ``block_size`` (and optionally ``num_blocks``) to
:meth:`SpecDecodeEngine.init_slots` replaces the per-slot contiguous ring
caches with one shared pool of fixed-size KV blocks.  The device half is
``k/v [nL, num_blocks, block_size, KVH, hd]`` plus a pool-wide ``pos`` map
and a per-slot block table ``bt [capacity, max_blocks]`` threaded through
``DecodeState.tcache``; the host half is a
:class:`~repro.serving.slots.PagedKVTables` free list carried on
``DecodeState.paged``.  Allocation is block-granular and follows the
commit frontier: ``prefill_into`` claims ``ceil(prompt/block)`` blocks and
scatters the B=1 prefill rows block-wise into the pool; every ``step``
first grows each live slot's table to cover its worst-case writes
(``seq_len + s`` rows — the s+1-token commit plus the verify feed) and
afterwards advances the host token mirror by the raw commit counts;
``retire_slot`` frees the blocks and clears their ``pos`` rows with one
jit-cached scatter so a recycled block can never leak stale attendable
keys.  Attention gathers each slot's logical view through the block table
(kernels/paged.py) and reuses the verify kernel unchanged, so short and
long requests stop sharing one worst-case ``cache_len`` and total KV
memory is ``num_blocks * block_size`` instead of ``capacity * cache_len``.
The draft model's (tiny) cache stays a contiguous ring at the logical
per-slot length.

Sharded serving (the production mesh): passing ``mesh`` to
:meth:`SpecDecodeEngine.init_slots` runs the whole slot pool as one SPMD
program.  The pool's capacity axis (and, for paged pools, the shared block
axis) is sharded over the mesh's data axes with the same
:func:`~repro.launch.specs._batch_spec` machinery the decode plans use;
params are placed replicated (data-parallel serving); and every jit-cached
engine function — the (capacity, s) step, the B=1 prefill and chunk
forwards (explicitly replicated), the inject / retire / chunk-commit
scatters — is compiled with explicit ``in_shardings`` / ``out_shardings``
so state never silently migrates or replicates between steps.  Host-side
bookkeeping (block free lists, slot claims, StepTrace) is unchanged, which
is what makes the sharded run token- and trace-identical to the
single-device run (tests/test_sharded_serving.py verifies this under
``XLA_FLAGS=--xla_force_host_platform_device_count=2``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from functools import partial, wraps
from typing import Any, Dict, Optional, TYPE_CHECKING, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.configs.registry import build_model
from repro.kernels.tuning import host_cu_blocks

if TYPE_CHECKING:  # real import is lazy: serving/__init__ imports back here
    from repro.serving.slots import PagedKVTables

Params = Any

# headroom rows in the per-request output buffer: one speculative step can
# commit up to s + 1 tokens past max_new, and prefill_into scatters B=1
# buffers into pool buffers, so both must size `out` identically.  It is
# also the hard ceiling on s: the step's `out` scatter silently drops
# writes past the buffer, so SpecDecodeEngine.step validates s <= S_MAX.
S_MAX = 8

# shared no-op context for the `engine.annotate` guards below: when device
# annotation is off, each jit dispatch enters this (reentrant, stateless)
# instead of constructing a jax.profiler.TraceAnnotation — the off path
# does no string formatting and allocates nothing
_NULLCTX = contextlib.nullcontext()


def _next_pow2(n: int) -> int:
    """Smallest power of two >= n (host ints; chunk rows-limit buckets)."""
    return 1 << max(int(n) - 1, 0).bit_length()


def _slot_axis(full_shape, single_shape) -> int:
    """The one axis where a B=1 leaf differs from the pool leaf."""
    diff = [i for i, (f, g) in enumerate(zip(full_shape, single_shape))
            if f != g]
    assert len(diff) == 1, (full_shape, single_shape)
    return diff[0]


def _take_slot(full, single, slot):
    """Slice one slot's B=1 view out of every leaf of a pool tree."""
    def one(f, s1):
        ax = _slot_axis(f.shape, s1.shape)
        starts = tuple(slot if i == ax else 0 for i in range(f.ndim))
        return jax.lax.dynamic_slice(f, starts, s1.shape)
    return jax.tree.map(one, full, single)


def _put_slot(full, upd, single, slot):
    """Scatter a B=1 update back into its slot row of a pool tree."""
    def one(f, u, s1):
        ax = _slot_axis(f.shape, s1.shape)
        starts = tuple(slot if i == ax else 0 for i in range(f.ndim))
        return jax.lax.dynamic_update_slice(f, u.astype(f.dtype), starts)
    return jax.tree.map(one, full, upd, single)


@dataclasses.dataclass
class DecodeState:
    """Device-side state of a running batch."""
    tcache: Any
    dcache: Any
    seq_lens: jax.Array      # [B] committed tokens (incl. any modality prefix)
    last2: jax.Array         # [B, 2] tokens at positions n-2, n-1
    out: jax.Array           # [B, max_new + s_max] generated tokens
    n_generated: jax.Array   # [B]
    done: jax.Array          # [B] bool
    # host half of the paged KV pool (block free list + per-slot tables);
    # None for contiguous per-slot ring caches
    paged: Optional["PagedKVTables"] = None


@dataclasses.dataclass
class StepStats:
    accepted: np.ndarray     # [B] accepted draft tokens this step (a)
    committed: np.ndarray    # [B] tokens committed this step (a+1, 0 if done)


@dataclasses.dataclass
class DeferredChunk:
    """A paged, NON-final prefill chunk whose host bookkeeping (block
    allocation, pending marking, first-chunk begin) has already run but
    whose forward dispatch was deferred (``prefill_chunk_into(...,
    defer=True)``).  Consumed either by :meth:`SpecDecodeEngine.
    step_with_chunk` — the mixed verify+chunk launch, one ragged kernel
    call serving both query kinds — or by :meth:`SpecDecodeEngine.
    flush_chunk`, the ordinary standalone dispatch.  Either way the pool
    ends bit-identical (per-query-row independence; the parked slot's
    verify writes are dropped in both orders)."""
    slot: int
    tokens: np.ndarray       # the CB-bucketed chunk tokens
    start: int               # first feed position this chunk writes
    total_len: int           # the request's full prompt(+stash) length
    bt_row: np.ndarray       # [max_blocks] the slot's host table row
    key: Tuple               # the standalone chunk-fn cache key


@dataclasses.dataclass
class PoolShardings:
    """NamedSharding trees of a mesh-sharded slot pool (one per init_slots).

    Every engine jit below threads these through ``in_shardings`` /
    ``out_shardings``: pool-shaped leaves carry their capacity-axis (or
    block-axis) sharding, while params, B=1 prefill outputs, scalars, and
    host-built index vectors use ``rep`` (explicitly replicated).
    """
    tcache: Any
    dcache: Any              # None when the engine has no draft model
    seq_lens: Any
    last2: Any
    out: Any
    n_generated: Any
    done: Any
    rep: Any                 # NamedSharding(mesh, P()) — fully replicated
    cu: Any = None           # cu_blocks / cu_row ragged-grid scalar operands

    @property
    def cu_sh(self):
        """Sharding of the host-built cu operands (rep if spec absent)."""
        return self.cu if self.cu is not None else self.rep

    @property
    def dc(self):
        """Draft-cache sharding usable as a jit prefix (rep if no draft)."""
        return self.dcache if self.dcache is not None else self.rep


@dataclasses.dataclass
class JitEntry:
    """One live engine jit, registered for graph-lint (tools/graphlint).

    Every jit the engine builds goes through
    :meth:`SpecDecodeEngine._register_jit`, which records the compiled
    function together with its standing contracts — which argnums carry KV
    pool / cache leaves and must be donated (``kv_args``), the declared
    output shardings of a sharded pool, whether the paged fused path may
    legally materialize a gathered-KV view — plus a trace counter and the
    arg/out ShapeDtypeStructs captured at trace time, so graph-lint can
    re-lower exactly the jits the dispatch loop runs instead of a drifting
    hand-maintained list.
    """
    name: str                      # jit family: step / prefill / inject / ...
    key: Tuple                     # engine cache key, e.g. (B, s) for step
    hot: bool                      # dispatched inside the serving iteration
    kv_args: Tuple[int, ...]       # argnums that carry pool/cache leaves
    donate: Tuple[int, ...]        # argnums actually passed to donate_argnums
    sharded: bool                  # built with explicit in/out shardings
    out_shardings: Any             # declared out_shardings tree (or None)
    paged_rows: Optional[int]      # paged pool logical_len (gather-view rows)
    paged_fused: Any               # tcfg.paged_fused at build time
    src_file: str                  # def site of the traced fn
    src_line: int
    cu_arg: Optional[int] = None   # argnum of the cu_blocks ragged-grid operand
    fn: Any = None                 # the jax.jit-wrapped callable
    n_traces: int = 0              # incremented on every (re)trace
    arg_specs: Any = None          # ShapeDtypeStruct tree of the last trace
    out_specs: Any = None


def _trace_spec(x):
    """ShapeDtypeStruct of a leaf seen during tracing (tracers carry avals)."""
    aval = getattr(x, "aval", None)
    if aval is not None:
        return jax.ShapeDtypeStruct(aval.shape, aval.dtype)
    return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))


def _copy_arrays(tree):
    """Deep-copy every jax.Array leaf of ``tree`` (sharding-preserving).

    Warm (compile-only) dispatches discard their results; with buffer
    donation the call would otherwise invalidate the *live* pool buffers it
    was handed, so warm paths feed the jits disposable copies instead.
    """
    return jax.tree.map(
        lambda x: jnp.copy(x) if isinstance(x, jax.Array) else x, tree)


class SpecDecodeEngine:
    """Target + draft pair with adaptive-ready batched speculative stepping."""

    def __init__(self, target_cfg: ModelConfig, draft_cfg: Optional[ModelConfig],
                 max_new: int = 128, eos_id: int = -1, dtype=jnp.float32,
                 sample: bool = False, temperature: float = 1.0,
                 paged_fused: Optional[bool] = None,
                 donate: bool = True):
        if paged_fused is not None:
            # route the paged-pool attention (kernels/paged.py): None = auto
            # (fused on TPU, gather reference on CPU), True = force the
            # fused streaming kernel, False = force gather+verify.  The
            # flag is trace-time static, so it lives on the model config
            # and every engine jit compiled from it picks it up.
            target_cfg = target_cfg.with_(paged_fused=paged_fused)
        self.tcfg = target_cfg
        self.dcfg = draft_cfg
        self.target = build_model(target_cfg)
        self.draft = build_model(draft_cfg) if draft_cfg is not None else None
        self.max_new = max_new
        self.eos_id = eos_id
        self.dtype = dtype
        self.sample = sample
        self.temperature = temperature
        # buffer donation for the KV pool / cache leaves of every state-
        # threading jit (step / inject / retire / chunk): each dispatch
        # reuses its input pool buffers for the outputs instead of double-
        # buffering the multi-GB pool.  donate=False keeps the old copying
        # semantics — callers that re-step a *stale* DecodeState (the input
        # buffers of a previous step) need it, since donation deletes those
        # buffers.  graph-lint's donation pass is the standing proof that
        # the default stays on and actually aliases in the lowered HLO.
        self.donate = donate
        # opt-in device-side phase tracing (serving/telemetry.py): when
        # True, every jit dispatch runs under a jax.profiler.TraceAnnotation
        # scope so a profiler trace attributes device time per serving
        # phase.  TraceAnnotation is a no-op outside an active trace; with
        # the flag False the dispatch sites enter a shared nullcontext and
        # never even format the annotation name.
        self.annotate: bool = False
        # draft models are text-only: for VLM targets their positions run
        # without the modality prefix offset
        self.prefix_offset = target_cfg.prefix_len if target_cfg.family == "vlm" else 0
        self._step_fns: Dict[Tuple[int, int], Any] = {}
        self._mixed_step_fns: Dict[Tuple, Any] = {}
        self._prefill_fns: Dict[Tuple[int, int, int], Any] = {}
        self._inject_fn: Any = None
        self._inject_paged_fn: Any = None
        self._retire_fn: Any = None
        self._retire_paged_fn: Any = None
        self._chunk_fns: Dict[Tuple, Any] = {}
        self._chunk_begin_fns: Dict[bool, Any] = {}
        self._chunk_commit_fns: Dict[bool, Any] = {}
        # prefix-cache (shared-block) paths: draft-only prefix prefill keyed
        # (P_pad, L), the attach park scatter keyed (has_draft,), the COW
        # block-copy scatter and the evicted-block pos wipe
        self._attach_fns: Dict[Tuple[int, int], Any] = {}
        self._attach_park_fns: Dict[bool, Any] = {}
        self._block_copy_fn: Any = None
        self._evict_fn: Any = None
        # graph-lint jit registry: one JitEntry per live compiled function,
        # keyed (name, key).  Populated by _register_jit as the caches above
        # fill; cleared with them so the registry never outlives a sharding
        # or kernel-routing change.
        self.jit_registry: Dict[Tuple[str, Tuple], JitEntry] = {}
        # sharded-serving state, set by init_slots(mesh=...): the mesh, the
        # pool's NamedSharding trees, the capacity they were built for, and
        # how many data shards the capacity axis splits into
        self.mesh: Optional[Mesh] = None
        self._shardings: Optional[PoolShardings] = None
        self._shard_capacity: Optional[int] = None
        self.n_data_shards: int = 1
        # True when init_slots auto-pinned paged_fused=False for a sharded
        # paged pool (restored to auto on the next unsharded init_slots)
        self._paged_fused_auto: bool = False

    def set_paged_fused(self, paged_fused: Optional[bool]) -> None:
        """Re-route the paged-pool attention kernel (fused vs gather).

        The flag is baked into every traced step/prefill/chunk function, so
        flipping it rebuilds the target model from its config and drops all
        cached compilations.  Call before :meth:`init_slots` — a pool
        mid-flight would otherwise mix kernels across steps (numerically
        identical, but the point of forcing a path is to not mix them).
        """
        # any explicit call supersedes a sharded-pool auto-pin: the next
        # unsharded init_slots must not silently revert the caller's choice
        self._paged_fused_auto = False
        if paged_fused == self.tcfg.paged_fused:
            return
        self.tcfg = self.tcfg.with_(paged_fused=paged_fused)
        self.target = build_model(self.tcfg)
        self._reset_jit_caches()

    def _reset_jit_caches(self) -> None:
        """Drop every cached compilation.  init_slots calls this so a pool
        re-initialised with a different mesh (or none) can never reuse a
        step/prefill/inject function compiled for the old sharding."""
        self._step_fns.clear()
        self._mixed_step_fns.clear()
        self._prefill_fns.clear()
        self._inject_fn = None
        self._inject_paged_fn = None
        self._retire_fn = None
        self._retire_paged_fn = None
        self._chunk_fns.clear()
        self._chunk_begin_fns.clear()
        self._chunk_commit_fns.clear()
        self._attach_fns.clear()
        self._attach_park_fns.clear()
        self._block_copy_fn = None
        self._evict_fn = None
        self.jit_registry.clear()

    def _register_jit(self, name: str, key: Tuple, fn, *, hot: bool,
                      kv_args: Tuple[int, ...] = (),
                      in_shardings=None, out_shardings=None,
                      paged_rows: Optional[int] = None,
                      cu_arg: Optional[int] = None):
        """jax.jit ``fn`` with the engine's standing contracts attached.

        ``kv_args`` are the argnums carrying KV pool / cache leaves: they
        become ``donate_argnums`` (unless the engine was built with
        ``donate=False``) and are recorded on the :class:`JitEntry` either
        way, so graph-lint can flag an engine whose pool leaves stopped
        being donated.  The wrapper body only runs while jax traces, so the
        per-entry trace counter and arg/out spec capture cost nothing on
        the cached dispatch path.
        """
        donate = tuple(kv_args) if self.donate else ()
        code = fn.__code__
        entry = JitEntry(
            name=name, key=tuple(key), hot=hot, kv_args=tuple(kv_args),
            donate=donate, sharded=in_shardings is not None,
            out_shardings=out_shardings, paged_rows=paged_rows,
            paged_fused=self.tcfg.paged_fused, cu_arg=cu_arg,
            src_file=code.co_filename, src_line=code.co_firstlineno)

        @wraps(fn)
        def counted(*args, **kwargs):
            entry.n_traces += 1
            entry.arg_specs = jax.tree.map(_trace_spec, args)
            out = fn(*args, **kwargs)
            entry.out_specs = jax.tree.map(_trace_spec, out)
            return out

        kw: Dict[str, Any] = {}
        if in_shardings is not None:
            kw["in_shardings"] = in_shardings
            kw["out_shardings"] = out_shardings
        if donate:
            kw["donate_argnums"] = donate
        # lint: allow-jit-sharding(shardings thread through **kw; every builder call site picks them under its own `sh is None` branch)
        entry.fn = jax.jit(counted, **kw)
        self.jit_registry[(name, tuple(key))] = entry
        return entry.fn

    # ------------------------------------------------------------------
    # prefill

    def _build_prefill(self, B: int, P: int, cache_len: int):
        tgt, drf = self.target, self.draft

        def fn(tparams, dparams, tokens, prompt_lens, tkw):
            src_len = (tkw["src_embeds"].shape[1]
                       if self.tcfg.family in ("encdec", "audio") else None)
            tcache, dcache = self._init_caches(B, cache_len, src_len)
            _, tcache, total = tgt.prefill(tparams, tokens, tcache,
                                           prompt_lens=prompt_lens - 1, **tkw)
            seq_lens = total + 1
            if drf is not None:
                _, dcache, _ = drf.prefill(dparams, tokens, dcache,
                                           prompt_lens=prompt_lens - 2)
            bidx = jnp.arange(B)
            last2 = jnp.stack([tokens[bidx, prompt_lens - 2],
                               tokens[bidx, prompt_lens - 1]], axis=1)
            return tcache, dcache, seq_lens, last2

        sh = self._shardings
        if sh is None:
            return self._register_jit("prefill", (B, P, cache_len), fn,
                                      hot=False)
        # sharded pool: the B=1 admission prefill runs explicitly REPLICATED
        # across the mesh (B=1 cannot shard the batch axis) so its outputs
        # can be scattered into any slot of any data shard without an
        # implicit-replication round-trip
        return self._register_jit("prefill", (B, P, cache_len), fn, hot=False,
                                  in_shardings=(sh.rep,) * 5,
                                  out_shardings=sh.rep)

    def prefill(self, tparams, dparams, tokens: jax.Array, prompt_lens: jax.Array,
                cache_len: int, target_extras: Optional[Dict] = None) -> DecodeState:
        B, P = tokens.shape
        assert int(np.min(np.asarray(prompt_lens))) >= 3, "prompts need >= 3 tokens"
        key = (B, P, cache_len)
        if key not in self._prefill_fns:
            self._prefill_fns[key] = self._build_prefill(B, P, cache_len)
        with (jax.profiler.TraceAnnotation(f"repro/prefill[B={B},P={P}]")
              if self.annotate else _NULLCTX):
            tcache, dcache, seq_lens, last2 = self._prefill_fns[key](
                tparams, dparams, jnp.asarray(tokens),
                jnp.asarray(prompt_lens), target_extras or {})
        return DecodeState(
            tcache=tcache, dcache=dcache, seq_lens=seq_lens, last2=last2,
            out=jnp.zeros((B, self.max_new + S_MAX + 1), jnp.int32),
            n_generated=jnp.zeros((B,), jnp.int32),
            done=jnp.zeros((B,), bool),
        )

    # ------------------------------------------------------------------
    # slot pool (continuous batching; serving/scheduler.py drives this)

    def _init_caches(self, B: int, cache_len: int, src_len: Optional[int] = None):
        tgt, drf = self.target, self.draft
        if self.tcfg.family in ("encdec", "audio"):
            tcache = tgt.init_cache(B, cache_len=cache_len, dtype=self.dtype,
                                    src_len=src_len or cache_len)
        elif self.tcfg.family == "ssm":
            tcache = tgt.init_cache(B, dtype=self.dtype)
        else:
            tcache = tgt.init_cache(B, cache_len=cache_len, dtype=self.dtype)
        dcache = (drf.init_cache(B, cache_len=cache_len, dtype=self.dtype)
                  if drf is not None else None)
        return tcache, dcache

    def init_slots(self, capacity: int, cache_len: int,
                   src_len: Optional[int] = None, *,
                   block_size: Optional[int] = None,
                   num_blocks: Optional[int] = None,
                   mesh: Optional[Mesh] = None) -> DecodeState:
        """Blank fixed-capacity slot pool: every row is an empty slot
        (``done = True``), ready to be claimed via :meth:`prefill_into`.

        With ``block_size`` set, the target KV lives in a paged block pool
        instead of per-slot rings: ``cache_len`` becomes the per-slot
        *logical* cap (rounded up to whole blocks) and ``num_blocks``
        (default: worst case, ``capacity * blocks_per_slot``) sizes the
        shared pool — undersize it to trade memory for scheduler
        preemptions.  See the module docstring's paged KV design note.

        With ``mesh`` set, the pool lives sharded on that mesh (module
        docstring, sharded-serving note): the capacity axis — and the block
        axis of a paged pool — splits over the mesh's data axes, and every
        engine function compiled for this pool carries explicit in/out
        shardings.  Params must be placed replicated on the same mesh by
        the caller (``jax.device_put(params, NamedSharding(mesh, P()))``;
        :class:`~repro.serving.scheduler.ContinuousEngineBackend` does
        this).  Each init_slots call resets the jit caches and the engine's
        sharding state, so the same engine can serve sharded and unsharded
        pools in sequence (never concurrently).

        Sharded **paged** pools pin the paged-attention routing to the
        gather path when it is on auto (``paged_fused=None``): the fused
        kernel's scalar-prefetched block table may reference any shard's
        blocks (allocation is not shard-local), which GSPMD cannot
        partition through a ``pallas_call``.  Forcing ``paged_fused=True``
        overrides; the next unsharded init_slots restores auto routing.
        """
        if mesh is not None or self._shardings is not None:
            # entering or leaving sharded mode: compilations for the other
            # placement must never be reused.  Unsharded -> unsharded keeps
            # the caches (repeat backends stay warm).
            self._reset_jit_caches()
        if block_size is not None and mesh is not None \
                and self.tcfg.paged_fused is None:
            # sharded paged pool + auto kernel routing: the fused kernel's
            # pallas_call cannot be partitioned over the block-sharded pool
            # by GSPMD (its prefetched block table may reference any
            # shard's blocks — the allocator is not shard-local), so auto
            # routes through the gather path's collectives.  Forcing
            # paged_fused=True overrides (ROADMAP: block-locality-aware
            # allocation is the open item that would lift this).
            self.set_paged_fused(False)
            self._paged_fused_auto = True
        elif getattr(self, "_paged_fused_auto", False) and mesh is None:
            # leaving sharded mode: restore auto routing (fused on TPU)
            self.set_paged_fused(None)
            self._paged_fused_auto = False
        self.mesh = mesh
        self._shardings = None
        self._shard_capacity = None
        self.n_data_shards = 1
        if block_size is None:
            tcache, dcache = self._init_caches(capacity, cache_len, src_len)
            paged = None
        else:
            from repro.serving.slots import PagedKVTables
            if not hasattr(self.target, "init_paged_cache"):
                raise NotImplementedError(
                    f"paged KV is not supported for family "
                    f"'{self.tcfg.family}'")
            max_blocks = -(-cache_len // block_size)
            if num_blocks is None:
                num_blocks = capacity * max_blocks
            paged = PagedKVTables(num_blocks, block_size, capacity, max_blocks)
            tcache = self.target.init_paged_cache(num_blocks, block_size,
                                                  dtype=self.dtype)
            tcache["bt"] = jnp.full((capacity, max_blocks), -1, jnp.int32)
            # the (tiny) draft keeps a contiguous ring at the logical cap
            dcache = (self.draft.init_cache(capacity,
                                            cache_len=paged.logical_len,
                                            dtype=self.dtype)
                      if self.draft is not None else None)
        state = DecodeState(
            tcache=tcache, dcache=dcache,
            # seq_lens = 2 keeps the masked step's positions non-negative
            seq_lens=jnp.full((capacity,), 2, jnp.int32),
            last2=jnp.zeros((capacity, 2), jnp.int32),
            out=jnp.zeros((capacity, self.max_new + S_MAX + 1), jnp.int32),
            n_generated=jnp.zeros((capacity,), jnp.int32),
            done=jnp.ones((capacity,), bool),
            paged=paged)
        if mesh is not None:
            state = self._shard_slot_pool(state, mesh, capacity)
        return state

    def _shard_slot_pool(self, state: DecodeState, mesh: Mesh,
                         capacity: int) -> DecodeState:
        """Place a fresh slot pool on ``mesh`` and record its shardings.

        Reuses the decode-plan sharding machinery (launch/specs.py): the
        capacity axis shards like a decode plan's batch dim, paged block
        arrays shard over the block axis, everything else replicates.
        """
        # lazy import: launch/specs.py imports make_spec_step back from here
        from repro.launch.specs import _ns, slot_pool_specs
        sp = slot_pool_specs(
            mesh, self.target, self.draft, capacity,
            paged_num_blocks=(state.paged.num_blocks
                              if state.paged is not None else None))
        sh = PoolShardings(
            tcache=_ns(mesh, sp.tcache),
            dcache=(_ns(mesh, sp.dcache) if sp.dcache is not None else None),
            seq_lens=_ns(mesh, sp.seq_lens), last2=_ns(mesh, sp.last2),
            out=_ns(mesh, sp.out), n_generated=_ns(mesh, sp.n_generated),
            done=_ns(mesh, sp.done),
            rep=NamedSharding(mesh, PartitionSpec()),
            cu=_ns(mesh, sp.cu_blocks))
        state = dataclasses.replace(
            state,
            tcache=jax.device_put(state.tcache, sh.tcache),
            dcache=(jax.device_put(state.dcache, sh.dcache)
                    if state.dcache is not None else None),
            seq_lens=jax.device_put(state.seq_lens, sh.seq_lens),
            last2=jax.device_put(state.last2, sh.last2),
            out=jax.device_put(state.out, sh.out),
            n_generated=jax.device_put(state.n_generated, sh.n_generated),
            done=jax.device_put(state.done, sh.done))
        self._shardings = sh
        self._shard_capacity = capacity
        self.n_data_shards = sp.n_shards
        return state

    @staticmethod
    def _slot_axis(full_shape, single_shape) -> int:
        """The one axis where a B=1 leaf differs from the pool leaf."""
        return _slot_axis(full_shape, single_shape)

    def _build_inject(self, paged_pool: bool = False):
        """Scatter every B=1 prefill leaf into its slot row of the pool.

        On a sharded pool the jit carries explicit shardings: the pool tuple
        keeps its capacity-axis shardings on both sides of the scatter and
        the replicated B=1 leaves are consumed as such — the update-slice at
        a dynamic slot lowers to SPMD without replicating the pool.
        ``paged_pool`` only selects the sharding tuple for the ``full``
        argument (the paged path injects the target cache separately via
        :meth:`_build_inject_paged`).
        """
        def fn(full, single, slot):
            def upd(f, x):
                ax = self._slot_axis(f.shape, x.shape)
                starts = tuple(slot if i == ax else 0 for i in range(f.ndim))
                return jax.lax.dynamic_update_slice(f, x.astype(f.dtype), starts)
            return jax.tree.map(upd, full, single)

        sh = self._shardings
        if sh is None:
            return self._register_jit("inject", (paged_pool,), fn, hot=True,
                                      kv_args=(0,))
        if paged_pool:
            full_sh = (sh.dc, sh.seq_lens, sh.last2, sh.out,
                       sh.n_generated, sh.done)
        else:
            full_sh = (sh.tcache, sh.dc, sh.seq_lens, sh.last2, sh.out,
                       sh.n_generated, sh.done)
        return self._register_jit("inject", (paged_pool,), fn, hot=True,
                                  kv_args=(0,),
                                  in_shardings=(full_sh, sh.rep, sh.rep),
                                  out_shardings=full_sh)

    def _build_inject_paged(self):
        """Scatter a B=1 contiguous prefill into the paged pool block-wise.

        ``scat_tbl`` is the slot's block table padded with ``num_blocks``
        (an out-of-range row that ``mode="drop"`` discards) so unallocated
        logical blocks never touch the pool; ``bt_row`` is the same table
        padded with -1 for the device block table.
        """
        def fn(tcache, single_tc, slot, scat_tbl, bt_row):
            NB, bs = tcache["pos"].shape
            MAXB = scat_tbl.shape[0]
            new = {}
            # per-row leaves (k/v, plus k_scale/v_scale on an int8 pool):
            # the B=1 contiguous row [nL, L, ...] folds to [nL, MAXB, bs,
            # ...] and scatters block-wise through the slot's table
            for name in tcache:
                if name in ("pos", "bt"):
                    continue
                s1 = single_tc[name][:, 0]               # [nL, L, ...]
                nL = s1.shape[0]
                s1 = s1.reshape(nL, MAXB, bs, *s1.shape[2:])
                # lint: allow-cow-write(whole-prompt inject: scat_tbl holds only blocks prefill just allocated at refcount 1 — a shared block can never appear here)
                new[name] = tcache[name].at[:, scat_tbl].set(
                    s1.astype(tcache[name].dtype), mode="drop")
            spos = single_tc["pos"][0].reshape(MAXB, bs)
            # lint: allow-cow-write(same freshly-allocated scat_tbl as the k/v scatter above)
            new["pos"] = tcache["pos"].at[scat_tbl].set(spos, mode="drop")
            new["bt"] = tcache["bt"].at[slot].set(bt_row)
            return new

        sh = self._shardings
        if sh is None:
            return self._register_jit("inject_paged", (), fn, hot=True,
                                      kv_args=(0,))
        return self._register_jit("inject_paged", (), fn, hot=True,
                                  kv_args=(0,),
                                  in_shardings=(sh.tcache, sh.rep, sh.rep,
                                                sh.rep, sh.rep),
                                  out_shardings=sh.tcache)

    def prefill_into(self, tparams, dparams, state: DecodeState, slot: int,
                     tokens, prompt_len: int, cache_len: int,
                     target_extras: Optional[Dict] = None,
                     warm: bool = False) -> DecodeState:
        """Inject one new request into row ``slot`` of a live slot pool.

        Runs the (jit-cached, B=1) prefill for the prompt, then scatters every
        per-slot leaf — KV/state caches, seq_lens, last2, out, n_generated,
        done — into the pool with one jit-cached dynamic-update-slice tree.
        The (capacity, s) step function is untouched, so admitting a request
        never recompiles the serving step.

        Paged pool: allocates ``ceil(prompt_len / block_size)`` blocks from
        the free list and scatters the prefill rows block-wise through the
        table.  ``warm=True`` compiles the path without allocating blocks or
        mutating host bookkeeping (the result must be discarded).
        """
        tokens = np.asarray(tokens, np.int32).reshape(1, -1)
        if state.paged is not None:
            cache_len = state.paged.logical_len
        single = self.prefill(tparams, dparams, tokens,
                              np.array([prompt_len], np.int32), cache_len,
                              target_extras)
        capacity = int(state.seq_lens.shape[0])
        if self._inject_fn is None:
            self._inject_fn = self._build_inject(
                paged_pool=state.paged is not None)
        if warm:
            # donation shield: the discarded warm dispatch must not consume
            # the live pool's buffers
            state = self._warm_shield(state)
        if state.paged is None:
            if capacity == 1:
                return single
            full = (state.tcache, state.dcache, state.seq_lens, state.last2,
                    state.out, state.n_generated, state.done)
            one = (single.tcache, single.dcache, single.seq_lens, single.last2,
                   single.out, single.n_generated, single.done)
            with (jax.profiler.TraceAnnotation("repro/inject")
                  if self.annotate else _NULLCTX):
                return DecodeState(*self._inject_fn(full, one,
                                                    jnp.int32(slot)))
        pk = state.paged
        scat_tbl = np.full((pk.max_blocks,), pk.num_blocks, np.int32)
        bt_row = np.full((pk.max_blocks,), -1, np.int32)
        if not warm:
            pk.prefill(slot, prompt_len)
            ids = pk.table(slot)
            scat_tbl[:len(ids)] = ids
            bt_row[:len(ids)] = ids
            # the allocation may have reclaimed cache blocks: wipe their
            # stale pos rows before the inject can hand them a new owner
            state = self._drain_evicted(state)
        if self._inject_paged_fn is None:
            self._inject_paged_fn = self._build_inject_paged()
        with (jax.profiler.TraceAnnotation("repro/inject")
              if self.annotate else _NULLCTX):
            tcache = self._inject_paged_fn(state.tcache, single.tcache,
                                           jnp.int32(slot),
                                           jnp.asarray(scat_tbl),
                                           jnp.asarray(bt_row))
            full = (state.dcache, state.seq_lens, state.last2, state.out,
                    state.n_generated, state.done)
            one = (single.dcache, single.seq_lens, single.last2, single.out,
                   single.n_generated, single.done)
            dcache, seq_lens, last2, out, n_gen, done = \
                self._inject_fn(full, one, jnp.int32(slot))
        return DecodeState(tcache=tcache, dcache=dcache, seq_lens=seq_lens,
                           last2=last2, out=out, n_generated=n_gen, done=done,
                           paged=pk)

    def retire_slot(self, state: DecodeState, slot: int) -> DecodeState:
        """Free a slot (mark done): the masked step stops committing for it,
        and the row can be re-claimed by the next :meth:`prefill_into`.

        Both paths are jit-cached device scatters — no host round-trip, so
        retirement stays off the step loop's critical path.  The paged path
        additionally frees the slot's blocks and clears their ``pos`` rows,
        so a recycled block can never leak stale attendable keys into its
        next owner.
        """
        sh = self._shardings
        if state.paged is not None:
            pk = state.paged
            freed = pk.release(slot)
            pad = np.full((pk.max_blocks,), pk.num_blocks, np.int32)
            pad[:len(freed)] = freed
            if self._retire_paged_fn is None:
                def fn(done, pos, bt, slot, freed):
                    return (done.at[slot].set(True),
                            pos.at[freed].set(-1, mode="drop"),  # lint: allow-cow-write(retire wipe: freed is the actually-freed list from release — refcount-0 by construction; surviving shared blocks are excluded)
                            bt.at[slot].set(-1))
                if sh is None:
                    self._retire_paged_fn = self._register_jit(
                        "retire_paged", (), fn, hot=True, kv_args=(0, 1, 2))
                else:
                    self._retire_paged_fn = self._register_jit(
                        "retire_paged", (), fn, hot=True, kv_args=(0, 1, 2),
                        in_shardings=(sh.done, sh.tcache["pos"],
                                      sh.tcache["bt"], sh.rep, sh.rep),
                        out_shardings=(sh.done, sh.tcache["pos"],
                                       sh.tcache["bt"]))
            with (jax.profiler.TraceAnnotation("repro/retire")
                  if self.annotate else _NULLCTX):
                done, pos, bt = self._retire_paged_fn(
                    state.done, state.tcache["pos"], state.tcache["bt"],
                    jnp.int32(slot), jnp.asarray(pad))
            return dataclasses.replace(
                state, done=done, tcache=dict(state.tcache, pos=pos, bt=bt))
        if self._retire_fn is None:
            fn = lambda done, slot: done.at[slot].set(True)
            self._retire_fn = (
                self._register_jit("retire", (), fn, hot=True, kv_args=(0,))
                if sh is None else
                self._register_jit("retire", (), fn, hot=True, kv_args=(0,),
                                   in_shardings=(sh.done, sh.rep),
                                   out_shardings=sh.done))
        with (jax.profiler.TraceAnnotation("repro/retire")
              if self.annotate else _NULLCTX):
            done = self._retire_fn(state.done, jnp.int32(slot))
        return dataclasses.replace(state, done=done)

    # ------------------------------------------------------------------
    # prefix-cache admission (shared blocks; serving/prefix_cache.py is the
    # host index, serving/scheduler.py drives this.  Unsharded paged pools
    # only — the backend refuses prefix_cache + mesh, see scheduler.py)

    def _require_unsharded(self, what: str) -> None:
        if self._shardings is not None:
            raise RuntimeError(
                f"{what} is not supported on a mesh-sharded pool: shared "
                f"blocks may live on any shard (allocation is not "
                f"shard-local) — serve with prefix_cache=False, or "
                f"unsharded")

    def _build_draft_prefill(self, P: int, L: int):
        """Draft-only B=1 prefill of a cached prefix: shared target blocks
        carry target KV only, so the (tiny, contiguous-ring) draft cache
        recomputes its rows ``[0, limit)`` for the attached prompt."""
        drf = self.draft

        def fn(dparams, tokens, limit):
            dcache = drf.init_cache(1, cache_len=L, dtype=self.dtype)
            _, dcache, _ = drf.prefill(dparams, tokens, dcache,
                                       prompt_lens=limit)
            return dcache

        return self._register_jit("draft_prefill", (P, L), fn, hot=False)

    def _build_attach_park(self, has_draft: bool):
        """Park an attach-admitted slot: scatter the draft's B=1 prefix
        cache into its pool row (replacing the previous occupant's rows
        wholesale, same stale-key guarantee as inject) and park
        ``seq_lens[slot]`` at the feed's final length — the identical
        parked-row contract chunked prefill relies on (_build_chunk_begin):
        interleaved decode steps' masked garbage writes for the still-done
        slot land past every suffix-chunk query and are rewritten by the
        slot's own first real step."""
        if has_draft:
            def fn(dcache, d_single, seq_lens, slot, total_len):
                def upd(f, x):
                    ax = self._slot_axis(f.shape, x.shape)
                    starts = tuple(slot if i == ax else 0
                                   for i in range(f.ndim))
                    return jax.lax.dynamic_update_slice(
                        f, x.astype(f.dtype), starts)
                return (jax.tree.map(upd, dcache, d_single),
                        seq_lens.at[slot].set(total_len))
            kv = (0, 2)
        else:
            def fn(seq_lens, slot, total_len):
                return seq_lens.at[slot].set(total_len)
            kv = (0,)
        return self._register_jit("attach_park", (has_draft,), fn, hot=True,
                                  kv_args=kv)

    def _build_block_copy(self):
        """COW resolve: copy every leaf's rows of blocks ``src[i]`` into
        ``dst[i]``.  Pairs are padded with ``num_blocks`` — the gather
        clamps (reads a garbage block) and the scatter drops (never writes
        it), so one compilation serves any pair count."""
        def fn(tcache, src, dst):
            new = {}
            for name in tcache:
                if name == "bt":
                    new[name] = tcache[name]
                elif name == "pos":
                    new[name] = tcache[name].at[dst].set(
                        tcache[name][src], mode="drop")
                else:
                    new[name] = tcache[name].at[:, dst].set(
                        tcache[name][:, src], mode="drop")
            return new
        return self._register_jit("block_copy", (), fn, hot=True,
                                  kv_args=(0,))

    def _build_evict_clear(self):
        def fn(pos, blocks):
            # lint: allow-cow-write(eviction wipe: the blocks are refcount-0 by construction — reclaim just freed them — and -1 rows are never attendable)
            return pos.at[blocks].set(-1, mode="drop")
        return self._register_jit("evict_clear", (), fn, hot=True,
                                  kv_args=(0,))

    def _drain_evicted(self, state: DecodeState) -> DecodeState:
        """Wipe device ``pos`` rows of cache blocks evicted by
        reclaim-under-pressure (slots.PagedKVTables.evicted_pending).

        Must run after any host allocation and before the next dispatch
        that could write (or attend) the re-allocated ids — that restores
        the standing "free blocks carry pos = -1" invariant before the
        block can be handed to a new owner.  Every allocating engine entry
        point calls this on its non-warm path.
        """
        pk = state.paged
        if pk is None or not pk.evicted_pending:
            return state
        ids = pk.evicted_pending
        pk.evicted_pending = []
        pad = np.full((pk.num_blocks,), pk.num_blocks, np.int32)
        pad[:len(ids)] = ids
        if self._evict_fn is None:
            self._evict_fn = self._build_evict_clear()
        with (jax.profiler.TraceAnnotation("repro/evict_clear")
              if self.annotate else _NULLCTX):
            pos = self._evict_fn(state.tcache["pos"], jnp.asarray(pad))
        return dataclasses.replace(state, tcache=dict(state.tcache, pos=pos))

    def attach_prefix(self, dparams, state: DecodeState, slot: int,
                      tokens, n_prefix: int, total_len: int, *,
                      warm: bool = False) -> DecodeState:
        """Admit a request whose first ``n_prefix`` feed rows are cached.

        Host side: the (already locked) cache blocks were mapped into the
        slot's table by the backend (`PagedKVTables.attach`); this call
        marks the slot pending and handles the device half — a draft-only
        prefix prefill (shared blocks hold target KV only) scattered into
        the slot's draft ring, and the parked ``seq_lens``.  The uncached
        suffix rows ``[n_prefix, total_len - 1)`` then flow through the
        ordinary :meth:`prefill_chunk_into` path (``start = n_prefix``),
        which a zero-suffix admission skips (see the backend's
        ``commit_attached``).

        ``tokens`` is the bucket-padded feed (prompt + stash); the draft
        consumes rows ``[0, min(n_prefix, total_len - 2))`` of it.

        ``warm=True`` compiles the draft-prefill and park paths for this
        token bucket without touching host bookkeeping (result discarded).
        """
        self._require_unsharded("prefix-cache attach")
        pk = state.paged
        assert pk is not None, "attach_prefix needs a paged pool"
        if warm:
            state = self._warm_shield(state)
        else:
            pk.mark_pending(slot)
        has_draft = self.draft is not None
        if has_draft:
            tokens = np.asarray(tokens, np.int32).reshape(1, -1)
            P = int(tokens.shape[1])
            L = pk.logical_len
            dlim = min(n_prefix, total_len - 2)
            if (P, L) not in self._attach_fns:
                self._attach_fns[(P, L)] = self._build_draft_prefill(P, L)
            with (jax.profiler.TraceAnnotation(f"repro/draft_prefill[P={P}]")
                  if self.annotate else _NULLCTX):
                d_single = self._attach_fns[(P, L)](
                    dparams, jnp.asarray(tokens),
                    jnp.full((1,), dlim, jnp.int32))
        if has_draft not in self._attach_park_fns:
            self._attach_park_fns[has_draft] = \
                self._build_attach_park(has_draft)
        with (jax.profiler.TraceAnnotation("repro/attach_park")
              if self.annotate else _NULLCTX):
            if has_draft:
                dcache, seq_lens = self._attach_park_fns[True](
                    state.dcache, d_single, state.seq_lens, jnp.int32(slot),
                    jnp.int32(total_len))
                return dataclasses.replace(state, dcache=dcache,
                                           seq_lens=seq_lens)
            seq_lens = self._attach_park_fns[False](
                state.seq_lens, jnp.int32(slot), jnp.int32(total_len))
            return dataclasses.replace(state, seq_lens=seq_lens)

    def commit_attached(self, state: DecodeState, slot: int,
                        total_len: int, last2, *,
                        warm: bool = False) -> DecodeState:
        """Turn a fully-cached (zero-suffix) attached slot into a live
        decode row — no prefill forward at all.

        The first decode step writes feed row ``total_len - 1``; when the
        cached prefix covers it (``n_prefix == total_len``) that row lives
        in a shared block, which is first COW-resolved through the
        block-copy scatter.  Then the table grows to cover ``total_len``
        and the ordinary chunk-commit scatter publishes the block table
        and row state, leaving the slot bit-identical to a chunked (and
        hence whole-prompt) admission.
        """
        self._require_unsharded("prefix-cache attach")
        pk = state.paged
        assert pk is not None
        if warm:
            # compile block_copy + chunk_commit with no-op pad-only args,
            # off the host bookkeeping and off the live pool's buffers
            state = self._warm_shield(state)
            pad = np.full((pk.max_blocks,), pk.num_blocks, np.int32)
            if self._block_copy_fn is None:
                self._block_copy_fn = self._build_block_copy()
            tcache = self._block_copy_fn(state.tcache, jnp.asarray(pad),
                                         jnp.asarray(pad))
            state = dataclasses.replace(state, tcache=tcache)
            if True not in self._chunk_commit_fns:
                self._chunk_commit_fns[True] = self._build_chunk_commit(True)
            self._chunk_commit_fns[True](
                state.seq_lens, state.last2, state.out, state.n_generated,
                state.done, jnp.int32(slot), jnp.int32(total_len),
                jnp.zeros((2,), jnp.int32), state.tcache["bt"],
                jnp.full((pk.max_blocks,), -1, jnp.int32))
            return state
        pairs = pk.cow_for_range(slot, total_len - 1, total_len)
        pk.ensure(slot, total_len)
        pk.commit(slot, total_len - pk.tokens(slot))
        pk.clear_pending(slot)
        state = self._drain_evicted(state)
        if pairs:
            src = np.full((pk.max_blocks,), pk.num_blocks, np.int32)
            dst = np.full((pk.max_blocks,), pk.num_blocks, np.int32)
            for i, (s_, d_) in enumerate(pairs):
                src[i], dst[i] = s_, d_
            if self._block_copy_fn is None:
                self._block_copy_fn = self._build_block_copy()
            with (jax.profiler.TraceAnnotation("repro/block_copy")
                  if self.annotate else _NULLCTX):
                tcache = self._block_copy_fn(state.tcache, jnp.asarray(src),
                                             jnp.asarray(dst))
            state = dataclasses.replace(state, tcache=tcache)
        ids = pk.table(slot)
        bt_row = np.full((pk.max_blocks,), -1, np.int32)
        bt_row[:len(ids)] = ids
        if True not in self._chunk_commit_fns:
            self._chunk_commit_fns[True] = self._build_chunk_commit(True)
        cargs = (state.seq_lens, state.last2, state.out, state.n_generated,
                 state.done, jnp.int32(slot), jnp.int32(total_len),
                 jnp.asarray(np.asarray(last2, np.int32)),
                 state.tcache["bt"], jnp.asarray(bt_row))
        with (jax.profiler.TraceAnnotation("repro/chunk_commit")
              if self.annotate else _NULLCTX):
            seq_lens, l2, out, n_gen, done, bt = \
                self._chunk_commit_fns[True](*cargs)
        return dataclasses.replace(
            state, seq_lens=seq_lens, last2=l2, out=out, n_generated=n_gen,
            done=done, tcache=dict(state.tcache, bt=bt))

    # ------------------------------------------------------------------
    # chunked prefill into a slot (in-step chunked prefill; the scheduler
    # interleaves these chunks with decode steps of the running batch)

    def _build_chunk_begin(self, paged: bool):
        """First-chunk setup: clear the slot's stale pos rows (contiguous
        target + draft ring — a whole-prompt inject replaces the full row,
        chunked writes do not, so the previous occupant's attendable keys
        must be wiped first) and PARK the slot's seq_lens at the prompt's
        final length.  Parking matters: the interleaved decode steps still
        compute (masked, garbage) writes for this done row, and at
        seq_lens = total_len those land at positions >= total_len - 1 —
        beyond every chunk query, and rewritten by the slot's own first
        real step before they can ever be attended (the ring invariant)."""
        def fn(tpos, dpos, seq_lens, slot, plen):
            new_tpos = tpos if paged else tpos.at[slot].set(-1)
            new_dpos = None if dpos is None else dpos.at[slot].set(-1)
            return new_tpos, new_dpos, seq_lens.at[slot].set(plen)

        # paged pools return tpos untouched and the caller keeps using the
        # *input* pos buffer — donating arg 0 there would delete a buffer
        # that stays live, so only the contiguous path donates it
        kv = (1, 2) if paged else (0, 1, 2)
        sh = self._shardings
        if sh is None:
            return self._register_jit("chunk_begin", (paged,), fn, hot=True,
                                      kv_args=kv)
        tpos_sh = sh.tcache["pos"]
        dpos_sh = (sh.dcache["pos"]
                   if isinstance(sh.dcache, dict) and "pos" in sh.dcache
                   else sh.rep)
        return self._register_jit("chunk_begin", (paged,), fn, hot=True,
                                  kv_args=kv,
                                  in_shardings=(tpos_sh, dpos_sh, sh.seq_lens,
                                                sh.rep, sh.rep),
                                  out_shardings=(tpos_sh, dpos_sh,
                                                 sh.seq_lens))

    def _build_chunk_commit(self, paged: bool):
        """Last-chunk commit: the slot becomes a live decode row — exactly
        the non-cache half of what prefill_into's inject scatters."""
        def fn(seq_lens, last2, out, n_gen, done, slot, plen, l2,
               bt=None, bt_row=None):
            out_row = jnp.zeros_like(out[0])
            res = (seq_lens.at[slot].set(plen),
                   last2.at[slot].set(l2),
                   out.at[slot].set(out_row),
                   n_gen.at[slot].set(0),
                   done.at[slot].set(False))
            if paged:
                res = res + (bt.at[slot].set(bt_row),)
            return res

        kv = (0, 1, 2, 3, 4) + ((8,) if paged else ())
        sh = self._shardings
        if sh is None:
            return self._register_jit("chunk_commit", (paged,), fn, hot=True,
                                      kv_args=kv)
        in_sh = [sh.seq_lens, sh.last2, sh.out, sh.n_generated, sh.done,
                 sh.rep, sh.rep, sh.rep]
        out_sh = [sh.seq_lens, sh.last2, sh.out, sh.n_generated, sh.done]
        if paged:
            in_sh += [sh.tcache["bt"], sh.rep]
            out_sh += [sh.tcache["bt"]]
        return self._register_jit("chunk_commit", (paged,), fn, hot=True,
                                  kv_args=kv,
                                  in_shardings=tuple(in_sh),
                                  out_shardings=tuple(out_sh))

    def _build_chunk(self, key: Tuple, t_single, d_single):
        """One bucketed chunk forward for one slot.

        ``key`` carries a rows-limit bucket ``R`` (power-of-two cover of
        ``start + CB``, capped at the logical length): during chunked
        prefill every attendable key lives below row ``start + CB``, so
        the contiguous forwards (target ring and the draft ring trailing a
        paged target) bound their attention to ``R`` rows instead of
        streaming the dead tail of the full logical cache.  Paged targets
        instead take a per-chunk ``cu_row`` operand — the slot's ragged
        grid-step count — so the chunk's pool attention runs the ragged
        kernel over exactly the slot's allocated blocks.

        Contiguous pool: the slot's B=1 caches are sliced out, extended by
        the chunk (model.prefill_chunk — the verify-attention masking makes
        the prefix extension exact), and scattered back.  Paged pool: the
        chunk writes the shared block pool in place through the slot's host
        block table (bt_row), so there is nothing to slice; the device
        ``bt`` row stays -1 until the final chunk commits (step growth
        uploads exclude pending slots), which keeps the interleaved decode
        steps' garbage writes for this row dropped.  Even without that, the
        parked-seq_lens invariant (see _build_chunk_begin) guarantees any
        such write lands past every chunk query and is rewritten before it
        is ever attendable — the same argument the contiguous path relies
        on.
        """
        CB, paged, capacity, L, R = key
        tgt, drf = self.target, self.draft

        def take(full, single, slot):
            def one(f, s1):
                ax = self._slot_axis(f.shape, s1.shape)
                starts = tuple(slot if i == ax else 0
                               for i in range(f.ndim))
                return jax.lax.dynamic_slice(f, starts, s1.shape)
            return jax.tree.map(one, full, single)

        def put(full, upd, single, slot):
            def one(f, u, s1):
                ax = self._slot_axis(f.shape, s1.shape)
                starts = tuple(slot if i == ax else 0
                               for i in range(f.ndim))
                return jax.lax.dynamic_update_slice(f, u.astype(f.dtype),
                                                    starts)
            return jax.tree.map(one, full, upd, single)

        def fn(tparams, dparams, tcache, dcache, slot, toks, start,
               t_limit, d_limit, bt_row=None, cu_row=None):
            off = jnp.full((1,), start, jnp.int32)
            tl = jnp.full((1,), t_limit, jnp.int32)
            dl = jnp.full((1,), d_limit, jnp.int32)
            toks1 = toks[None, :]
            if paged:
                # the pool IS the B=1 cache (writes land in place through
                # the slot's host table); only bt is a per-slot view
                t1 = dict({n: tcache[n] for n in tcache if n != "bt"},
                          bt=bt_row[None, :])
                _, t1n = tgt.prefill_chunk(tparams, toks1, t1, off, tl,
                                           cu_blocks=cu_row)
                new_t = dict(tcache,
                             **{n: t1n[n] for n in t1n if n != "bt"})
            elif t_single is None:       # capacity == 1: the pool IS the slot
                _, new_t = tgt.prefill_chunk(tparams, toks1, tcache, off, tl,
                                             rows_limit=R)
            else:
                _, t1n = tgt.prefill_chunk(
                    tparams, toks1, take(tcache, t_single, slot), off, tl,
                    rows_limit=R)
                new_t = put(tcache, t1n, t_single, slot)
            if drf is None:
                return new_t, dcache
            if d_single is None:
                _, new_d = drf.prefill_chunk(dparams, toks1, dcache, off, dl,
                                             rows_limit=R)
            else:
                _, d1n = drf.prefill_chunk(
                    dparams, toks1, take(dcache, d_single, slot), off, dl,
                    rows_limit=R)
                new_d = put(dcache, d1n, d_single, slot)
            return new_t, new_d

        rows = L if paged else None
        cu_arg = 10 if paged else None
        sh = self._shardings
        if sh is None:
            return self._register_jit("chunk", key, fn, hot=True,
                                      kv_args=(2, 3), paged_rows=rows,
                                      cu_arg=cu_arg)
        in_sh = [sh.rep, sh.rep, sh.tcache, sh.dc, sh.rep, sh.rep, sh.rep,
                 sh.rep, sh.rep]
        if paged:
            in_sh += [sh.rep, sh.cu_sh]   # bt_row + cu_row (host-built)
        return self._register_jit("chunk", key, fn, hot=True,
                                  kv_args=(2, 3), paged_rows=rows,
                                  cu_arg=cu_arg,
                                  in_shardings=tuple(in_sh),
                                  out_shardings=(sh.tcache, sh.dc))

    def _get_chunk_fn(self, key: Tuple):
        """The jit-cached standalone chunk forward for ``key`` (compiling
        it on first use) — shared by prefill_chunk_into and flush_chunk."""
        if key not in self._chunk_fns:
            CB, paged, capacity, L, R = key
            if capacity == 1:
                t_single = d_single = None
            else:
                t_tmpl, d_tmpl = jax.eval_shape(
                    lambda: self._init_caches(1, L))
                t_single = None if paged else t_tmpl
                d_single = d_tmpl
            self._chunk_fns[key] = self._build_chunk(key, t_single, d_single)
        return self._chunk_fns[key]

    def prefill_chunk_into(self, tparams, dparams, state: DecodeState,
                           slot: int, tokens, start: int, n: int,
                           total_len: int, last2=None, *,
                           warm: bool = False, defer: bool = False):
        """Feed one prefill chunk of a request into row ``slot``.

        The request's feed (prompt, or prompt + pre-preemption stash) has
        ``total_len`` tokens; this call writes feed positions
        ``[start, start + n)`` of the target cache (the draft trails by one:
        its limit is ``total_len - 2``, exactly mirroring the whole-prompt
        prefill which leaves the last prompt token to the first decode
        step).  ``tokens`` is the bucket-padded chunk (first ``n`` entries
        real).

        Row-state contract (what the interleaved decode steps may observe):

        * **first chunk** (``start == 0``): the slot's stale ``pos`` rows
          are wiped (contiguous target ring + draft ring — a previous
          occupant's keys must never be attendable) and ``seq_lens[slot]``
          is PARKED at ``total_len``.  Parking is load-bearing: the slot is
          still ``done``, so interleaved decode steps compute masked
          garbage writes for it, and at ``seq_lens = total_len`` those land
          at positions ``>= total_len - 1`` — beyond every chunk query, and
          rewritten by the slot's own first real decode step before they
          can ever be attended.  On a paged pool the slot is additionally
          marked *pending*: its device block-table row stays ``-1`` (decode
          writes drop) until the final chunk publishes it.
        * **middle chunks**: only cache rows ``[start, start + n)`` change;
          ``done/out/n_generated/last2`` stay untouched, so the scheduler
          sees an occupied-but-not-decoding slot.
        * **final chunk** (``start + n == total_len - 1``): ``last2`` (the
          feed's final two tokens) must be supplied; the commit reproduces
          exactly the non-cache row state a whole-prompt ``prefill_into``
          would have left — ``seq_lens = total_len``, ``last2`` set, ``out``
          zeroed, ``n_generated = 0``, ``done = False``, and (paged) the
          block table published including the block covering row
          ``total_len - 1``, which the first decode step writes.  From the
          next iteration on, the slot is indistinguishable from a
          whole-prompt admission — that equivalence is what makes
          chunk-vs-whole token equality (tests/test_chunked_prefill.py)
          hold bit-for-bit.

        ``warm=True`` compiles the begin/chunk/commit paths for this chunk
        bucket without touching host block bookkeeping (result discarded).

        ``defer=True`` (paged, NON-final, non-warm chunks only) runs the
        host bookkeeping and begin path as usual but SKIPS the forward
        dispatch, returning ``(state, DeferredChunk)`` instead of a state:
        the caller later folds the forward into the next speculative step
        (:meth:`step_with_chunk`, the mixed verify+chunk launch) or
        dispatches it standalone (:meth:`flush_chunk`).
        """
        if not hasattr(self.target, "prefill_chunk") or (
                self.draft is not None
                and not hasattr(self.draft, "prefill_chunk")):
            raise NotImplementedError(
                f"chunked prefill is not supported for family "
                f"'{self.tcfg.family}' (model lacks a prefill_chunk path)")
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        CB = int(tokens.shape[0])
        feed_total = total_len - 1
        final = (not warm) and (start + n == feed_total)
        if not warm and not 0 < n <= CB:
            raise ValueError(f"chunk carries n={n} tokens in a {CB} bucket")
        if not warm and start + n > feed_total:
            raise ValueError(
                f"chunk [{start}, {start + n}) overruns the {feed_total}"
                f"-token feed (prompt of {total_len})")
        if final and (last2 is None or len(np.asarray(last2)) != 2):
            raise ValueError(
                "the final chunk must pass last2 = the feed's last 2 tokens")
        pk = state.paged
        paged = pk is not None
        capacity = int(state.seq_lens.shape[0])
        if warm:
            # donation shield: warm begin/chunk/commit dispatches discard
            # their results and must not consume the live pool's buffers
            state = self._warm_shield(state)

        # ---- first chunk: wipe stale rows, park seq_lens ----
        if start == 0 or warm:
            if paged not in self._chunk_begin_fns:
                self._chunk_begin_fns[paged] = self._build_chunk_begin(paged)
            dpos = (state.dcache["pos"]
                    if (self.draft is not None and isinstance(state.dcache, dict)
                        and "pos" in state.dcache) else None)
            tpos, dpos_new, seq_lens = self._chunk_begin_fns[paged](
                state.tcache["pos"], dpos, state.seq_lens, jnp.int32(slot),
                jnp.int32(total_len))
            # rebind even when warm: begin just consumed (donated) the
            # shielded copy's pos/seq_lens buffers, so the warm chunk and
            # commit dispatches below must see the outputs, not the inputs
            tcache = (state.tcache if paged
                      else dict(state.tcache, pos=tpos))
            dcache = (dict(state.dcache, pos=dpos_new)
                      if dpos is not None else state.dcache)
            state = dataclasses.replace(state, tcache=tcache,
                                        dcache=dcache, seq_lens=seq_lens)

        # ---- host block accounting + this chunk's block table ----
        bt_row = None
        if paged:
            bt_row = np.full((pk.max_blocks,), -1, np.int32)
            if not warm:
                if start == 0:
                    pk.prefill(slot, n)
                    pk.mark_pending(slot)
                else:
                    pk.ensure(slot, start + n)
                    pk.commit(slot, n)
                ids = pk.table(slot)
                bt_row[:len(ids)] = ids
                state = self._drain_evicted(state)

        # ---- the chunk forward ----
        L = (pk.logical_len if paged else int(state.tcache["pos"].shape[1]))
        # rows-limit bucket: every attendable key lives below row
        # start + CB (positions equal rows before the first wrap, and
        # chunks never wrap), so the contiguous/draft forwards attend a
        # power-of-two cover of it instead of the whole logical cache
        R = min(max(_next_pow2(start + CB), 16), L)
        key = (CB, paged, capacity, L, R)
        fn = self._get_chunk_fn(key)
        if defer:
            if not paged or final or warm:
                raise ValueError(
                    "defer=True needs a paged, non-final, non-warm chunk")
            return state, DeferredChunk(
                slot=int(slot), tokens=tokens, start=int(start),
                total_len=int(total_len), bt_row=bt_row, key=key)
        args = (tparams, dparams, state.tcache, state.dcache,
                jnp.int32(slot), jnp.asarray(tokens), jnp.int32(start),
                jnp.int32(feed_total), jnp.int32(feed_total - 1))
        if paged:
            # [0, max(live, 1)]: the slot's one-row ragged grid plan
            cu_row = host_cu_blocks(bt_row[None, :])
            args = args + (jnp.asarray(bt_row), jnp.asarray(cu_row))
        with (jax.profiler.TraceAnnotation(f"repro/chunk[CB={CB}]")
              if self.annotate else _NULLCTX):
            new_t, new_d = fn(*args)
        if warm:
            # compile the commit path too, then discard everything
            if paged not in self._chunk_commit_fns:
                self._chunk_commit_fns[paged] = self._build_chunk_commit(paged)
            cargs = (state.seq_lens, state.last2, state.out,
                     state.n_generated, state.done, jnp.int32(slot),
                     jnp.int32(total_len), jnp.zeros((2,), jnp.int32))
            if paged:
                # the warm chunk dispatch above consumed state.tcache, so
                # the block table must come from its output
                cargs = cargs + (new_t["bt"], jnp.asarray(bt_row))
            self._chunk_commit_fns[paged](*cargs)
            # hand back only live buffers (the chunk consumed state.tcache/
            # dcache); warm callers discard this anyway
            return dataclasses.replace(state, tcache=new_t, dcache=new_d)
        state = dataclasses.replace(state, tcache=new_t, dcache=new_d)

        # ---- final chunk: the slot becomes a live decode row ----
        if final:
            if paged:
                # cover row total_len - 1 (written by the first decode step)
                pk.ensure(slot, total_len)
                pk.commit(slot, 1)
                pk.clear_pending(slot)
                ids = pk.table(slot)
                bt_row = np.full((pk.max_blocks,), -1, np.int32)
                bt_row[:len(ids)] = ids
                state = self._drain_evicted(state)
            if paged not in self._chunk_commit_fns:
                self._chunk_commit_fns[paged] = self._build_chunk_commit(paged)
            cargs = (state.seq_lens, state.last2, state.out,
                     state.n_generated, state.done, jnp.int32(slot),
                     jnp.int32(total_len),
                     jnp.asarray(np.asarray(last2, np.int32)))
            if paged:
                cargs = cargs + (state.tcache["bt"], jnp.asarray(bt_row))
                with (jax.profiler.TraceAnnotation("repro/chunk_commit")
                      if self.annotate else _NULLCTX):
                    seq_lens, l2, out, n_gen, done, bt = \
                        self._chunk_commit_fns[paged](*cargs)
                state = dataclasses.replace(
                    state, seq_lens=seq_lens, last2=l2, out=out,
                    n_generated=n_gen, done=done,
                    tcache=dict(state.tcache, bt=bt))
            else:
                with (jax.profiler.TraceAnnotation("repro/chunk_commit")
                      if self.annotate else _NULLCTX):
                    seq_lens, l2, out, n_gen, done = \
                        self._chunk_commit_fns[paged](*cargs)
                state = dataclasses.replace(
                    state, seq_lens=seq_lens, last2=l2, out=out,
                    n_generated=n_gen, done=done)
        return state

    def flush_chunk(self, tparams, dparams, state: DecodeState,
                    chunk: DeferredChunk) -> DecodeState:
        """Dispatch a deferred chunk's forward standalone.

        The host bookkeeping already ran at defer time, so this is exactly
        the chunk-fn dispatch :meth:`prefill_chunk_into` skipped — callers
        use it when no speculative step follows before the next pool
        consumer (another chunk, an admission prefill, a preemption).
        """
        fn = self._get_chunk_fn(chunk.key)
        CB = chunk.key[0]
        feed_total = chunk.total_len - 1
        cu_row = host_cu_blocks(chunk.bt_row[None, :])
        args = (tparams, dparams, state.tcache, state.dcache,
                jnp.int32(chunk.slot), jnp.asarray(chunk.tokens),
                jnp.int32(chunk.start), jnp.int32(feed_total),
                jnp.int32(feed_total - 1), jnp.asarray(chunk.bt_row),
                jnp.asarray(cu_row))
        with (jax.profiler.TraceAnnotation(f"repro/chunk[CB={CB}]")
              if self.annotate else _NULLCTX):
            new_t, new_d = fn(*args)
        return dataclasses.replace(state, tcache=new_t, dcache=new_d)

    def step_with_chunk(self, tparams, dparams, state: DecodeState, s: int,
                        chunk: DeferredChunk,
                        rng: Optional[jax.Array] = None,
                        ) -> Tuple[DecodeState, StepStats]:
        """One speculative step FUSED with a deferred chunk's forward —
        the mixed verify+chunk launch.

        The chunk slot's queries (its prefix-extension rows, read/written
        through its host table row) ride the same ragged attention call as
        every decode slot's verify queries, so the separate chunk dispatch
        — and its grid, weight re-streaming and launch overhead —
        disappears.  Numerically this is bit-identical to
        ``flush_chunk(...)`` followed by ``step(...)``: attention rows are
        independent per query, the parked chunk slot's verify writes are
        dropped in both orders (its device table row is still ``-1``), and
        its accept count is forced to zero by its ``done`` flag.
        """
        if not 0 <= s <= S_MAX:
            raise ValueError(
                f"s={s} outside [0, {S_MAX}]: the step's output buffer is "
                f"sized for at most S_MAX={S_MAX} speculative tokens and "
                f"would silently drop commits beyond it")
        pk = state.paged
        if pk is None:
            raise ValueError("step_with_chunk needs a paged slot pool")
        grew = False
        for slot in pk.active_slots():
            if pk.is_pending(slot):
                continue
            grew |= bool(pk.ensure(slot, pk.tokens(slot) + s))
        if grew:
            state = dataclasses.replace(
                state, tcache=dict(state.tcache, bt=jnp.asarray(
                    pk.device_tables(exclude_pending=True))))
        state = self._drain_evicted(state)
        B = int(state.seq_lens.shape[0])
        CB, _, _, L, R = chunk.key
        key = (B, s, CB, L, R)
        if key not in self._mixed_step_fns:
            self._mixed_step_fns[key] = self._build_step_mixed(B, s, CB,
                                                               L, R)
        # the grid plan covers the chunk row's blocks through the patched
        # table (the kernel reads them via bt_eff, not the device bt)
        tables = pk.device_tables(exclude_pending=True)
        tables[chunk.slot] = chunk.bt_row
        cu = host_cu_blocks(tables)
        feed_total = chunk.total_len - 1
        args = (tparams, dparams, state.tcache, state.dcache,
                state.seq_lens, state.last2, state.out, state.n_generated,
                state.done, jnp.asarray(cu), jnp.int32(chunk.slot),
                jnp.asarray(chunk.tokens), jnp.int32(chunk.start),
                jnp.int32(feed_total), jnp.int32(feed_total - 1),
                jnp.asarray(chunk.bt_row))
        if self.sample:
            if rng is None:
                rng = jax.random.PRNGKey(
                    int(np.asarray(state.n_generated).sum()))
            args = (*args, rng)
        with (jax.profiler.TraceAnnotation(
                f"repro/step_mixed[B={B},s={s},CB={CB}]")
              if self.annotate else _NULLCTX):
            (tc, dcache, seq_lens, last2, out, n_gen, done, a, n_commit) = \
                self._mixed_step_fns[key](*args)
        new_state = DecodeState(tc, dcache, seq_lens, last2, out, n_gen,
                                done, paged=pk)
        stats = StepStats(accepted=np.asarray(a), committed=np.asarray(n_commit))
        for slot in pk.active_slots():
            if not pk.is_pending(slot):
                pk.commit(slot, int(stats.committed[slot]))
        return new_state, stats

    # ------------------------------------------------------------------
    # one speculative step

    def _warm_shield(self, state: DecodeState) -> DecodeState:
        """Disposable copy of a DecodeState's device leaves.

        Warm (compile-only) dispatches discard their results; with pool
        donation on, handing them the live state would delete the very
        buffers the next real step needs.  ``donate=False`` engines keep the
        zero-copy warm path.
        """
        if not self.donate:
            return state
        return dataclasses.replace(
            state,
            tcache=_copy_arrays(state.tcache),
            dcache=_copy_arrays(state.dcache),
            seq_lens=_copy_arrays(state.seq_lens),
            last2=_copy_arrays(state.last2),
            out=_copy_arrays(state.out),
            n_generated=_copy_arrays(state.n_generated),
            done=_copy_arrays(state.done))

    def _build_step(self, B: int, s: int, paged_rows: Optional[int] = None):
        paged = paged_rows is not None
        fn = make_spec_step(
            self.target, self.draft, B, s, eos_id=self.eos_id,
            max_new=self.max_new, prefix_offset=self.prefix_offset,
            sample=self.sample, temperature=self.temperature, paged=paged)
        cu_arg = 9 if paged else None
        # donate every DecodeState leaf the step threads through — except
        # the target cache of recurrent families, whose checkpoint-selecting
        # commit makes buffer reuse shape-incompatible (launch/specs.py
        # makes the same call for the decode plans)
        kv = (tuple(range(3, 9)) if self.tcfg.family in ("ssm", "hybrid")
              else tuple(range(2, 9)))
        sh = self._shardings
        if sh is None or B != self._shard_capacity:
            # no mesh, or a non-pool batch size (generate()/warmup paths):
            # plain single-placement jit
            return self._register_jit("step", (B, s, paged), fn, hot=True,
                                      kv_args=kv, paged_rows=paged_rows,
                                      cu_arg=cu_arg)
        # sharded pool: the serving step is one explicit SPMD program —
        # params replicated, every pool-shaped leaf sharded on its capacity
        # (or block) axis on both sides, per-slot stats sharded like seq_lens
        in_sh = [sh.rep, sh.rep, sh.tcache, sh.dc, sh.seq_lens, sh.last2,
                 sh.out, sh.n_generated, sh.done]
        if paged:
            in_sh.append(sh.cu_sh)            # cu_blocks (host-built, tiny)
        if self.sample:
            in_sh.append(sh.rep)
        out_sh = (sh.tcache, sh.dc, sh.seq_lens, sh.last2, sh.out,
                  sh.n_generated, sh.done, sh.seq_lens, sh.seq_lens)
        return self._register_jit("step", (B, s, paged), fn, hot=True,
                                  kv_args=kv, paged_rows=paged_rows,
                                  cu_arg=cu_arg,
                                  in_shardings=tuple(in_sh),
                                  out_shardings=out_sh)

    def _build_step_mixed(self, B: int, s: int, CB: int, L: int, R: int):
        """The mixed verify+chunk step jit (see :meth:`step_with_chunk`).

        Same contract as the plain paged step — ``cu_blocks`` at argnum 9
        so the graph-lint ragged pass checks both the same way — plus the
        six chunk operands (slot, tokens, start, target/draft limits, host
        table row) after it."""
        d_single = None
        if self.draft is not None and B != 1:
            _, d_single = jax.eval_shape(lambda: self._init_caches(1, L))
        fn = make_spec_step(
            self.target, self.draft, B, s, eos_id=self.eos_id,
            max_new=self.max_new, prefix_offset=self.prefix_offset,
            sample=self.sample, temperature=self.temperature, paged=True,
            chunk=(CB, R, d_single))
        kv = tuple(range(2, 9))
        key = (B, s, CB, L, R)
        sh = self._shardings
        if sh is None or B != self._shard_capacity:
            return self._register_jit("step_mixed", key, fn, hot=True,
                                      kv_args=kv, paged_rows=L, cu_arg=9)
        in_sh = [sh.rep, sh.rep, sh.tcache, sh.dc, sh.seq_lens, sh.last2,
                 sh.out, sh.n_generated, sh.done, sh.cu_sh,
                 sh.rep, sh.rep, sh.rep, sh.rep, sh.rep, sh.rep]
        if self.sample:
            in_sh.append(sh.rep)
        out_sh = (sh.tcache, sh.dc, sh.seq_lens, sh.last2, sh.out,
                  sh.n_generated, sh.done, sh.seq_lens, sh.seq_lens)
        return self._register_jit("step_mixed", key, fn, hot=True,
                                  kv_args=kv, paged_rows=L, cu_arg=9,
                                  in_shardings=tuple(in_sh),
                                  out_shardings=out_sh)



    def step(self, tparams, dparams, state: DecodeState, s: int,
             rng: Optional[jax.Array] = None, *,
             warm: bool = False) -> Tuple[DecodeState, StepStats]:
        """One speculative step at length ``s`` for the whole batch.

        ``s`` must stay within ``S_MAX``: the ``out`` ring scatter sizes its
        headroom from S_MAX and silently drops writes past it, so a larger s
        would lose committed tokens instead of failing loudly.

        Paged pool: before the device step, each live slot's block table is
        grown to cover its worst-case writes this step (``seq_len + s``
        rows); afterwards the host token mirror advances by the raw commit
        counts.  ``warm=True`` compiles the step without touching the host
        block bookkeeping (the result must be discarded).
        """
        if not 0 <= s <= S_MAX:
            raise ValueError(
                f"s={s} outside [0, {S_MAX}]: the step's output buffer is "
                f"sized for at most S_MAX={S_MAX} speculative tokens and "
                f"would silently drop commits beyond it")
        if state.paged is not None and not warm:
            pk = state.paged
            grew = False
            for slot in pk.active_slots():
                if pk.is_pending(slot):
                    # mid-chunked-prefill: the slot is parked done, writes
                    # nothing this step, and grows only when its next chunk
                    # is fed (prefill_chunk_into allocates those blocks)
                    continue
                grew |= bool(pk.ensure(slot, pk.tokens(slot) + s))
            if grew:
                # prefill_into/retire_slot keep the device table in sync, so
                # the host->device upload only happens on actual growth.
                # Pending (mid-chunked-prefill) slots' rows stay -1 so their
                # parked rows' masked decode writes remain dropped; their
                # blocks are published by the final chunk's commit.
                state = dataclasses.replace(
                    state, tcache=dict(state.tcache, bt=jnp.asarray(
                        pk.device_tables(exclude_pending=True))))
            state = self._drain_evicted(state)
        B = state.seq_lens.shape[0]
        # pagedness is part of the key: the paged wrapper takes the extra
        # cu_blocks operand, so a contiguous pool on the same engine must
        # never reuse a paged-built step fn (or vice versa)
        key = (B, s, state.paged is not None)
        if key not in self._step_fns:
            self._step_fns[key] = self._build_step(
                B, s, paged_rows=(state.paged.logical_len
                                  if state.paged is not None else None))
        if warm:
            state = self._warm_shield(state)
        args = (tparams, dparams, state.tcache, state.dcache, state.seq_lens,
                state.last2, state.out, state.n_generated, state.done)
        if state.paged is not None:
            # ragged-grid operand: cumulative live-block counts from the
            # same host tables the device `bt` upload above mirrors, so the
            # kernel's grid always matches the table it prefetches
            cu = host_cu_blocks(
                state.paged.device_tables(exclude_pending=True))
            args = (*args, jnp.asarray(cu))
        if self.sample:
            if rng is None:
                # lint: allow-host-sync(sample-mode fallback seed only; serving passes rng explicitly)
                rng = jax.random.PRNGKey(int(np.asarray(state.n_generated).sum()))
            args = (*args, rng)
        with (jax.profiler.TraceAnnotation(f"repro/step[B={B},s={s}]")
              if self.annotate else _NULLCTX):
            (tc, dc, seq_lens, last2, out, n_gen, done, a, n_commit) = \
                self._step_fns[key](*args)
        new_state = DecodeState(tc, dc, seq_lens, last2, out, n_gen, done,
                                paged=state.paged)
        # lint: allow-host-sync(step-boundary sync: commit counts drive host-side block accounting)
        stats = StepStats(accepted=np.asarray(a), committed=np.asarray(n_commit))
        if state.paged is not None and not warm:
            for slot in state.paged.active_slots():
                if not state.paged.is_pending(slot):
                    state.paged.commit(slot, int(stats.committed[slot]))
        return new_state, stats

    # ------------------------------------------------------------------
    # full generation driver

    def generate(self, tparams, dparams, tokens, prompt_lens, *, s: int,
                 cache_len: int, max_new: Optional[int] = None,
                 target_extras: Optional[Dict] = None,
                 collect_stats: bool = False,
                 key: Optional[jax.Array] = None):
        """Generate ``max_new`` tokens for every request with fixed s.
        Returns (tokens [B, max_new], list[StepStats], n_steps)."""
        state = self.prefill(tparams, dparams, tokens, prompt_lens, cache_len,
                             target_extras)
        stats = []
        n_steps = 0
        limit = max_new or self.max_new
        if self.sample and key is None:
            key = jax.random.PRNGKey(0)
        while True:
            rng = jax.random.fold_in(key, n_steps) if self.sample else None
            state, st = self.step(tparams, dparams, state, s, rng=rng)
            n_steps += 1
            if collect_stats:
                stats.append(st)
            if bool(np.asarray(state.done).all()) or n_steps > limit * 2 + 8:
                break
        return np.asarray(state.out)[:, :self.max_new], stats, n_steps

    def warmup(self, tparams, dparams, batch_sizes, s_values, cache_len: int,
               prompt_len: int = 8):
        """Pre-compile step functions for the profiling grid."""
        for b in batch_sizes:
            tokens = np.full((b, prompt_len), 3, np.int32)
            lens = np.full((b,), prompt_len, np.int32)
            state = self.prefill(tparams, dparams, tokens, lens, cache_len)
            for s in s_values:
                # warm=True: compile-only, and the donation shield keeps the
                # discarded dispatch from consuming `state` for the next s
                self.step(tparams, dparams, state, s, warm=True)


def make_spec_step(tgt, drf, B: int, s: int, *, eos_id: int = -1,
                   max_new: int = 128, prefix_offset: int = 0,
                   sample: bool = False, temperature: float = 1.0,
                   paged: bool = False,
                   chunk: Optional[Tuple[int, int, Any]] = None):
    """Pure one-speculative-step function (paper Algorithm 1, batched).

    Signature: fn(tparams, dparams, tcache, dcache, seq_lens, last2, out,
    n_generated, done[, cu_blocks][, rng]) -> (tcache', dcache', seq_lens',
    last2', out', n_generated', done', accepted, n_commit).

    ``paged=True`` adds the ``cu_blocks [B + 1]`` operand (host cumulative
    ragged grid-step counts, kernels/tuning.py) right after ``done``; the
    target verify forward threads it into the paged attention so the fused
    path runs the ragged kernel (kernels/paged.py) — the gather reference
    ignores it, so the flag is numerically free.

    ``chunk = (CB, R, d_single)`` (requires ``paged``) builds the MIXED
    verify+chunk step: six extra operands after ``cu_blocks`` — chunk
    slot, CB-bucketed tokens, start, target/draft feed limits, and the
    slot's host block-table row — and the target verify runs
    ``decode_step_mixed``, one ragged attention launch per layer serving
    both the decode slots' verify queries and the chunk slot's
    prefix-extension queries.  The draft's trailing chunk forward runs
    first (B=1 slice bounded to ``R`` rows, exactly the standalone chunk
    fn's draft half), then the usual draft loop; the chunk slot is parked
    ``done`` so its accept count is forced to zero and its row state never
    moves.  Bit-identical to standalone-chunk-then-step by per-query-row
    independence (see :meth:`SpecDecodeEngine.step_with_chunk`).

    ``sample=False`` (default) is the paper's argmax verification.
    ``sample=True`` is Leviathan/Chen-style stochastic speculative sampling
    (beyond-paper, DESIGN §10): the draft SAMPLES proposals from
    q(x) = softmax(logits/T); the target accepts token t_i with probability
    min(1, p_i(t_i)/q_i(t_i)) and on first rejection resamples from the
    residual norm(max(p − q, 0)) — provably distributed exactly as sampling
    from the target alone.  Takes one extra ``rng`` argument.

    Exposed at module level so the multi-pod dry-run can lower exactly the
    serving step the engine runs (launch/dryrun.py jits it with explicit
    in/out shardings); the engine jit-caches one instance per (B, s).
    """
    eos = eos_id
    assert chunk is None or paged, "the mixed step is paged-pool only"

    def body(tparams, dparams, tcache, dcache, seq_lens, last2, out,
             n_generated, done, cu_blocks, rng, chunk_ops=None):
        if sample:
            assert rng is not None, "sample=True needs an rng argument"
            k_draft, k_acc, k_res = jax.random.split(rng, 3)
        # ---- 0. mixed launch: the draft's trailing chunk forward first
        # (same dispatch order as standalone-chunk-then-step) ----
        if chunk_ops is not None:
            cslot, ctoks, cstart, ctl, cdl, cbt_row = chunk_ops
            CB, R, d_single = chunk
            if drf is not None:
                off1 = jnp.full((1,), cstart, jnp.int32)
                dl1 = jnp.full((1,), cdl, jnp.int32)
                ctoks1 = ctoks[None, :]
                if d_single is None:   # capacity 1: the pool IS the slot
                    _, dcache = drf.prefill_chunk(dparams, ctoks1, dcache,
                                                  off1, dl1, rows_limit=R)
                else:
                    _, d1n = drf.prefill_chunk(
                        dparams, ctoks1, _take_slot(dcache, d_single, cslot),
                        off1, dl1, rows_limit=R)
                    dcache = _put_slot(dcache, d1n, d_single, cslot)
        # ---- 1. draft phase ----
        dlens = seq_lens - prefix_offset
        drafts = []
        q_probs = []                                  # draft probs of drafts
        if s > 0:
            logits, dcache = drf.decode_step(dparams, last2, dcache, dlens - 1)
            lg = logits[:, -1]
            for i in range(0, s):
                if i > 0:
                    logits, dcache = drf.decode_step(dparams, d[:, None],
                                                     dcache, dlens + i)
                    lg = logits[:, 0]
                if sample:
                    qd = jax.nn.softmax(lg / temperature, axis=-1)   # [B, V]
                    d = jax.random.categorical(
                        jax.random.fold_in(k_draft, i), lg / temperature,
                        axis=-1).astype(jnp.int32)
                    q_probs.append(qd)
                else:
                    d = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                drafts.append(d)
            drafts = jnp.stack(drafts, axis=1)                    # [B, s]
        else:
            drafts = jnp.zeros((B, 0), jnp.int32)

        # ---- 2. verify: [t_{n-1}, d_1..d_s] ----
        feed = jnp.concatenate([last2[:, 1:], drafts], axis=1)    # [B, s+1]
        if chunk_ops is not None:
            # one launch, two query kinds: pad both streams to a shared
            # width (padding columns carry position -1 — write nowhere,
            # match nothing) and let the per-row masking sort them out
            Tm = max(s + 1, chunk[0])
            feed_m = (jnp.pad(feed, ((0, 0), (0, Tm - (s + 1))))
                      if Tm > s + 1 else feed)
            ct = (jnp.pad(ctoks, (0, Tm - chunk[0]))
                  if Tm > chunk[0] else ctoks)
            vlogits, tcache_out = tgt.decode_step_mixed(
                tparams, feed_m, tcache, seq_lens, cslot, ct, cstart, ctl,
                cbt_row, s + 1, cu_blocks)
            vlogits = vlogits[:, :s + 1]
        elif paged:
            vlogits, tcache_out = tgt.decode_step(tparams, feed, tcache,
                                                  seq_lens, cu_blocks)
        else:
            vlogits, tcache_out = tgt.decode_step(tparams, feed, tcache,
                                                  seq_lens)
        bidx = jnp.arange(B)

        if not sample:
            # ---- 3a. acceptance (argmax verification, Algorithm 1) ----
            pred = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)  # [B, s+1]
            if s > 0:
                match = drafts == pred[:, :s]                      # [B, s]
                a = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
            else:
                a = jnp.zeros((B,), jnp.int32)
            a = jnp.where(done, 0, a)
            bonus = pred[bidx, a]                                  # [B]
        else:
            # ---- 3b. stochastic acceptance (Leviathan-style) ----
            p_all = jax.nn.softmax(vlogits / temperature, axis=-1)  # [B,s+1,V]
            if s > 0:
                q_all = jnp.stack(q_probs, axis=1)                  # [B,s,V]
                p_at = jnp.take_along_axis(p_all[:, :s],
                                           drafts[..., None], -1)[..., 0]
                q_at = jnp.take_along_axis(q_all, drafts[..., None], -1)[..., 0]
                ratio = p_at / jnp.maximum(q_at, 1e-20)             # [B, s]
                u = jax.random.uniform(k_acc, (B, s))
                acc = u < jnp.minimum(ratio, 1.0)
                a = jnp.cumprod(acc.astype(jnp.int32), axis=1).sum(axis=1)
                # residual distribution at the cut point (or p_s if a == s)
                p_cut = p_all[bidx, a]                              # [B, V]
                q_pad = jnp.concatenate(
                    [q_all, jnp.zeros_like(q_all[:, :1])], axis=1)  # q_s = 0
                q_cut = q_pad[bidx, a]
                resid = jnp.maximum(p_cut - q_cut, 0.0)
                norm = resid.sum(-1, keepdims=True)
                resid = jnp.where(norm > 1e-20, resid / jnp.maximum(norm, 1e-20),
                                  p_cut)
            else:
                a = jnp.zeros((B,), jnp.int32)
                resid = p_all[:, 0]
            a = jnp.where(done, 0, a)
            bonus = jax.random.categorical(
                k_res, jnp.log(jnp.maximum(resid, 1e-30)), axis=-1
            ).astype(jnp.int32)

        # ---- 4. commit ----
        tcache_new = tgt.commit(tcache_out, a)

        # committed tokens this step: drafts[:a] then bonus at index a
        cand = jnp.concatenate([drafts, bonus[:, None]], axis=1)  # [B, s+1]
        cand = cand.at[bidx, a].set(bonus)
        icols = jnp.arange(s + 1)[None, :]                        # [B, s+1]
        write = (icols <= a[:, None]) & (~done[:, None])
        # stop at eos within the committed run
        is_eos = (cand == eos) & write
        eos_cum = jnp.cumsum(is_eos.astype(jnp.int32), axis=1)
        write &= (eos_cum - is_eos.astype(jnp.int32)) == 0        # keep first eos
        n_commit = write.sum(axis=1)

        cols = jnp.where(write, n_generated[:, None] + icols, out.shape[1])
        out = out.at[bidx[:, None], cols].set(cand, mode="drop")
        n_generated = n_generated + n_commit
        seq_lens = seq_lens + n_commit
        hit_eos = (is_eos & write).any(axis=1)
        done = done | hit_eos | (n_generated >= max_new)

        # last two committed tokens for the next draft phase
        last1 = jnp.where(a > 0,
                          cand[bidx, jnp.maximum(a - 1, 0)], last2[:, 1])
        new_last2 = jnp.where(
            done[:, None], last2,
            jnp.stack([last1, bonus], axis=1))
        last2 = jnp.where((n_commit > 0)[:, None], new_last2, last2)
        return (tcache_new, dcache, seq_lens, last2, out, n_generated, done,
                a, n_commit)

    # explicit signatures per variant so legacy callers (launch/dryrun.py,
    # the contiguous pool) keep the 9-arg form while the paged step gains
    # the cu_blocks operand at a fixed argnum (9) graph-lint can check
    if chunk is not None:
        def fn(tparams, dparams, tcache, dcache, seq_lens, last2, out,
               n_generated, done, cu_blocks, chunk_slot, chunk_tokens,
               chunk_start, chunk_t_limit, chunk_d_limit, chunk_bt_row,
               rng=None):
            return body(tparams, dparams, tcache, dcache, seq_lens, last2,
                        out, n_generated, done, cu_blocks, rng,
                        (chunk_slot, chunk_tokens, chunk_start,
                         chunk_t_limit, chunk_d_limit, chunk_bt_row))
    elif paged:
        def fn(tparams, dparams, tcache, dcache, seq_lens, last2, out,
               n_generated, done, cu_blocks, rng=None):
            return body(tparams, dparams, tcache, dcache, seq_lens, last2,
                        out, n_generated, done, cu_blocks, rng)
    else:
        def fn(tparams, dparams, tcache, dcache, seq_lens, last2, out,
               n_generated, done, rng=None):
            return body(tparams, dparams, tcache, dcache, seq_lens, last2,
                        out, n_generated, done, None, rng)
    return fn
