"""Checkpointing: flat-key npz of any parameter/optimizer pytree.

No external deps (orbax is absent in this container); arrays are stored under
their '/'-joined tree paths, the optimizer step as a scalar.  Restore maps
into an existing template pytree so dtypes/structure are authoritative.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any


def _flatten(tree: Params, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save(path: str, params: Params, opt_state=None, step: Optional[int] = None,
         ) -> None:
    flat = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        flat.update({f"opt/m/{k}": v for k, v in _flatten(opt_state.m).items()})
        flat.update({f"opt/v/{k}": v for k, v in _flatten(opt_state.v).items()})
        flat["opt/step"] = np.asarray(opt_state.step)
    if step is not None:
        flat["meta/step"] = np.asarray(step)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # atomic write: tmp + rename
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def _unflatten_into(template: Params, flat: Dict[str, np.ndarray],
                    prefix: str = "") -> Params:
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (tuple, list)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    key = prefix[:-1]
    arr = flat[key]
    t = template
    assert tuple(arr.shape) == tuple(t.shape), f"{key}: {arr.shape} != {t.shape}"
    return jax.numpy.asarray(arr, dtype=t.dtype)


def restore(path: str, params_template: Params, opt_template=None,
            ) -> Tuple[Params, Any, int]:
    """Returns (params, opt_state | None, step)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    params = _unflatten_into(params_template,
                             {k[len("params/"):]: v for k, v in flat.items()
                              if k.startswith("params/")})
    opt_state = None
    if opt_template is not None and any(k.startswith("opt/") for k in flat):
        from repro.training.optimizer import AdamWState
        m = _unflatten_into(opt_template.m,
                            {k[len("opt/m/"):]: v for k, v in flat.items()
                             if k.startswith("opt/m/")})
        v = _unflatten_into(opt_template.v,
                            {k[len("opt/v/"):]: v for k, v in flat.items()
                             if k.startswith("opt/v/")})
        opt_state = AdamWState(step=jax.numpy.asarray(flat["opt/step"]), m=m, v=v)
    step = int(flat.get("meta/step", flat.get("opt/step", np.asarray(0))))
    return params, opt_state, step
