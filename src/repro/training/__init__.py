from repro.training.checkpoint import restore, save
from repro.training.data import DataConfig, batch_at, stream
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw, lr_schedule)
from repro.training.train_step import (cross_entropy, make_distill_step,
                                       make_eval_step, make_loss_fn,
                                       make_train_step)
