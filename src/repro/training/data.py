"""Synthetic data pipeline: deterministic, shardable token streams.

Two generators:
  * ``markov_stream`` — an order-1 Markov chain over a reduced alphabet with a
    skewed transition matrix.  Crucially this makes token streams *partially
    predictable*, so a trained draft model achieves non-trivial acceptance
    l(s) — random-uniform tokens would pin l(s) ~= 0 and void the paper's
    phenomenon on synthetic data.
  * ``uniform_stream`` — i.i.d. uniform tokens (worst-case draftability).

Batches are yielded as {tokens [B, T+1]} (+1 for the shifted labels) and are
deterministic in (seed, step), so multi-host data loading would shard by
taking ``batch[host::n_hosts]`` without coordination.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    kind: str = "markov"      # "markov" | "uniform"
    alphabet: int = 256       # active symbols for the markov stream
    skew: float = 0.85        # prob. mass on each state's favourite successor
    seed: int = 0


def _markov_matrix(cfg: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed + 1)
    A = min(cfg.alphabet, cfg.vocab_size)
    fav = rng.integers(0, A, size=A)
    M = np.full((A, A), (1.0 - cfg.skew) / (A - 1))
    M[np.arange(A), fav] = cfg.skew
    return M / M.sum(1, keepdims=True)


def _markov2_fav(cfg: DataConfig) -> np.ndarray:
    """Order-2 favourite-successor table fav[a, b] (kind='markov2').

    The conditional depends on the last TWO tokens, so a model that can only
    capture order-1 structure (e.g. a 1-layer draft) predicts the marginal
    argmax and disagrees with a deeper model on a tunable fraction of steps —
    producing the partial speculative acceptance the paper's l(s) exhibits.
    """
    rng = np.random.default_rng(cfg.seed + 2)
    A = min(cfg.alphabet, cfg.vocab_size)
    return rng.integers(0, A, size=(A, A))


def batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Deterministic batch for a given step (checkpoint-resumable)."""
    rng = np.random.default_rng((cfg.seed << 20) ^ step)
    B, T = cfg.batch, cfg.seq_len + 1
    A = min(cfg.alphabet, cfg.vocab_size)
    if cfg.kind == "uniform":
        toks = rng.integers(0, cfg.vocab_size, size=(B, T))
    elif cfg.kind == "markov2":
        fav = _markov2_fav(cfg)
        toks = np.empty((B, T), np.int64)
        toks[:, :2] = rng.integers(0, A, size=(B, 2))
        u = rng.random((B, T))
        rand = rng.integers(0, A, size=(B, T))
        for t in range(2, T):
            f = fav[toks[:, t - 2], toks[:, t - 1]]
            toks[:, t] = np.where(u[:, t] < cfg.skew, f, rand[:, t])
    else:
        M = _markov_matrix(cfg)
        cdf = np.cumsum(M, axis=1)
        toks = np.empty((B, T), np.int64)
        toks[:, 0] = rng.integers(0, A, size=B)
        u = rng.random((B, T))
        for t in range(1, T):
            toks[:, t] = (cdf[toks[:, t - 1]] > u[:, t, None]).argmax(axis=1)
    return {"tokens": toks.astype(np.int32)}


def stream(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
