"""AdamW in pure JAX (pytree-native, shardable: optimizer state inherits the
parameter PartitionSpecs, so m/v shard exactly like their parameters)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


def init_adamw(params: Params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_state_specs(param_specs: Params):
    """Optimizer-state PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_specs, v=jax.tree.map(lambda s: s, param_specs))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(step_f / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step_f - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: Params, state: AdamWState,
                 params: Params) -> Tuple[Params, AdamWState, jax.Array]:
    """One AdamW step with global-norm clipping; returns (params', state', gnorm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_ = cfg.b1 * m + (1 - cfg.b1) * g
        v_ = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m_ / b1t, v_ / b2t
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_, v_

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params_ = treedef.unflatten([x[0] for x in new])
    m_ = treedef.unflatten([x[1] for x in new])
    v_ = treedef.unflatten([x[2] for x in new])
    return params_, AdamWState(step=step, m=m_, v=v_), gnorm
