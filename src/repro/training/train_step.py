"""Training step: next-token cross-entropy + MoE aux loss, grads, AdamW.

Used three ways:
  * CPU smoke tests (one step on reduced configs; finiteness + shape asserts);
  * the draft-distillation example (the paper's SSM must mimic the target);
  * the ``train_4k`` dry-run shape (lower + compile on the production mesh).

The loss recomputes activations through the model's scanned layers;
``jax.checkpoint`` around the model forward gives the standard remat-per-layer
policy (scan carries only layer boundaries, each recomputed on the backward
pass), which is what makes train_4k fit at 48-60 layers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update

Params = Any


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token NLL. logits [B,T,V] fp32; labels [B,T] int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def make_loss_fn(model, cfg: ModelConfig, remat: bool = True,
                 extra_keys: Tuple[str, ...] = ()):
    """loss(params, batch) -> (loss, metrics).  batch: tokens [B,T+1] plus
    optional modality extras (src_embeds / prefix_embeds)."""

    # NOTE: rematerialization is owned by the models themselves — every
    # family jax.checkpoint's its scanned layer body (remat-per-layer), which
    # is the policy that makes train_4k fit at 48-60 layers.  The ``remat``
    # flag is kept for API stability but adds no outer wrapper (an outer
    # checkpoint around the whole forward would *not* bound scan residuals).
    def fwd(params, inputs, kw):
        return model.forward(params, inputs, **kw)

    def loss_fn(params, batch: Dict[str, jax.Array]):
        tokens = batch["tokens"]
        inputs = tokens[:, :-1]
        labels = batch.get("labels", tokens[:, 1:])
        kw = {k: batch[k] for k in extra_keys if k in batch}
        logits, aux = fwd(params, inputs, kw)
        # modality-prefix positions (vlm) predict nothing: slice them off
        if logits.shape[1] != inputs.shape[1]:
            logits = logits[:, logits.shape[1] - inputs.shape[1]:]
        ce = cross_entropy(logits, labels, batch.get("mask"))
        loss = ce + aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}

    return loss_fn


def make_train_step(model, cfg: ModelConfig, opt: AdamWConfig,
                    remat: bool = True, extra_keys: Tuple[str, ...] = ()):
    """Returns train_step(params, opt_state, batch) -> (params', opt_state',
    metrics).  Pure; jit/pjit it at the call site with the right shardings."""
    loss_fn = make_loss_fn(model, cfg, remat, extra_keys)

    def train_step(params: Params, opt_state: AdamWState, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, gnorm = adamw_update(opt, grads, opt_state, params)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model, cfg: ModelConfig, extra_keys: Tuple[str, ...] = ()):
    loss_fn = make_loss_fn(model, cfg, remat=False, extra_keys=extra_keys)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step


# ---------------------------------------------------------------------------
# draft distillation (beyond-paper utility): train the SSM on the target's
# greedy outputs so l(s) is non-trivial on synthetic data.


def make_distill_step(draft_model, cfg: ModelConfig, opt: AdamWConfig,
                      temperature: float = 1.0):
    """Distill target logits into the draft: KL(target || draft) on the same
    token stream.  batch: {tokens [B,T+1], teacher_logits [B,T,V]}."""

    def loss_fn(params, batch):
        inputs = batch["tokens"][:, :-1]
        logits, _ = draft_model.forward(params, inputs)
        t = jax.nn.log_softmax(batch["teacher_logits"] / temperature, axis=-1)
        d = jax.nn.log_softmax(logits[..., :batch["teacher_logits"].shape[-1]], axis=-1)
        kl = jnp.sum(jnp.exp(t) * (t - d), axis=-1).mean()
        return kl, {"kl": kl}

    def step(params, opt_state, batch):
        (kl, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, gnorm = adamw_update(opt, grads, opt_state, params)
        return params, opt_state, dict(metrics, grad_norm=gnorm)

    return step
