"""Latency metrics & timeline grouping for the serving experiments."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.serving.request import Request
from repro.serving.server import ServeResult


@dataclass(frozen=True)
class LatencySummary:
    mean: float
    p50: float
    p90: float
    p99: float
    max: float
    n: int

    @staticmethod
    def of(latencies: Sequence[float]) -> "LatencySummary":
        a = np.asarray(latencies, dtype=np.float64)
        return LatencySummary(
            mean=float(a.mean()), p50=float(np.percentile(a, 50)),
            p90=float(np.percentile(a, 90)), p99=float(np.percentile(a, 99)),
            max=float(a.max()), n=len(a))


def summarize(result: ServeResult) -> LatencySummary:
    return LatencySummary.of(result.latencies)


def timeline_groups(result: ServeResult, group: int = 40,
                    ) -> List[Tuple[float, float]]:
    """Fig. 6 view: (timestamp of first request in group, mean latency of the
    group) for consecutive groups of ``group`` requests in arrival order."""
    reqs = sorted(result.requests, key=lambda r: r.arrival)
    out = []
    for i in range(0, len(reqs) - group + 1, group):
        chunk = reqs[i:i + group]
        out.append((chunk[0].arrival, float(np.mean([r.latency for r in chunk]))))
    return out


def batch_size_histogram(result: ServeResult) -> Dict[int, int]:
    h: Dict[int, int] = {}
    for b in result.batches:
        h[b.batch_size] = h.get(b.batch_size, 0) + 1
    return h


def speedup(base: ServeResult, new: ServeResult) -> float:
    return base.mean_latency / new.mean_latency


# ---------------------------------------------------------------------------
# iteration-level (continuous batching) metrics: TTFT / ITL / occupancy
# — only schedulers that commit at step granularity fill these in


def ttft_summary(result: ServeResult) -> LatencySummary:
    """Time-to-first-token distribution (arrival -> first committed token)."""
    vals = [r.ttft for r in result.requests if r.ttft is not None]
    if not vals:
        raise ValueError("no per-request first-token times recorded "
                         "(run an iteration-level scheduler)")
    return LatencySummary.of(vals)


def itl_summary(result: ServeResult) -> LatencySummary:
    """Mean inter-token-latency distribution across requests."""
    vals = [r.itl for r in result.requests if r.itl is not None]
    if not vals:
        raise ValueError("no per-request inter-token latencies recorded")
    return LatencySummary.of(vals)


def occupancy_timeline(result: ServeResult) -> List[Tuple[float, int]]:
    """(step start time, live batch size) per executed iteration."""
    return [(b.start, b.batch_size) for b in result.batches]


def mean_occupancy(result: ServeResult) -> float:
    """Time-weighted mean live batch size over the serving run."""
    num = sum(b.batch_size * b.duration for b in result.batches)
    den = sum(b.duration for b in result.batches)
    return num / max(den, 1e-12)
