"""Latency metrics & timeline grouping for the serving experiments."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.serving.request import Request
from repro.serving.server import ServeResult


@dataclass(frozen=True)
class LatencySummary:
    mean: float
    p50: float
    p90: float
    p99: float
    max: float
    n: int

    @staticmethod
    def of(latencies: Sequence[float]) -> "LatencySummary":
        a = np.asarray(latencies, dtype=np.float64)
        return LatencySummary(
            mean=float(a.mean()), p50=float(np.percentile(a, 50)),
            p90=float(np.percentile(a, 90)), p99=float(np.percentile(a, 99)),
            max=float(a.max()), n=len(a))


def summarize(result: ServeResult) -> LatencySummary:
    return LatencySummary.of(result.latencies)


def timeline_groups(result: ServeResult, group: int = 40,
                    ) -> List[Tuple[float, float]]:
    """Fig. 6 view: (timestamp of first request in group, mean latency of the
    group) for consecutive groups of ``group`` requests in arrival order."""
    reqs = sorted(result.requests, key=lambda r: r.arrival)
    out = []
    for i in range(0, len(reqs) - group + 1, group):
        chunk = reqs[i:i + group]
        out.append((chunk[0].arrival, float(np.mean([r.latency for r in chunk]))))
    return out


def batch_size_histogram(result: ServeResult) -> Dict[int, int]:
    h: Dict[int, int] = {}
    for b in result.batches:
        h[b.batch_size] = h.get(b.batch_size, 0) + 1
    return h


def speedup(base: ServeResult, new: ServeResult) -> float:
    return base.mean_latency / new.mean_latency
