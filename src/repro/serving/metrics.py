"""Latency metrics & timeline grouping for the serving experiments."""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.serving.request import Request
from repro.serving.server import ServeResult


@dataclass(frozen=True)
class LatencySummary:
    mean: float
    p50: float
    p90: float
    p99: float
    max: float
    n: int
    n_skipped: int = 0      # unfinished/rejected requests excluded upstream

    @staticmethod
    def of(latencies: Sequence[float], name: str = "latency",
           n_skipped: int = 0) -> "LatencySummary":
        a = np.asarray(latencies, dtype=np.float64)
        if a.size == 0:
            # previously this died inside numpy ("zero-size array to
            # reduction operation maximum") — name the empty metric instead
            raise ValueError(
                f"LatencySummary.of: no '{name}' samples to summarize"
                + (f" ({n_skipped} unfinished/rejected requests skipped)"
                   if n_skipped else ""))
        return LatencySummary(
            mean=float(a.mean()), p50=float(np.percentile(a, 50)),
            p90=float(np.percentile(a, 90)), p99=float(np.percentile(a, 99)),
            max=float(a.max()), n=len(a), n_skipped=n_skipped)


def _finished(result: ServeResult) -> Tuple[List[Request], int]:
    """Requests with a recorded finish time, plus the skipped count.

    Runs that were interrupted (or that rejected requests) leave
    ``finish = None`` on some records; summarizing those used to crash via
    the ``Request.latency`` assert.
    """
    done = [r for r in result.requests if r.finish is not None]
    return done, len(result.requests) - len(done)


def summarize(result: ServeResult) -> LatencySummary:
    done, skipped = _finished(result)
    return LatencySummary.of([r.latency for r in done], name="latency",
                             n_skipped=skipped)


def timeline_groups(result: ServeResult, group: int = 40,
                    ) -> List[Tuple[float, float]]:
    """Fig. 6 view: (timestamp of first request in group, mean latency of the
    group) for consecutive groups of ``group`` requests in arrival order.
    When the request count is not a multiple of ``group``, the tail
    remainder is emitted as a final partial group (previously it was
    silently dropped).  Unfinished/rejected requests are skipped (with a
    warning)."""
    done, skipped = _finished(result)
    if skipped:
        warnings.warn(f"timeline_groups: skipping {skipped} unfinished/"
                      f"rejected requests")
    reqs = sorted(done, key=lambda r: r.arrival)
    out = []
    for i in range(0, len(reqs), group):
        chunk = reqs[i:i + group]
        out.append((chunk[0].arrival, float(np.mean([r.latency for r in chunk]))))
    return out


def batch_size_histogram(result: ServeResult) -> Dict[int, int]:
    h: Dict[int, int] = {}
    for b in result.batches:
        h[b.batch_size] = h.get(b.batch_size, 0) + 1
    return h


def speedup(base: ServeResult, new: ServeResult) -> float:
    return base.mean_latency / new.mean_latency


# ---------------------------------------------------------------------------
# iteration-level (continuous batching) metrics: TTFT / ITL / occupancy
# — only schedulers that commit at step granularity fill these in


def ttft_summary(result: ServeResult) -> LatencySummary:
    """Time-to-first-token distribution (arrival -> first committed token)."""
    vals = [r.ttft for r in result.requests if r.ttft is not None]
    if not vals:
        raise ValueError("no per-request first-token times recorded "
                         "(run an iteration-level scheduler)")
    return LatencySummary.of(vals, name="ttft",
                             n_skipped=len(result.requests) - len(vals))


def itl_summary(result: ServeResult) -> LatencySummary:
    """Mean inter-token-latency distribution across requests."""
    vals = [r.itl for r in result.requests if r.itl is not None]
    if not vals:
        raise ValueError("no per-request inter-token latencies recorded")
    return LatencySummary.of(vals, name="itl",
                             n_skipped=len(result.requests) - len(vals))


def occupancy_timeline(result: ServeResult) -> List[Tuple[float, int]]:
    """(step start time, live batch size) per executed iteration."""
    return [(b.start, b.batch_size) for b in result.batches]


def mean_occupancy(result: ServeResult) -> float:
    """Time-weighted mean live batch size over the serving run."""
    if not result.batches:
        # previously the 1e-12 denominator guard silently returned ~0 here,
        # which reads as "the pool sat empty" rather than "nothing ran"
        raise ValueError("mean_occupancy: no executed batches to average "
                         "over (empty ServeResult.batches)")
    num = sum(b.batch_size * b.duration for b in result.batches)
    den = sum(b.duration for b in result.batches)
    if den <= 0.0:
        raise ValueError("mean_occupancy: executed batches carry zero total "
                         "duration")
    return num / den


def goodput(result: ServeResult) -> float:
    """Committed tokens per second of makespan (first arrival to last
    finish), counting finished requests only — the serving benchmark's
    primary regression metric."""
    done, _ = _finished(result)
    if not done:
        raise ValueError("goodput: no finished requests")
    t0 = min(r.arrival for r in result.requests)
    t1 = max(r.finish for r in done)
    if t1 <= t0:
        raise ValueError("goodput: zero makespan")
    return sum(r.n_generated for r in done) / (t1 - t0)


def admission_gaps(result: ServeResult) -> List[float]:
    """Per-iteration wall time of iterations that performed admission work
    (whole-prompt prefills or prefill chunks) while a decode batch was
    already running — i.e. the inter-token gap those admissions impose on
    every running request.  The chunked-prefill study compares the max of
    this under whole-prompt-burst vs chunked admission.

    ``StepTrace.occupancy`` is recorded *after* admission, so it counts
    the just-admitted slots themselves; an admission into an idle pool
    stalls nobody and must not count as a gap.  A request is "running"
    here once it has decoded in an earlier iteration.
    """
    if result.trace is None:
        raise ValueError("no StepTrace recorded "
                         "(run an iteration-level scheduler)")
    gaps = []
    seen_decoding: set = set()
    for t in result.trace:
        work = (sum(dt for dt in t.prefill_s if dt > 0)
                + sum(t.chunk_s))
        stalled = [rid for rid in t.rids if rid in seen_decoding]
        if work > 0 and stalled:
            gaps.append(t.duration + work)
        seen_decoding.update(t.rids)
    return gaps
