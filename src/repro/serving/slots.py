"""Host-side bookkeeping for the engine's KV slot pool.

The device half of a slot pool is a fixed-capacity
:class:`~repro.core.spec_decode.DecodeState` (rows = slots, empty rows are
``done``); this module tracks the host half: which request occupies which
slot, how many tokens it still owes, and the claim/retire lifecycle the
iteration-level scheduler (serving/scheduler.py) drives every speculative
step.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.serving.request import Request


class SlotPool:
    """Fixed-capacity slot bookkeeping: claim on admit, retire on finish."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._reqs: List[Optional[Request]] = [None] * capacity
        self._remaining = np.zeros(capacity, dtype=np.int64)
        # lowest-numbered free slot claimed first (deterministic placement)
        self._free = list(range(capacity - 1, -1, -1))

    # ------------------------------------------------------------------
    # lifecycle

    def claim(self, req: Request) -> int:
        """Assign ``req`` to a free slot; returns the slot index."""
        if not self._free:
            raise RuntimeError("slot pool full")
        slot = self._free.pop()
        self._reqs[slot] = req
        self._remaining[slot] = req.max_new
        return slot

    def retire(self, slot: int) -> Request:
        """Release ``slot``; returns the request that occupied it."""
        req = self._reqs[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} is not occupied")
        self._reqs[slot] = None
        self._remaining[slot] = 0
        self._free.append(slot)
        self._free.sort(reverse=True)
        return req

    # ------------------------------------------------------------------
    # accounting

    def consume(self, slot: int, tokens: int) -> None:
        self._remaining[slot] -= tokens

    def remaining(self, slot: int) -> int:
        return int(self._remaining[slot])

    def request_at(self, slot: int) -> Request:
        req = self._reqs[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} is not occupied")
        return req

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._reqs) if r is not None]

    @property
    def occupancy(self) -> int:
        return sum(r is not None for r in self._reqs)

    @property
    def free_count(self) -> int:
        return len(self._free)
