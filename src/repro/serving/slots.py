"""Host-side bookkeeping for the engine's KV slot pool.

The device half of a slot pool is a fixed-capacity
:class:`~repro.core.spec_decode.DecodeState` (rows = slots, empty rows are
``done``); this module tracks the host half: which request occupies which
slot, how many tokens it still owes, and the claim/retire lifecycle the
iteration-level scheduler (serving/scheduler.py) drives every speculative
step.

Paged KV (vLLM-style): :class:`BlockPool` is a free-list allocator of
fixed-size KV blocks and :class:`PagedKVTables` maps each slot to the list
of physical blocks holding its KV rows.  The same class is the host truth
for the live engine (which also consumes the concrete block ids) and the
count-exact mirror inside :class:`~repro.serving.scheduler.SimStepBackend`,
so the scheduler's preemption decisions — pure functions of (free blocks,
per-slot tokens, per-slot allocated blocks) — replay identically sim vs
live.

Prefix sharing (copy-on-write): every block carries a reference count.
``alloc`` hands blocks out at refcount 1; a block enters the free list
exactly when its count drops to 0 (``decref``/``release``), so the free
set and the referenced set partition the pool at all times.  A block with
refcount > 1 is SHARED — between slots whose requests share a prompt
prefix, and/or with the :class:`~repro.serving.prefix_cache.PrefixCache`
radix index, which holds its own +1 on every block it indexes — and must
never be written in place: writers go through
:meth:`PagedKVTables.cow_for_range`, which swaps a fresh copy into the
writing slot's table (the engine copies the rows with a jit-cached
block-copy scatter) and drops the shared reference.  Cache-held blocks at
refcount 1 are *reclaimable*: allocation under pressure evicts them
LRU-first (``PrefixCache.reclaim``) and records the evicted ids in
``evicted_pending`` so the live engine can wipe their ``pos`` rows before
the blocks are ever handed out again (the standing "free blocks carry
pos = -1" invariant).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.serving.request import Request


class BlockPoolExhausted(RuntimeError):
    """Raised when an allocation cannot be served from the free list.

    The scheduler is expected to preempt *before* this can happen; seeing it
    from the engine means admission/preemption accounting is out of sync.
    """


class BlockPool:
    """Free-list allocator of fixed-size KV blocks (the paged pool's core).

    Blocks are handed out lowest-id-first and the free list is kept sorted,
    so allocation is deterministic — a requirement for sim-vs-live parity of
    preemption decisions (both sides see the same free count at every step).

    Every block carries a reference count: 0 while on the free list, 1 when
    exclusively owned, > 1 when shared between slot tables and/or the prefix
    cache.  ``free`` is a bulk :meth:`decref` — a block only re-enters the
    free list when its last reference drops — so with no sharing the
    behavior is exactly the pre-refcount allocator.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # lowest-numbered block allocated first (pop from the tail)
        self._free = list(range(num_blocks - 1, -1, -1))
        self._refs = [0] * num_blocks

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV rows."""
        return -(-int(n_tokens) // self.block_size)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise BlockPoolExhausted(
                f"requested {n} blocks, only {len(self._free)} free "
                f"(pool of {self.num_blocks}); the scheduler should have "
                f"preempted before this allocation")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._refs[b] = 1
        return out

    def incref(self, block: int) -> int:
        """Add a reference to an allocated block; returns the new count."""
        if self._refs[block] < 1:
            raise RuntimeError(
                f"incref on free block {block}: references may only be "
                f"added to a block that is already owned")
        self._refs[block] += 1
        return self._refs[block]

    def decref(self, block: int) -> bool:
        """Drop one reference; returns True when the block became free."""
        if self._refs[block] < 1:
            raise RuntimeError(
                f"double-free of block {block} (refcount already 0)")
        self._refs[block] -= 1
        if self._refs[block] == 0:
            self._free.append(block)
            self._free.sort(reverse=True)
            return True
        return False

    def refcount(self, block: int) -> int:
        return self._refs[block]

    def free(self, blocks: List[int]) -> List[int]:
        """Bulk :meth:`decref`; returns the blocks that actually became
        free (all of them when nothing is shared — the pre-refcount
        contract)."""
        freed = []
        for b in blocks:
            if self._refs[b] < 1:
                raise RuntimeError(
                    f"double-free of block {b} (refcount already 0)")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                freed.append(b)
        if freed:
            self._free.extend(freed)
            self._free.sort(reverse=True)
        return freed

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def shared_count(self) -> int:
        """Blocks currently referenced more than once (shared)."""
        return sum(r > 1 for r in self._refs)

    @property
    def exclusive_count(self) -> int:
        """Blocks referenced exactly once (exclusively owned)."""
        return sum(r == 1 for r in self._refs)

    def check_invariants(self) -> None:
        """Raise AssertionError unless the free set and the referenced set
        partition the pool — the no-leak / no-double-free invariant the
        property suite asserts after every operation."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate id on the free list"
        assert self._free == sorted(free, reverse=True), \
            "free list not sorted descending"
        for b in range(self.num_blocks):
            if b in free:
                assert self._refs[b] == 0, \
                    f"block {b} is free but has refcount {self._refs[b]}"
            else:
                assert self._refs[b] >= 1, \
                    f"block {b} leaked: not free, refcount 0"
        assert len(free) + sum(r > 0 for r in self._refs) == self.num_blocks

    @staticmethod
    def _run_fragmentation(ids_desc: List[int]) -> float:
        """1 − (largest contiguous run / count) over a descending id list."""
        if not ids_desc:
            return 0.0
        best = run = 1
        for prev, cur in zip(ids_desc, ids_desc[1:]):
            run = run + 1 if prev == cur + 1 else 1
            best = max(best, run)
        return 1.0 - best / len(ids_desc)

    @property
    def fragmentation(self) -> float:
        """Free-list fragmentation in [0, 1]: one minus the largest
        contiguous free run over the total free count (0.0 when the free
        list is empty or a single run).  Block tables make any free block
        usable, so this is a telemetry gauge, not an allocator concern —
        it tracks how shuffled the pool has become under churn."""
        return self._run_fragmentation(self._free)


class PagedKVTables:
    """Per-slot block tables over a :class:`BlockPool`.

    Tracks, per slot, the physical blocks backing its KV rows and the number
    of tokens written so far (prompt + raw committed).  ``ensure`` grows a
    table block-by-block as the sequence grows — allocate-on-commit — and
    ``release`` drops one reference on every block on retire/preempt (with
    no sharing that frees them all — the pre-refcount contract).

    With a :class:`~repro.serving.prefix_cache.PrefixCache` attached
    (:meth:`attach_cache`), allocations that outrun the free list reclaim
    LRU cache-only blocks first; the evicted ids accumulate in
    ``evicted_pending`` until the live engine wipes their device ``pos``
    rows (sim backends just clear the list).  ``attach`` maps already-held
    cache blocks into a slot's table at refcount+1 and
    :meth:`cow_for_range` is the only legal way to make shared rows
    writable again.
    """

    def __init__(self, num_blocks: int, block_size: int, capacity: int,
                 max_blocks_per_slot: int):
        if max_blocks_per_slot < 1:
            raise ValueError("max_blocks_per_slot must be >= 1")
        if num_blocks < max_blocks_per_slot:
            # a lone maximal request must always fit, or the scheduler could
            # spin forever on a request it can never admit (every admitted
            # request is bounded by the per-slot cap, so this also makes the
            # preemption loop's "a single slot always fits" invariant hold)
            raise ValueError(
                f"num_blocks={num_blocks} < max_blocks_per_slot="
                f"{max_blocks_per_slot}: the pool could not hold even one "
                f"maximal request")
        self.pool = BlockPool(num_blocks, block_size)
        self.capacity = capacity
        self.max_blocks = max_blocks_per_slot
        self._tables: List[List[int]] = [[] for _ in range(capacity)]
        self._tokens = np.zeros(capacity, dtype=np.int64)
        # slots whose prefill is still being fed chunk-by-chunk: they hold
        # blocks but do not decode, so the per-step worst-case growth
        # (seq + s) must not be charged to them — the live engine and the
        # sim mirror both skip pending slots in their pre-step growth
        self._pending: set = set()
        # prefix cache (None = sharing disabled; exact legacy behavior)
        self.cache = None
        # cache blocks evicted by reclaim-under-pressure whose device pos
        # rows still hold stale entries; the live engine drains this list
        # (pos.at[ids].set(-1)) before the next dispatch that could hand
        # the ids back out, sim backends just clear it
        self.evicted_pending: List[int] = []
        self.evicted_total = 0

    # ------------------------------------------------------------------
    # geometry

    @property
    def block_size(self) -> int:
        return self.pool.block_size

    @property
    def num_blocks(self) -> int:
        return self.pool.num_blocks

    @property
    def free_blocks(self) -> int:
        return self.pool.free_count

    @property
    def available_blocks(self) -> int:
        """Blocks an allocation can actually obtain: the free list plus
        cache-only (refcount-1, unlocked) blocks that reclaim-under-pressure
        may evict.  Every feasibility check in the scheduler uses this —
        with no cache attached it equals ``free_blocks`` exactly."""
        extra = self.cache.reclaimable() if self.cache is not None else 0
        return self.pool.free_count + extra

    @property
    def shared_blocks(self) -> int:
        """Blocks referenced more than once (slot tables and/or cache)."""
        return self.pool.shared_count

    @property
    def cached_blocks(self) -> int:
        """Blocks currently indexed by the attached prefix cache."""
        return self.cache.size if self.cache is not None else 0

    @property
    def fragmentation(self) -> float:
        """Free-list fragmentation gauge (see BlockPool.fragmentation).

        With a prefix cache attached the gauge is computed over the free
        list *plus* the reclaimable cache-only blocks: those are the ids an
        allocation can actually obtain, and the old free-list-only walk
        would misreport 0.0 fragmentation on a pool whose every available
        block sits (scattered) in the cache."""
        if self.cache is None:
            return self.pool.fragmentation
        ids = sorted(set(self.pool._free) | set(self.cache.reclaimable_ids()),
                     reverse=True)
        return BlockPool._run_fragmentation(ids)

    def attach_cache(self, cache) -> None:
        """Attach a :class:`~repro.serving.prefix_cache.PrefixCache` so
        allocations can reclaim LRU cache-only blocks under pressure."""
        if cache.pool is not self.pool:
            raise ValueError("prefix cache is bound to a different BlockPool")
        self.cache = cache

    @property
    def logical_len(self) -> int:
        """Per-slot logical capacity in tokens (block table fully grown)."""
        return self.max_blocks * self.pool.block_size

    def blocks_for(self, n_tokens: int) -> int:
        return self.pool.blocks_for(n_tokens)

    # ------------------------------------------------------------------
    # per-slot accounting

    def tokens(self, slot: int) -> int:
        return int(self._tokens[slot])

    def allocated(self, slot: int) -> int:
        return len(self._tables[slot])

    def table(self, slot: int) -> List[int]:
        return list(self._tables[slot])

    def active_slots(self) -> List[int]:
        return [i for i, t in enumerate(self._tables) if t]

    # ------------------------------------------------------------------
    # chunked-prefill (pending) slots

    def mark_pending(self, slot: int) -> None:
        """Flag ``slot`` as mid-chunked-prefill (holds blocks, not decoding)."""
        self._pending.add(slot)

    def clear_pending(self, slot: int) -> None:
        self._pending.discard(slot)

    def is_pending(self, slot: int) -> bool:
        return slot in self._pending

    # ------------------------------------------------------------------
    # lifecycle

    def _alloc(self, n: int) -> List[int]:
        """Pool allocation that reclaims LRU cache-only blocks when the
        free list alone cannot serve the request."""
        short = n - self.pool.free_count
        if short > 0 and self.cache is not None:
            evicted = self.cache.reclaim(short)
            if evicted:
                self.evicted_pending.extend(evicted)
                self.evicted_total += len(evicted)
        return self.pool.alloc(n)

    def prefill(self, slot: int, n_tokens: int) -> List[int]:
        """Allocate the blocks covering a fresh prompt in ``slot``."""
        if self._tables[slot]:
            raise RuntimeError(f"slot {slot} already holds blocks")
        need = self.blocks_for(n_tokens)
        if need > self.max_blocks:
            raise ValueError(
                f"{n_tokens} tokens need {need} blocks > per-slot cap "
                f"{self.max_blocks}")
        blocks = self._alloc(need)
        self._tables[slot] = blocks
        self._tokens[slot] = n_tokens
        return blocks

    def attach(self, slot: int, blocks: List[int], n_tokens: int) -> None:
        """Map already-owned cache blocks into an empty slot's table.

        Each block gains a reference (the slot's own); the caller must
        already hold the blocks (the admission lock or the cache index), so
        they cannot have been evicted between match and attach.  The slot
        starts at ``n_tokens`` = blocks·block_size prefix rows; the suffix
        is fed afterwards through the normal ensure/commit chunk path.
        """
        if self._tables[slot]:
            raise RuntimeError(f"slot {slot} already holds blocks")
        if len(blocks) > self.max_blocks:
            raise ValueError(
                f"{len(blocks)} prefix blocks > per-slot cap {self.max_blocks}")
        if n_tokens != len(blocks) * self.block_size:
            raise ValueError(
                f"attach of {len(blocks)} blocks must cover exactly "
                f"{len(blocks) * self.block_size} tokens, got {n_tokens}")
        for b in blocks:
            self.pool.incref(b)
        self._tables[slot] = list(blocks)
        self._tokens[slot] = n_tokens

    def cow_for_range(self, slot: int, lo: int, hi: int) -> List[Tuple[int, int]]:
        """Make token rows [lo, hi) of ``slot`` writable: every shared
        block covering the range is swapped for a fresh exclusive copy.

        Returns (src, dst) pairs for the engine's jit-cached block-copy
        scatter (host tables are updated here; device rows move on the
        engine).  Allocation happens before the decref, and a shared
        block's count stays ≥ 1 after it, so the source rows remain valid
        for the device copy.
        """
        if hi <= lo:
            return []
        pairs: List[Tuple[int, int]] = []
        table = self._tables[slot]
        # indices past the table are not allocated yet — ensure() will hand
        # them out fresh (exclusively owned), so they need no copy
        for bi in range(lo // self.block_size,
                        min(self.blocks_for(hi), len(table))):
            b = table[bi]
            if self.pool.refcount(b) > 1:
                dst = self._alloc(1)[0]
                self.pool.decref(b)
                table[bi] = dst
                pairs.append((b, dst))
        return pairs

    def ensure(self, slot: int, n_tokens: int) -> List[int]:
        """Grow ``slot``'s table to cover ``n_tokens``; returns new blocks."""
        need = self.blocks_for(n_tokens) - len(self._tables[slot])
        if need <= 0:
            return []
        if len(self._tables[slot]) + need > self.max_blocks:
            raise ValueError(
                f"slot {slot} would exceed the per-slot cap of "
                f"{self.max_blocks} blocks")
        new = self._alloc(need)
        self._tables[slot].extend(new)
        return new

    def commit(self, slot: int, n_new_tokens: int) -> None:
        self._tokens[slot] += int(n_new_tokens)

    def release(self, slot: int) -> List[int]:
        """Drop the slot's reference on every block (retire or preempt).

        Returns only the blocks that actually became free — blocks still
        referenced by the prefix cache (or another slot) survive with
        their KV rows intact, so the engine must clear device ``pos`` rows
        only for the returned ids.
        """
        blocks = self._tables[slot]
        self._tables[slot] = []
        self._tokens[slot] = 0
        self._pending.discard(slot)
        return self.pool.free(blocks)

    def device_tables(self, exclude_pending: bool = False) -> np.ndarray:
        """[capacity, max_blocks] int32 block table, -1 = unallocated.

        ``exclude_pending=True`` keeps mid-chunked-prefill slots' rows at -1:
        the decode step uploads with this set, so a parked slot's (masked,
        garbage) decode-step writes stay dropped on the device even while
        other slots' growth re-uploads the table — its blocks are only
        published by the final chunk's commit.
        """
        out = np.full((self.capacity, self.max_blocks), -1, np.int32)
        for i, t in enumerate(self._tables):
            if exclude_pending and i in self._pending:
                continue
            out[i, :len(t)] = t
        return out


class SlotPool:
    """Fixed-capacity slot bookkeeping: claim on admit, retire on finish."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._reqs: List[Optional[Request]] = [None] * capacity
        self._remaining = np.zeros(capacity, dtype=np.int64)
        # lowest-numbered free slot claimed first (deterministic placement)
        self._free = list(range(capacity - 1, -1, -1))

    # ------------------------------------------------------------------
    # lifecycle

    def claim(self, req: Request, slot: Optional[int] = None) -> int:
        """Assign ``req`` to a free slot; returns the slot index.

        Without ``slot``, the lowest-numbered free slot is claimed
        (deterministic placement).  With ``slot``, that specific free slot
        is claimed — the sharded scheduler's per-host admission queue
        (:class:`~repro.serving.scheduler.HostShardQueue`) uses this to
        round-robin placements across the data shards of a mesh-sharded
        pool.  A preempted request re-enters with ``n_generated > 0``; its
        budget resumes where it left off rather than restarting at
        ``max_new``.
        """
        if not self._free:
            raise RuntimeError("slot pool full")
        if slot is None:
            slot = self._free.pop()
        else:
            if slot not in self._free:
                raise RuntimeError(f"slot {slot} is not free")
            self._free.remove(slot)
        self._reqs[slot] = req
        self._remaining[slot] = req.max_new - req.n_generated
        return slot

    def is_free(self, slot: int) -> bool:
        return self._reqs[slot] is None

    def retire(self, slot: int) -> Request:
        """Release ``slot``; returns the request that occupied it."""
        req = self._reqs[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} is not occupied")
        self._reqs[slot] = None
        self._remaining[slot] = 0
        self._free.append(slot)
        self._free.sort(reverse=True)
        return req

    # ------------------------------------------------------------------
    # accounting

    def consume(self, slot: int, tokens: int) -> None:
        self._remaining[slot] -= tokens

    def remaining(self, slot: int) -> int:
        return int(self._remaining[slot])

    def request_at(self, slot: int) -> Request:
        req = self._reqs[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} is not occupied")
        return req

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._reqs) if r is not None]

    @property
    def occupancy(self) -> int:
        return sum(r is not None for r in self._reqs)

    @property
    def free_count(self) -> int:
        return len(self._free)
