"""Host-side bookkeeping for the engine's KV slot pool.

The device half of a slot pool is a fixed-capacity
:class:`~repro.core.spec_decode.DecodeState` (rows = slots, empty rows are
``done``); this module tracks the host half: which request occupies which
slot, how many tokens it still owes, and the claim/retire lifecycle the
iteration-level scheduler (serving/scheduler.py) drives every speculative
step.

Paged KV (vLLM-style): :class:`BlockPool` is a free-list allocator of
fixed-size KV blocks and :class:`PagedKVTables` maps each slot to the list
of physical blocks holding its KV rows.  The same class is the host truth
for the live engine (which also consumes the concrete block ids) and the
count-exact mirror inside :class:`~repro.serving.scheduler.SimStepBackend`,
so the scheduler's preemption decisions — pure functions of (free blocks,
per-slot tokens, per-slot allocated blocks) — replay identically sim vs
live.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.serving.request import Request


class BlockPoolExhausted(RuntimeError):
    """Raised when an allocation cannot be served from the free list.

    The scheduler is expected to preempt *before* this can happen; seeing it
    from the engine means admission/preemption accounting is out of sync.
    """


class BlockPool:
    """Free-list allocator of fixed-size KV blocks (the paged pool's core).

    Blocks are handed out lowest-id-first and the free list is kept sorted,
    so allocation is deterministic — a requirement for sim-vs-live parity of
    preemption decisions (both sides see the same free count at every step).
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # lowest-numbered block allocated first (pop from the tail)
        self._free = list(range(num_blocks - 1, -1, -1))

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV rows."""
        return -(-int(n_tokens) // self.block_size)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise BlockPoolExhausted(
                f"requested {n} blocks, only {len(self._free)} free "
                f"(pool of {self.num_blocks}); the scheduler should have "
                f"preempted before this allocation")
        return [self._free.pop() for _ in range(n)]

    def free(self, blocks: List[int]) -> None:
        self._free.extend(blocks)
        self._free.sort(reverse=True)

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def fragmentation(self) -> float:
        """Free-list fragmentation in [0, 1]: one minus the largest
        contiguous free run over the total free count (0.0 when the free
        list is empty or a single run).  Block tables make any free block
        usable, so this is a telemetry gauge, not an allocator concern —
        it tracks how shuffled the pool has become under churn."""
        if not self._free:
            return 0.0
        # _free is kept sorted descending; walk runs of consecutive ids
        best = run = 1
        for prev, cur in zip(self._free, self._free[1:]):
            run = run + 1 if prev == cur + 1 else 1
            best = max(best, run)
        return 1.0 - best / len(self._free)


class PagedKVTables:
    """Per-slot block tables over a :class:`BlockPool`.

    Tracks, per slot, the physical blocks backing its KV rows and the number
    of tokens written so far (prompt + raw committed).  ``ensure`` grows a
    table block-by-block as the sequence grows — allocate-on-commit — and
    ``release`` returns every block to the free list on retire/preempt.
    """

    def __init__(self, num_blocks: int, block_size: int, capacity: int,
                 max_blocks_per_slot: int):
        if max_blocks_per_slot < 1:
            raise ValueError("max_blocks_per_slot must be >= 1")
        if num_blocks < max_blocks_per_slot:
            # a lone maximal request must always fit, or the scheduler could
            # spin forever on a request it can never admit (every admitted
            # request is bounded by the per-slot cap, so this also makes the
            # preemption loop's "a single slot always fits" invariant hold)
            raise ValueError(
                f"num_blocks={num_blocks} < max_blocks_per_slot="
                f"{max_blocks_per_slot}: the pool could not hold even one "
                f"maximal request")
        self.pool = BlockPool(num_blocks, block_size)
        self.capacity = capacity
        self.max_blocks = max_blocks_per_slot
        self._tables: List[List[int]] = [[] for _ in range(capacity)]
        self._tokens = np.zeros(capacity, dtype=np.int64)
        # slots whose prefill is still being fed chunk-by-chunk: they hold
        # blocks but do not decode, so the per-step worst-case growth
        # (seq + s) must not be charged to them — the live engine and the
        # sim mirror both skip pending slots in their pre-step growth
        self._pending: set = set()

    # ------------------------------------------------------------------
    # geometry

    @property
    def block_size(self) -> int:
        return self.pool.block_size

    @property
    def num_blocks(self) -> int:
        return self.pool.num_blocks

    @property
    def free_blocks(self) -> int:
        return self.pool.free_count

    @property
    def fragmentation(self) -> float:
        """Free-list fragmentation gauge (see BlockPool.fragmentation)."""
        return self.pool.fragmentation

    @property
    def logical_len(self) -> int:
        """Per-slot logical capacity in tokens (block table fully grown)."""
        return self.max_blocks * self.pool.block_size

    def blocks_for(self, n_tokens: int) -> int:
        return self.pool.blocks_for(n_tokens)

    # ------------------------------------------------------------------
    # per-slot accounting

    def tokens(self, slot: int) -> int:
        return int(self._tokens[slot])

    def allocated(self, slot: int) -> int:
        return len(self._tables[slot])

    def table(self, slot: int) -> List[int]:
        return list(self._tables[slot])

    def active_slots(self) -> List[int]:
        return [i for i, t in enumerate(self._tables) if t]

    # ------------------------------------------------------------------
    # chunked-prefill (pending) slots

    def mark_pending(self, slot: int) -> None:
        """Flag ``slot`` as mid-chunked-prefill (holds blocks, not decoding)."""
        self._pending.add(slot)

    def clear_pending(self, slot: int) -> None:
        self._pending.discard(slot)

    def is_pending(self, slot: int) -> bool:
        return slot in self._pending

    # ------------------------------------------------------------------
    # lifecycle

    def prefill(self, slot: int, n_tokens: int) -> List[int]:
        """Allocate the blocks covering a fresh prompt in ``slot``."""
        if self._tables[slot]:
            raise RuntimeError(f"slot {slot} already holds blocks")
        need = self.blocks_for(n_tokens)
        if need > self.max_blocks:
            raise ValueError(
                f"{n_tokens} tokens need {need} blocks > per-slot cap "
                f"{self.max_blocks}")
        blocks = self.pool.alloc(need)
        self._tables[slot] = blocks
        self._tokens[slot] = n_tokens
        return blocks

    def ensure(self, slot: int, n_tokens: int) -> List[int]:
        """Grow ``slot``'s table to cover ``n_tokens``; returns new blocks."""
        need = self.blocks_for(n_tokens) - len(self._tables[slot])
        if need <= 0:
            return []
        if len(self._tables[slot]) + need > self.max_blocks:
            raise ValueError(
                f"slot {slot} would exceed the per-slot cap of "
                f"{self.max_blocks} blocks")
        new = self.pool.alloc(need)
        self._tables[slot].extend(new)
        return new

    def commit(self, slot: int, n_new_tokens: int) -> None:
        self._tokens[slot] += int(n_new_tokens)

    def release(self, slot: int) -> List[int]:
        """Free every block of ``slot`` (retire or preempt)."""
        blocks = self._tables[slot]
        self._tables[slot] = []
        self._tokens[slot] = 0
        self._pending.discard(slot)
        self.pool.free(blocks)
        return blocks

    def device_tables(self, exclude_pending: bool = False) -> np.ndarray:
        """[capacity, max_blocks] int32 block table, -1 = unallocated.

        ``exclude_pending=True`` keeps mid-chunked-prefill slots' rows at -1:
        the decode step uploads with this set, so a parked slot's (masked,
        garbage) decode-step writes stay dropped on the device even while
        other slots' growth re-uploads the table — its blocks are only
        published by the final chunk's commit.
        """
        out = np.full((self.capacity, self.max_blocks), -1, np.int32)
        for i, t in enumerate(self._tables):
            if exclude_pending and i in self._pending:
                continue
            out[i, :len(t)] = t
        return out


class SlotPool:
    """Fixed-capacity slot bookkeeping: claim on admit, retire on finish."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._reqs: List[Optional[Request]] = [None] * capacity
        self._remaining = np.zeros(capacity, dtype=np.int64)
        # lowest-numbered free slot claimed first (deterministic placement)
        self._free = list(range(capacity - 1, -1, -1))

    # ------------------------------------------------------------------
    # lifecycle

    def claim(self, req: Request, slot: Optional[int] = None) -> int:
        """Assign ``req`` to a free slot; returns the slot index.

        Without ``slot``, the lowest-numbered free slot is claimed
        (deterministic placement).  With ``slot``, that specific free slot
        is claimed — the sharded scheduler's per-host admission queue
        (:class:`~repro.serving.scheduler.HostShardQueue`) uses this to
        round-robin placements across the data shards of a mesh-sharded
        pool.  A preempted request re-enters with ``n_generated > 0``; its
        budget resumes where it left off rather than restarting at
        ``max_new``.
        """
        if not self._free:
            raise RuntimeError("slot pool full")
        if slot is None:
            slot = self._free.pop()
        else:
            if slot not in self._free:
                raise RuntimeError(f"slot {slot} is not free")
            self._free.remove(slot)
        self._reqs[slot] = req
        self._remaining[slot] = req.max_new - req.n_generated
        return slot

    def is_free(self, slot: int) -> bool:
        return self._reqs[slot] is None

    def retire(self, slot: int) -> Request:
        """Release ``slot``; returns the request that occupied it."""
        req = self._reqs[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} is not occupied")
        self._reqs[slot] = None
        self._remaining[slot] = 0
        self._free.append(slot)
        self._free.sort(reverse=True)
        return req

    # ------------------------------------------------------------------
    # accounting

    def consume(self, slot: int, tokens: int) -> None:
        self._remaining[slot] -= tokens

    def remaining(self, slot: int) -> int:
        return int(self._remaining[slot])

    def request_at(self, slot: int) -> Request:
        req = self._reqs[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} is not occupied")
        return req

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._reqs) if r is not None]

    @property
    def occupancy(self) -> int:
        return sum(r is not None for r in self._reqs)

    @property
    def free_count(self) -> int:
        return len(self._free)
