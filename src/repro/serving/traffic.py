"""Client-side traffic generation (paper §5.3).

Inter-arrival times are sampled from a Gamma distribution parameterised by
the mean interval and the coefficient of variation (CV):

    shape k = 1 / CV**2,   scale theta = mean * CV**2

so that E[X] = k * theta = mean and std/mean = CV.  CV = 1 recovers the
exponential (Poisson arrivals); CV > 1 is burstier, CV < 1 more regular.

The alternating generator reproduces Fig. 6's experiment: the client switches
between *intense* (interval 0.2 s) and *sparse* (interval 1.0 s) traffic every
50 seconds, CV fixed at 1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Request


def gamma_intervals(n: int, mean: float, cv: float, rng: np.random.Generator,
                    ) -> np.ndarray:
    """n inter-arrival gaps with the paper's (mean, CV) parameterisation."""
    if mean <= 0:
        return np.zeros(n)
    shape = 1.0 / (cv * cv)
    scale = mean * cv * cv
    return rng.gamma(shape, scale, size=n)


@dataclass(frozen=True)
class TrafficPhase:
    mean_interval: float
    cv: float
    duration: float  # seconds this phase lasts; inf for a single-phase run


def arrival_times(n: int, phases: Sequence[TrafficPhase],
                  rng: np.random.Generator) -> np.ndarray:
    """Absolute arrival times for ``n`` requests walking through ``phases``
    cyclically (each phase lasts ``duration`` seconds of arrival time)."""
    out = np.empty(n)
    t = 0.0
    phase_idx, phase_t0 = 0, 0.0
    for i in range(n):
        ph = phases[phase_idx % len(phases)]
        gap = float(gamma_intervals(1, ph.mean_interval, ph.cv, rng)[0])
        t += gap
        while np.isfinite(ph.duration) and t - phase_t0 > ph.duration:
            phase_t0 += ph.duration
            phase_idx += 1
            ph = phases[phase_idx % len(phases)]
        out[i] = t
    return out


def synthetic_prompts(n: int, vocab: int, rng: np.random.Generator,
                      min_len: int = 8, max_len: int = 32) -> List[np.ndarray]:
    """Stand-in for the Chatbot-Instruction-Prompts sample: random-token
    prompts with the dataset's short-prompt length profile."""
    lens = rng.integers(min_len, max_len + 1, size=n)
    return [rng.integers(0, vocab, size=int(L)).astype(np.int32) for L in lens]


def make_requests(n: int, phases: Sequence[TrafficPhase], vocab: int,
                  seed: int = 0, max_new: int = 128,
                  prompts: Optional[List[np.ndarray]] = None) -> List[Request]:
    rng = np.random.default_rng(seed)
    at = arrival_times(n, phases, rng)
    if prompts is None:
        prompts = synthetic_prompts(n, vocab, rng)
    return [Request(rid=i, arrival=float(at[i]), tokens=prompts[i % len(prompts)],
                    prompt_len=len(prompts[i % len(prompts)]), max_new=max_new)
            for i in range(n)]


def uniform_traffic(n: int, mean_interval: float, cv: float, vocab: int,
                    seed: int = 0, max_new: int = 128) -> List[Request]:
    return make_requests(n, [TrafficPhase(mean_interval, cv, float("inf"))],
                         vocab, seed, max_new)


def alternating_traffic(n: int, vocab: int, seed: int = 0,
                        intense: float = 0.2, sparse: float = 1.0,
                        period: float = 50.0, cv: float = 1.0,
                        max_new: int = 128) -> List[Request]:
    """Fig. 6: alternate intense/sparse every ``period`` seconds."""
    return make_requests(
        n, [TrafficPhase(intense, cv, period), TrafficPhase(sparse, cv, period)],
        vocab, seed, max_new)
