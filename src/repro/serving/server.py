"""Server loop (paper §5.3): a message queue feeding a batched speculative
decoding engine.

Pending requests are merged into one batched request (up to ``max_batch``,
16 in the paper), the controller picks the speculation length for that batch
size, and the batch runs to completion before the next batch is formed.

Two execution backends:

  * :class:`EngineBackend` — drives a live
    :class:`~repro.core.spec_decode.SpecDecodeEngine` and uses its wall-clock
    time (the paper's setup, used by tests/examples at CPU-friendly scale);
  * :class:`SimBackend` — discrete-event simulation from a fitted
    :class:`~repro.core.analytical.LatencyModel` with stochastic acceptance,
    so the 1000-request traffic studies (Figs. 5-6) run in milliseconds and
    can be projected onto hardware we do not have.

Both backends answer ``run_batch(requests, s) -> (duration_s, BatchRecord)``;
the server's virtual clock advances by the returned duration, so the loop is
deterministic and backend-agnostic.

Iteration-level (continuous-batching) scheduling lives in
:mod:`repro.serving.scheduler`: :func:`serve_continuous` below runs that
scheduler over the simulated step backend, and
:func:`~repro.serving.scheduler.serve_continuous_live` runs the identical
scheduling code on a live engine's KV slot pool.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.adaptive import AdaptiveController
from repro.core.analytical import LatencyModel
from repro.serving.acceptance import GeometricAcceptance, match_prob
from repro.serving.request import BatchRecord, Request

# retained name: tests and notebooks import the inverse-acceptance solver
# from here; the implementation lives in serving/acceptance.py now
_match_prob = match_prob


# ---------------------------------------------------------------------------
# backends


class EngineBackend:
    """Wall-clock execution on a live SpecDecodeEngine.

    Batches are padded to the next power of two so the engine's per-(B, s)
    jit cache stays bounded (profiled sizes are powers of two anyway).
    """

    def __init__(self, engine, tparams, dparams, cache_len: int = 256):
        self.engine = engine
        self.tparams = tparams
        self.dparams = dparams
        self.cache_len = cache_len
        self._warm = set()

    @staticmethod
    def _pad_pow2(b: int) -> int:
        p = 1
        while p < b:
            p *= 2
        return p

    def run_batch(self, reqs: Sequence[Request], s: int) -> Tuple[float, BatchRecord]:
        b = len(reqs)
        B = self._pad_pow2(b)
        tp = max(max(r.prompt_len for r in reqs), 4)
        tokens = np.ones((B, tp), np.int32)
        lens = np.full((B,), 4, np.int32)
        for i, r in enumerate(reqs):
            tokens[i, :r.prompt_len] = r.tokens
            lens[i] = r.prompt_len
        max_new = max(r.max_new for r in reqs)
        # jit-warm this (B, prompt-shape, s) combination outside the timed
        # region: serving latency is steady-state (the paper profiles before
        # deployment; compile time must not contaminate scheme comparisons)
        wkey = (B, tokens.shape[1], s)
        if wkey not in self._warm:
            state = self.engine.prefill(self.tparams, self.dparams, tokens,
                                        lens, self.cache_len)
            self.engine.step(self.tparams, self.dparams, state, s)
            self._warm.add(wkey)
        t0 = time.perf_counter()
        out, stats, n_steps = self.engine.generate(
            self.tparams, self.dparams, tokens, lens, s=s,
            cache_len=self.cache_len, max_new=max_new, collect_stats=True)
        dt = time.perf_counter() - t0
        toks = b * max_new
        return dt, BatchRecord(start=0.0, duration=dt, batch_size=b, s_used=s,
                               tokens_generated=toks, n_steps=n_steps,
                               rids=tuple(r.rid for r in reqs))


class SimBackend:
    """Discrete-event simulation of batched speculative decoding.

    Per step at (b, s): duration t_L(b, s) + s * t_S(b, 1) from the latency
    model; each live request independently accepts a truncated-geometric
    number of drafts whose mean matches l(s) (the shared
    :class:`~repro.serving.acceptance.GeometricAcceptance` process), then
    commits a + 1 tokens.
    """

    def __init__(self, model: LatencyModel, seed: int = 0):
        self.model = model
        self.acceptance = GeometricAcceptance(model, seed)

    def _batch_key(self, b: int) -> int:
        """Nearest profiled batch size >= b (clamped to the largest)."""
        bs = self.model.batch_sizes
        for x in bs:
            if x >= b:
                return x
        return bs[-1]

    def run_batch(self, reqs: Sequence[Request], s: int) -> Tuple[float, BatchRecord]:
        b = len(reqs)
        bk = self._batch_key(b)
        step_t = self.model.t_verify(bk, s) + s * self.model.t_s[bk]
        remaining = np.array([r.max_new for r in reqs], dtype=np.int64)
        n_steps, toks = 0, 0
        while remaining.max() > 0:
            accepted = self.acceptance.draw(b, s)
            commit = np.minimum(accepted + 1, np.maximum(remaining, 0))
            commit = np.where(remaining > 0, commit, 0)
            toks += int(commit.sum())
            remaining -= commit
            n_steps += 1
        return n_steps * step_t, BatchRecord(
            start=0.0, duration=n_steps * step_t, batch_size=b, s_used=s,
            tokens_generated=toks, n_steps=n_steps,
            rids=tuple(r.rid for r in reqs))


# ---------------------------------------------------------------------------
# the server


@dataclass
class ServeResult:
    requests: List[Request]
    batches: List[BatchRecord]
    # iteration-level schedulers attach their per-step StepTrace list here
    trace: Optional[list] = None

    @property
    def latencies(self) -> np.ndarray:
        return np.array([r.latency for r in self.requests])

    @property
    def mean_latency(self) -> float:
        return float(self.latencies.mean())


def serve_continuous(requests: Sequence[Request], model: LatencyModel,
                     controller: AdaptiveController, max_batch: int = 16,
                     seed: int = 0, policy=None,
                     telemetry=None) -> ServeResult:
    """Iteration-level (Orca-style) continuous batching x speculation,
    simulated from a fitted latency model.

    Beyond-paper: the paper's server runs each batch to completion (§5.3);
    here requests JOIN and LEAVE the running batch at speculative-step
    granularity, and the controller re-chooses s every iteration from the
    *current* batch size — the finest-grained use of the adaptive LUT.

    This is the same :class:`~repro.serving.scheduler.ContinuousScheduler`
    that drives the live engine (serve_continuous_live), run over
    :class:`~repro.serving.scheduler.SimStepBackend` — identical admission
    logic, so sim and live scheduling are comparable step for step on one
    trace.
    """
    from repro.serving.scheduler import ContinuousScheduler, SimStepBackend
    backend = SimStepBackend(model, capacity=max_batch, seed=seed)
    sched = ContinuousScheduler(backend, controller, policy,
                                telemetry=telemetry)
    result = sched.run(requests)
    result.trace = sched.trace
    return result


def serve(requests: Sequence[Request], backend, controller: AdaptiveController,
          max_batch: int = 16) -> ServeResult:
    """Run the paper's server loop over a pre-generated request trace.

    The clock is virtual: it advances by each batch's execution duration (the
    backend decides whether that duration is wall-clock or simulated), so the
    same trace evaluates every comparison point reproducibly (§5.3:
    "we generate only one sequence of requests, which is used to evaluate all
    comparison points").
    """
    reqs = sorted(requests, key=lambda r: r.arrival)
    clock = 0.0
    i, n = 0, len(reqs)
    batches: List[BatchRecord] = []
    while i < n:
        if reqs[i].arrival > clock:
            clock = reqs[i].arrival           # idle until next arrival
        j = i
        while j < n and reqs[j].arrival <= clock and j - i < max_batch:
            j += 1
        batch = reqs[i:j]
        s = controller.choose(len(batch))
        duration, rec = backend.run_batch(batch, s)
        rec.start = clock
        for r in batch:
            r.start = clock
            r.finish = clock + duration
        clock += duration
        batches.append(rec)
        i = j
    return ServeResult(requests=list(reqs), batches=batches)
