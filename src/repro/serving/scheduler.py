"""Iteration-level (Orca-style) continuous-batching scheduler.

Requests JOIN and LEAVE the running batch at *speculative-step* granularity:
every iteration the scheduler (1) admits arrived requests into free KV slots
via a pluggable :class:`AdmissionPolicy`, (2) asks the
:class:`~repro.core.adaptive.AdaptiveController` for the speculation length
at the **live occupancy** — the finest-grained use of the paper's b -> s_opt
LUT — and (3) runs one speculative step, retiring finished slots.

Two step backends answer the same protocol, so the identical scheduling code
runs against hardware truth and against the fitted simulation:

  * :class:`ContinuousEngineBackend` — a live
    :class:`~repro.core.spec_decode.SpecDecodeEngine` slot pool
    (``prefill_into`` / masked step / ``retire_slot``), wall-clock timed
    with compiles warmed outside the timed region;
  * :class:`SimStepBackend` — one discrete-event step from a fitted
    :class:`~repro.core.analytical.LatencyModel` with the shared
    truncated-geometric acceptance process (serving/acceptance.py).

``serve_continuous_live()`` is the live entrypoint mirroring
:func:`repro.serving.server.serve_continuous` (which now runs this same
scheduler over :class:`SimStepBackend`), so Fig. 5-7 traffic studies can be
replayed on a real engine and validated against the simulation
(sim-vs-live parity on identical traces).

Paged KV + preemption: when the engine slot pool is paged (fixed-size
blocks + a free list, core/spec_decode.py design note), the scheduler also
(a) admits by block feasibility — a prompt only enters when the free list
covers it, (b) hard-rejects requests whose worst-case footprint
(prompt + max_new + the controller's speculation ceiling) exceeds the
per-request capacity (previously they silently wrapped their KV ring), and
(c) preempts under memory pressure: if covering this step's worst-case
commit (s+1 tokens per live slot) could exhaust the free list, the victim
with the longest remaining budget (ties: most recently admitted, i.e.
LIFO) is evicted back to the backlog and later re-prefilled from prompt +
its generated-token stash.  Preemptions are recorded in
:class:`StepTrace`; because they are pure functions of the block
accounting, a :class:`SimStepBackend` built with the same pool geometry
re-derives them exactly during replay.

In-step chunked prefill (Sarathi-style; SNIPPETS §2): with a
:class:`PrefillBudgetAdmit` policy, admission work is bounded by a strict
per-iteration token budget.  A prompt that fits the budget prefills whole;
a longer one is admitted *chunked* — its slot carries PREFILLING state
across iterations (``Request.prefill_pos``), each iteration feeds at most
one ``chunk`` of tokens (interleaved with the running batch's decode
steps), and the slot joins the decode batch only when its last chunk
commits.  The controller therefore keeps seeing the *decode* batch size,
admission can no longer stall every running request for a whole-prompt
burst, and chunk events are recorded in :class:`StepTrace` so the sim
backend replays them for exact sim-vs-live parity.

Sharded serving (the production mesh): ``serve_continuous_live(mesh=...)``
runs the engine slot pool sharded over the mesh's data axes (engine design
note in core/spec_decode.py).  The scheduler side is deliberately small:
the capacity axis splits into ``backend.n_shards`` contiguous slot ranges —
one per data shard, i.e. per serving host in a multi-host deployment — and
a :class:`HostShardQueue` round-robins slot claims across those ranges so
every shard carries an even share of the live batch.  Because the queue
only changes *which slot* a request lands in (never *when* it is admitted,
FCFS order is untouched) and StepTrace records request ids, the sharded
run's trace is identical to the single-device run's — the sharded parity
contract tests/test_sharded_serving.py enforces.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.adaptive import AdaptiveController
from repro.core.analytical import LatencyModel
from repro.core.spec_decode import S_MAX
from repro.kernels.tuning import grid_steps_dense, grid_steps_ragged
from repro.serving.acceptance import GeometricAcceptance
from repro.serving.request import BatchRecord, Request
from repro.serving.slots import PagedKVTables, SlotPool


# ---------------------------------------------------------------------------
# admission policies


class AdmissionPolicy:
    """Chooses which backlog requests to admit into free slots this step.

    Protocol contract (every policy must honour it):

    * ``backlog`` is the FCFS-ordered list of arrived, not-yet-admitted
      requests (a re-admitted preemption victim sits at the head).  The
      policy must treat it as read-only — the scheduler removes admitted
      requests itself.
    * ``free_slots`` is the number of currently claimable slots;
      ``clock`` is the scheduler's virtual time in seconds (policies may
      use it for deadline/aging decisions).
    * Returns the requests to admit this iteration, in admission order, a
      subset of ``backlog`` with ``len(result) <= free_slots``.  Returning
      a request not in ``backlog`` is a protocol violation.
    * The policy only *selects*; feasibility is the scheduler's job.  The
      scheduler may admit fewer than selected (KV-block feasibility,
      oversize rejection), and on a chunk-capable backend a
      :class:`PrefillBudgetAdmit` policy's budget/chunk attributes are read
      directly by the scheduler instead of :meth:`select` (see that class).
    * Policies may keep internal state across calls (e.g. deferral
      counters); the scheduler instantiates one policy per run.
    """

    def select(self, backlog: Sequence[Request], free_slots: int,
               clock: float) -> List[Request]:
        raise NotImplementedError


class ImmediateAdmit(AdmissionPolicy):
    """Admit FCFS into every free slot (Orca-style, the default)."""

    def select(self, backlog, free_slots, clock):
        return list(backlog[:free_slots])


class PrefillBudgetAdmit(AdmissionPolicy):
    """Chunked-prefill-style admission: cap the prefill tokens injected per
    iteration so admission work cannot starve the running batch (bounds the
    inter-token latency hit of each admission burst; SNIPPETS §2).

    ``chunk`` (default: the budget) is the fixed chunk size used when the
    scheduler runs a chunk-capable backend: a prompt longer than the
    remaining budget is then admitted chunked — never as a whole-prompt
    burst — and continues across iterations.  On a backend without chunk
    support, :meth:`select` falls back to whole-prompt budgeting: an
    over-budget head prompt waits (without blocking smaller backlog
    requests that still fit this step's budget) but only for at most
    ``max_defer`` iterations — after that it is admitted whole so a steady
    stream of small prompts cannot starve it forever — and when nothing
    fits at all the head is admitted whole immediately (no deadlock).
    """

    def __init__(self, token_budget: int = 64, chunk: Optional[int] = None,
                 max_defer: int = 16):
        if token_budget < 1:
            raise ValueError("token_budget must be >= 1")
        self.token_budget = token_budget
        self.chunk_tokens = token_budget if chunk is None else chunk
        if self.chunk_tokens < 1:
            raise ValueError("chunk must be >= 1")
        self.max_defer = max_defer
        self._deferred: Dict[int, int] = {}    # rid -> times passed over

    def select(self, backlog, free_slots, clock):
        out: List[Request] = []
        used = 0
        for req in backlog:
            if len(out) >= free_slots or used >= self.token_budget:
                break                  # nothing else can fit this step
            if used + req.prompt_len > self.token_budget:
                skips = self._deferred.get(req.rid, 0) + 1
                if skips > self.max_defer and not out:
                    # aging escape: a chronically deferred prompt bursts
                    # whole rather than being starved by a steady stream
                    # of smaller fits (chunk-capable backends never get
                    # here — the scheduler admits it chunked instead)
                    self._deferred.pop(req.rid, None)
                    out.append(req)
                    used += req.prompt_len
                    continue
                # over budget this step: wait — but do not block smaller
                # backlog requests that still fit (the head-of-line fix)
                self._deferred[req.rid] = skips
                continue
            out.append(req)
            used += req.prompt_len
            self._deferred.pop(req.rid, None)
        if not out and backlog and free_slots > 0:
            # nothing fits the budget at all: whole-prompt fallback so the
            # policy never deadlocks
            req = backlog[0]
            self._deferred.pop(req.rid, None)
            out.append(req)
        return out


class FCFSBacklog(AdmissionPolicy):
    """At most ``max_per_step`` admissions per iteration (rate-limited FCFS,
    the gentlest admission schedule)."""

    def __init__(self, max_per_step: int = 1):
        self.max_per_step = max_per_step

    def select(self, backlog, free_slots, clock):
        return list(backlog[:min(free_slots, self.max_per_step)])


class HostShardQueue:
    """Per-host admission queue for a mesh-sharded slot pool.

    A slot pool sharded over ``n_shards`` data shards places slot rows in
    contiguous ranges — shard ``i`` (one serving host's devices in a
    multi-host deployment) owns slots ``[i * capacity/n, (i+1) *
    capacity/n)``, exactly the layout a NamedSharding gives the capacity
    axis.  This queue claims slots ROUND-ROBIN across those ranges (lowest
    free slot within the chosen shard), so admissions spread evenly over
    the shards instead of filling shard 0 first — every host carries an
    even share of the live batch and of the per-step KV writes.

    It deliberately does NOT reorder admissions: the scheduler admits in
    the same FCFS order with or without a mesh, which is what keeps the
    sharded StepTrace identical to the single-device one (rids, commits,
    preemptions are all slot-number-free).
    """

    def __init__(self, capacity: int, n_shards: int):
        if n_shards < 1 or capacity % n_shards != 0:
            raise ValueError(
                f"capacity {capacity} does not split into {n_shards} "
                f"equal shard ranges")
        self.n_shards = n_shards
        self.per_shard = capacity // n_shards
        self._next = 0                 # round-robin cursor

    def claim(self, pool: SlotPool, req: Request) -> int:
        """Claim a slot for ``req``, round-robining across shard ranges.

        Starts at the cursor and takes the first shard with a free slot
        (lowest slot id within it), then advances the cursor past that
        shard.  Deterministic: a pure function of the pool's free set and
        the claim history.
        """
        for k in range(self.n_shards):
            sh = (self._next + k) % self.n_shards
            lo = sh * self.per_shard
            for slot in range(lo, lo + self.per_shard):
                if pool.is_free(slot):
                    self._next = (sh + 1) % self.n_shards
                    return pool.claim(req, slot=slot)
        raise RuntimeError("slot pool full")


# ---------------------------------------------------------------------------
# step backends


def controller_s_cap(controller) -> int:
    """Largest speculation length ``controller`` can ever choose.

    This — not the global S_MAX — is the right worst-case reservation unit
    for admission and KV-overflow checks: one speculative step commits at
    most ``s + 1`` tokens, so every "can this request still fit its KV
    budget" bound is of the form ``prompt + max_new + s_cap``, and a
    controller capped below S_MAX can serve requests the S_MAX bound would
    wrongly reject.

    Derivation: the max over the controller's LUT entries, raised to
    ``controller.s_max`` when an online acceptance model may rebuild LUT
    entries upward, clamped to the engine's hard S_MAX (the ``out``-buffer
    headroom).  Controllers without a LUT (e.g. ad-hoc stubs) conservatively
    get S_MAX.
    """
    try:
        cap = max(controller.lut.table.values())
    except (AttributeError, ValueError):
        return S_MAX
    if getattr(controller, "model", None) is not None:
        # online LUT refresh may rebuild entries up to controller.s_max
        cap = max(cap, getattr(controller, "s_max", S_MAX))
    return min(int(cap), S_MAX)


def _reject_oversize(req: Request, max_context: int,
                     s_cap: int = S_MAX) -> None:
    """Hard admission bound: a request whose worst-case KV footprint exceeds
    the per-request capacity can never be served — deferring it would spin
    forever, and admitting it would silently wrap the ring / overrun the
    block table and corrupt the KV (the PR-1 bug this check closes).
    ``s_cap`` is the scheduler's speculation ceiling (one step can overshoot
    ``max_new`` by at most that many tokens)."""
    if req.prompt_len + req.max_new + s_cap > max_context:
        raise ValueError(
            f"request {req.rid}: prompt_len={req.prompt_len} + "
            f"max_new={req.max_new} + s_cap={s_cap} exceeds the per-request "
            f"KV capacity {max_context}; the KV ring would wrap and corrupt "
            f"itself")


class ContinuousEngineBackend:
    """Live-engine step backend: a SpecDecodeEngine slot pool on hardware.

    Prefill compiles (per prompt bucket) and step compiles (per s) are warmed
    outside the timed regions — serving latency is steady-state, matching
    EngineBackend's treatment of compile time.

    With ``block_size`` set, the engine slot pool is the paged KV block pool
    (``self.kv`` holds its host free list / block tables) and the scheduler
    gains admission feasibility checks and preemption under memory pressure.
    A preempted request's generated tokens are stashed host-side; on
    re-admission it re-prefills from prompt + stash (recompute-style
    restore) and greedy decoding continues exactly where it left off.

    :meth:`prefill_chunk` feeds one chunk of a request's prompt through the
    engine's ``prefill_chunk_into`` (in-step chunked prefill); the slot
    stays masked out of the decode steps until its final chunk commits.

    With ``mesh`` set, the slot pool is sharded over the mesh's data axes
    (one SPMD program per step; core/spec_decode.py sharded-serving note),
    params are placed replicated on the mesh, and ``n_shards`` reports how
    many data shards the capacity axis splits into — the scheduler's
    :class:`HostShardQueue` round-robins slot claims across them.
    """

    def __init__(self, engine, tparams, dparams, capacity: int,
                 cache_len: int = 256, warm_s: Sequence[int] = (),
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 collect_outputs: bool = False,
                 s_cap: int = S_MAX,
                 mesh=None,
                 paged_fused=None,
                 prefix_cache: bool = False,
                 mixed_launch: bool = False):
        if engine.tcfg.family in ("encdec", "audio", "vlm"):
            # these families need per-request modality extras (src_embeds /
            # prefix_embeds) that the admission path does not plumb yet; see
            # ROADMAP open items
            raise NotImplementedError(
                f"continuous batching does not support family "
                f"'{engine.tcfg.family}' yet (per-request modality extras)")
        if mesh is not None:
            # replicate params across the serving mesh (data-parallel
            # serving; the engine's sharded jits consume them as such)
            import jax
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(mesh, PartitionSpec())
            tparams = jax.device_put(tparams, rep)
            if dparams is not None:
                dparams = jax.device_put(dparams, rep)
        if paged_fused is not None:
            # force the paged-attention kernel path (fused streaming kernel
            # vs gather path, kernels/paged.py) BEFORE the pool and its
            # jits exist, so every compiled step uses one path.  None
            # deliberately leaves the engine's current routing untouched
            # (an engine constructed with paged_fused=... keeps its choice;
            # call engine.set_paged_fused(None) to restore auto routing)
            engine.set_paged_fused(paged_fused)
        self.engine = engine
        self.tparams = tparams
        self.dparams = dparams
        self.capacity = capacity
        self.s_cap = s_cap
        self.mesh = mesh
        self.state = engine.init_slots(capacity, cache_len,
                                       block_size=block_size,
                                       num_blocks=num_blocks,
                                       mesh=mesh)
        self.n_shards = getattr(engine, "n_data_shards", 1)
        self.kv = self.state.paged               # None => contiguous rings
        self.cache_len = (self.kv.logical_len if self.kv is not None
                          else cache_len)
        self.collect_outputs = collect_outputs
        self.outputs: Dict[int, np.ndarray] = {}   # rid -> generated tokens
        self._stash: Dict[int, np.ndarray] = {}    # rid -> pre-preempt tokens
        self._warm_prefill: set = set()
        self._warm_chunk: set = set()
        self._warm_step: set = set()
        self._warm_attach: set = set()
        self._warm_commit_attached = False
        # cross-request prefix sharing (serving/prefix_cache.py): opt-in,
        # paged + unsharded + chunk-capable only.  `cache is None` keeps
        # every legacy code path bit-identical.
        self.cache = None
        self._locked: Dict[int, List[int]] = {}  # rid -> lock()ed blocks
        if prefix_cache:
            if self.kv is None:
                raise ValueError(
                    "prefix_cache=True needs a paged KV pool (block_size)")
            if mesh is not None:
                raise ValueError(
                    "prefix_cache is not supported on a mesh-sharded pool: "
                    "shared blocks may live on any shard (allocation is not "
                    "shard-local)")
            if not self.can_chunk:
                raise ValueError(
                    "prefix_cache needs chunked prefill support: the "
                    "uncached suffix of a hit is fed through the chunk path")
            from repro.serving.prefix_cache import PrefixCache
            self.cache = PrefixCache(self.kv.pool)
            self.kv.attach_cache(self.cache)
        # mixed verify+chunk launch: NON-final paged prefill chunks defer
        # their forward (host bookkeeping still runs at feed time, so block
        # accounting and StepTrace stay bit-identical) and ride the next
        # speculative step's ragged attention call (engine.step_with_chunk).
        # Every other pool consumer flushes the pending chunk standalone
        # first, so at most one chunk is ever in flight.
        self.mixed_launch = mixed_launch
        self._deferred = None            # Optional[DeferredChunk]
        if mixed_launch:
            if self.kv is None:
                raise ValueError(
                    "mixed_launch=True needs a paged KV pool (block_size): "
                    "the fused launch rides the ragged paged kernel")
            if mesh is not None:
                raise ValueError(
                    "mixed_launch is not supported on a mesh-sharded pool "
                    "yet (the mixed step is registered unsharded only)")
        for s in warm_s:
            self.warm_step(s)

    @property
    def max_context(self) -> int:
        """Per-request KV capacity in tokens (admission hard limit)."""
        return self.cache_len

    @property
    def can_chunk(self) -> bool:
        """Whether the engine's model pair supports chunked prefill."""
        eng = self.engine
        return (hasattr(eng.target, "prefill_chunk")
                and (eng.draft is None
                     or hasattr(eng.draft, "prefill_chunk")))

    def warm_step(self, s: int) -> None:
        if s not in self._warm_step:
            self.engine.step(self.tparams, self.dparams, self.state, s,
                             warm=True)
            self._warm_step.add(s)

    def _flush_deferred(self) -> None:
        """Dispatch the pending deferred chunk standalone, if any.

        Called at the top of every other pool consumer (prefill / chunk /
        attach / commit / step-without-mixing / preempt / retire / output
        reads): the deferred forward must land before anything else touches
        the pool or the state buffers it will consume.  Chunk rows are
        slot-private, so dispatch order relative to the *step* is free —
        this guard is about buffer lineage, not numerics.
        """
        if self._deferred is not None:
            chunk, self._deferred = self._deferred, None
            self.state = self.engine.flush_chunk(
                self.tparams, self.dparams, self.state, chunk)

    def _bucket(self, n: int) -> int:
        p = 4
        while p < n:
            p *= 2
        return min(p, self.cache_len)   # never wider than the KV capacity

    def _full_prompt(self, req: Request) -> np.ndarray:
        """Prompt plus any tokens generated before a preemption."""
        stash = self._stash.get(req.rid)
        if stash is None:
            return np.asarray(req.tokens[:req.prompt_len], np.int32)
        return np.concatenate(
            [np.asarray(req.tokens[:req.prompt_len], np.int32), stash])

    def prefill(self, req: Request, slot: int) -> float:
        """Inject ``req`` into ``slot``; returns seconds of prefill work."""
        _reject_oversize(req, self.max_context, self.s_cap)  # defense in depth
        self._flush_deferred()
        prompt = self._full_prompt(req)
        plen = len(prompt)
        P = self._bucket(plen)
        toks = np.ones((P,), np.int32)
        toks[:plen] = prompt
        if P not in self._warm_prefill:
            # compile the B=1 prefill + inject for this bucket off the clock
            self.engine.prefill_into(self.tparams, self.dparams, self.state,
                                     slot, toks, plen, self.cache_len,
                                     warm=True)
            self._warm_prefill.add(P)
        t0 = time.perf_counter()
        self.state = self.engine.prefill_into(
            self.tparams, self.dparams, self.state, slot, toks,
            plen, self.cache_len)
        np.asarray(self.state.seq_lens)  # lint: allow-host-sync(deliberate fence: prefill wall-clock timing)
        return time.perf_counter() - t0

    def prefill_chunk(self, req: Request, slot: int, start: int,
                      n: int) -> float:
        """Feed feed-positions ``[start, start + n)`` of ``req``'s prompt
        (+ pre-preemption stash) into ``slot``; returns seconds.

        The feed spans ``len(prompt) - 1`` positions (the last token is
        written by the slot's first decode step, exactly like whole-prompt
        prefill); the chunk carrying the final position also commits the
        slot into the decode batch.
        """
        if start == 0:
            _reject_oversize(req, self.max_context, self.s_cap)
        self._flush_deferred()
        prompt = self._full_prompt(req)
        total_len = len(prompt)
        feed_total = total_len - 1
        CB = self._bucket(n)
        toks = np.ones((CB,), np.int32)
        toks[:n] = prompt[start:start + n]
        final = start + n == feed_total
        if CB not in self._warm_chunk:
            # compile begin/chunk/commit for this bucket off the clock
            self.engine.prefill_chunk_into(
                self.tparams, self.dparams, self.state, slot,
                np.ones((CB,), np.int32), 0, CB, CB + 2, warm=True)
            self._warm_chunk.add(CB)
        if self.mixed_launch and not final:
            # defer the forward: host bookkeeping runs now (block accounting
            # and admission decisions are unchanged), the dispatch rides the
            # next speculative step — or a standalone flush, whichever pool
            # consumer comes first
            t0 = time.perf_counter()
            self.state, self._deferred = self.engine.prefill_chunk_into(
                self.tparams, self.dparams, self.state, slot, toks, start,
                n, total_len, defer=True)
            return time.perf_counter() - t0
        t0 = time.perf_counter()
        self.state = self.engine.prefill_chunk_into(
            self.tparams, self.dparams, self.state, slot, toks, start, n,
            total_len, last2=prompt[-2:] if final else None)
        np.asarray(self.state.seq_lens)  # lint: allow-host-sync(deliberate fence: chunk wall-clock timing)
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    # prefix-cache protocol (no-ops unless built with prefix_cache=True;
    # SimStepBackend implements the same five methods over the same host
    # accounting, which is what makes cache admissions replay sim-vs-live)

    def match_and_lock(self, req: Request) -> int:
        """Longest cached prefix of ``req``'s *prompt* (never the stash:
        generated tokens are model outputs the sim backend cannot know, so
        matching them would break sim-vs-live re-derivation).  The matched
        blocks are pinned against eviction until :meth:`attach` or
        :meth:`cancel_match`.  Returns the prefix length in tokens."""
        if self.cache is None:
            return 0
        blocks = self.cache.lock(np.asarray(req.tokens[:req.prompt_len]))
        if not blocks:
            return 0
        self._locked[req.rid] = blocks
        return len(blocks) * self.kv.block_size

    def cancel_match(self, req: Request) -> None:
        """Drop a lock taken by :meth:`match_and_lock` (admission abort)."""
        blocks = self._locked.pop(req.rid, None)
        if blocks:
            self.cache.unlock(blocks)

    def attach(self, req: Request, slot: int, n_prefix: int) -> float:
        """Map the locked prefix blocks into ``slot`` (refcount+1), park
        the slot, and run the draft-only prefix prefill; returns seconds.
        The uncached suffix is then fed via :meth:`prefill_chunk` with
        ``start = n_prefix`` (or, zero-suffix, :meth:`commit_attached`)."""
        self._flush_deferred()
        blocks = self._locked.pop(req.rid)
        prompt = self._full_prompt(req)
        total_len = len(prompt)
        self.kv.attach(slot, blocks, n_prefix)
        self.cache.unlock(blocks)      # the slot now holds its own refs
        P = self._bucket(total_len)
        toks = np.ones((P,), np.int32)
        toks[:total_len] = prompt
        if P not in self._warm_attach:
            self.engine.attach_prefix(self.dparams, self.state, slot, toks,
                                      n_prefix, total_len, warm=True)
            self._warm_attach.add(P)
        t0 = time.perf_counter()
        self.state = self.engine.attach_prefix(
            self.dparams, self.state, slot, toks, n_prefix, total_len)
        np.asarray(self.state.seq_lens)  # lint: allow-host-sync(deliberate fence: attach wall-clock timing)
        return time.perf_counter() - t0

    def commit_attached(self, req: Request, slot: int) -> float:
        """Commit a fully-cached attach into the decode batch (no prefill
        forward at all — COW of the last block if needed, then the ordinary
        chunk-commit).  Returns seconds."""
        self._flush_deferred()
        prompt = self._full_prompt(req)
        total_len = len(prompt)
        if not self._warm_commit_attached:
            self.engine.commit_attached(self.state, slot, total_len,
                                        prompt[-2:], warm=True)
            self._warm_commit_attached = True
        t0 = time.perf_counter()
        self.state = self.engine.commit_attached(self.state, slot, total_len,
                                                 prompt[-2:])
        np.asarray(self.state.seq_lens)  # lint: allow-host-sync(deliberate fence: attach-commit wall-clock timing)
        return time.perf_counter() - t0

    def cache_insert(self, req: Request, slot: int) -> None:
        """Publish ``slot``'s prompt blocks into the prefix index (called
        by the scheduler when the slot joins the decode batch).

        Only *prompt* rows strictly below the feed's final row are indexed:
        the block containing row ``total_len - 1`` is excluded, so this
        slot's own decode writes never land in an indexed block and need no
        COW.  First writer wins — prefixes already indexed keep their node.
        """
        if self.cache is None:
            return
        total_len = req.prompt_len + req.n_generated
        rows = min(req.prompt_len, total_len - 1)
        n_ins = rows // self.kv.block_size
        if n_ins:
            self.cache.insert(
                np.asarray(req.tokens[:n_ins * self.kv.block_size]),
                self.kv.table(slot)[:n_ins])

    def step(self, s: int) -> Tuple[float, np.ndarray, np.ndarray]:
        """One speculative step at live occupancy.  Returns
        (wall seconds, committed[capacity], done[capacity]).

        With a deferred chunk pending (``mixed_launch``), the step runs as
        ONE mixed verify+chunk launch — the chunk's prefix-extension rows
        ride the same ragged attention grid as the verify queries instead
        of paying a second kernel launch and weight re-stream.
        """
        self.warm_step(s)
        if self._deferred is not None:
            chunk, self._deferred = self._deferred, None
            t0 = time.perf_counter()
            self.state, st = self.engine.step_with_chunk(
                self.tparams, self.dparams, self.state, s, chunk)
            committed = np.asarray(st.committed)  # lint: allow-host-sync(step boundary: commit counts steer the scheduler)
            dt = time.perf_counter() - t0
            # lint: allow-host-sync(step boundary: done flags steer retirement)
            return dt, committed, np.asarray(self.state.done)
        t0 = time.perf_counter()
        self.state, st = self.engine.step(self.tparams, self.dparams,
                                          self.state, s)
        committed = np.asarray(st.committed)  # lint: allow-host-sync(step boundary: commit counts steer the scheduler)
        dt = time.perf_counter() - t0
        # lint: allow-host-sync(step boundary: done flags steer retirement)
        return dt, committed, np.asarray(self.state.done)

    def preempt(self, slot: int, req: Request) -> None:
        """Evict ``req`` under memory pressure: stash its generated tokens,
        free the slot's KV blocks, and mark the row done."""
        self._flush_deferred()
        dev_n = int(np.asarray(self.state.n_generated)[slot])  # lint: allow-host-sync(preempt is off the steady path; must read victim count)
        fresh = np.asarray(self.state.out)[slot, :dev_n].astype(np.int32)  # lint: allow-host-sync(victim tokens are stashed host-side)
        old = self._stash.get(req.rid)
        self._stash[req.rid] = (fresh if old is None
                                else np.concatenate([old, fresh]))
        self.state = self.engine.retire_slot(self.state, slot)

    def retire(self, slot: int, req: Optional[Request] = None) -> None:
        self._flush_deferred()
        if req is not None:
            if self.collect_outputs:
                # stitch ever-preempted requests now, before the slot (and
                # its out row) is recycled
                self.outputs[req.rid] = self.output_for(slot, req)
            # always drop the stash: keeping it for callers who opted out of
            # output collection would leak memory on long-lived backends
            self._stash.pop(req.rid, None)
        self.state = self.engine.retire_slot(self.state, slot)

    def output_for(self, slot: int, req: Optional[Request] = None) -> np.ndarray:
        """Generated tokens of the request in ``slot``.

        With ``req`` given, the result is truncated to ``req.n_generated``
        (a request with a smaller ``max_new`` than the engine's must not
        surface tokens past its budget) and stitched with any pre-preemption
        stash; without it, the legacy engine-sized row is returned.
        """
        self._flush_deferred()
        out = np.asarray(self.state.out)[slot]
        if req is None:
            return out[:self.engine.max_new]
        stash = self._stash.get(req.rid)
        if stash is None:
            return out[:req.n_generated].astype(np.int32)
        cont = out[:req.n_generated - len(stash)].astype(np.int32)
        return np.concatenate([stash, cont])


class SimStepBackend:
    """Discrete-event step backend over a fitted LatencyModel.

    Step duration at live occupancy b is t_L(bk, s) + s * t_S(bk, 1) with bk
    the nearest profiled batch size >= b; acceptance is the shared
    truncated-geometric process — or, for sim-vs-live parity tests, a
    replayed ``accept_source(step_idx, rids, s) -> accepted`` trace.
    """

    can_chunk = True

    def __init__(self, model: LatencyModel, capacity: int, seed: int = 0,
                 accept_source: Optional[Callable] = None,
                 duration_source: Optional[Callable] = None,
                 prefill_source: Optional[Callable] = None,
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 max_context: int = 256,
                 done_source: Optional[Callable] = None,
                 chunk_source: Optional[Callable] = None,
                 prefix_cache: bool = False,
                 prefill_token_cost: float = 0.0):
        self.model = model
        self.capacity = capacity
        self.acceptance = GeometricAcceptance(model, seed)
        self.accept_source = accept_source
        self.duration_source = duration_source
        self.prefill_source = prefill_source
        # default prefill cost per fed token (seconds): 0.0 keeps the legacy
        # "prefill is outside the fitted model" behavior; a positive value
        # makes TTFT sensitive to how many rows actually get prefilled —
        # which is what lets the templated-traffic bench show the prefix
        # cache's TTFT win on the sim backend
        self.prefill_token_cost = prefill_token_cost
        # replayed per-step done sets: the live engine marks a slot done on
        # its EOS step (commit > 0) one iteration before it commits 0, and
        # victim selection must see the same flag to replay identically
        self.done_source = done_source
        # replayed per-rid chunk durations (FIFO, like prefill_source)
        self.chunk_source = chunk_source
        self.done = np.ones(capacity, dtype=bool)
        self.rids = np.full(capacity, -1, dtype=np.int64)
        self._step_idx = 0
        # paged-KV mirror: same geometry as the live pool => the scheduler's
        # preemption decisions (functions of free/allocated/token counts
        # only) replay count-for-count against the live run
        if block_size is not None:
            max_blocks = -(-max_context // block_size)
            if num_blocks is None:
                num_blocks = capacity * max_blocks
            self.kv: Optional[PagedKVTables] = PagedKVTables(
                num_blocks, block_size, capacity, max_blocks)
        else:
            self.kv = None
        # the plain sim has no KV to overflow, so no admission hard limit
        self.max_context = (self.kv.logical_len if self.kv is not None
                            else None)
        # prefix cache mirror: the same PrefixCache/refcount machinery as
        # the live backend over the same pool geometry, so cache hits,
        # attach block accounting and evictions re-derive identically
        self.cache = None
        self._locked: Dict[int, List[int]] = {}
        if prefix_cache:
            if self.kv is None:
                raise ValueError(
                    "prefix_cache=True needs a paged KV mirror (block_size)")
            from repro.serving.prefix_cache import PrefixCache
            self.cache = PrefixCache(self.kv.pool)
            self.kv.attach_cache(self.cache)

    def _batch_key(self, b: int) -> int:
        for x in self.model.batch_sizes:
            if x >= b:
                return x
        return self.model.batch_sizes[-1]

    def prefill(self, req: Request, slot: int) -> float:
        self.done[slot] = False
        self.rids[slot] = req.rid
        if self.kv is not None:
            # a re-admitted (preempted) request re-prefills prompt + stash
            self.kv.prefill(slot, req.prompt_len + req.n_generated)
            self.kv.evicted_pending.clear()  # no device rows to wipe in sim
        if self.prefill_source is not None:
            return float(self.prefill_source(req.rid))
        # default: prefill outside the fitted model (0.0 per-token cost)
        return (req.prompt_len + req.n_generated) * self.prefill_token_cost

    def prefill_chunk(self, req: Request, slot: int, start: int,
                      n: int) -> float:
        """Mirror of the live chunked-prefill block accounting: tokens grow
        chunk-by-chunk, the slot stays done (out of the decode batch) until
        the final chunk, then joins with the whole-prompt end state."""
        total_len = req.prompt_len + req.n_generated
        feed_total = total_len - 1
        if start == 0:
            self.done[slot] = True
            self.rids[slot] = req.rid
            if self.kv is not None:
                self.kv.prefill(slot, n)
                self.kv.mark_pending(slot)
        elif self.kv is not None:
            self.kv.ensure(slot, start + n)
            self.kv.commit(slot, n)
        if start + n == feed_total:
            if self.kv is not None:
                # cover the row the first decode step writes (row total-1)
                self.kv.ensure(slot, total_len)
                self.kv.commit(slot, 1)
                self.kv.clear_pending(slot)
            self.done[slot] = False
        if self.kv is not None:
            self.kv.evicted_pending.clear()  # no device rows to wipe in sim
        if self.chunk_source is not None:
            return float(self.chunk_source(req.rid))
        return n * self.prefill_token_cost

    def step(self, s: int) -> Tuple[float, np.ndarray, np.ndarray]:
        active = np.where(~self.done)[0]
        b = len(active)
        bk = self._batch_key(b)
        if self.kv is not None:
            # same slot set as the live engine's pre-step growth: every slot
            # still holding blocks (incl. EOS'd rows awaiting retirement),
            # minus mid-prefill slots (they grow chunk-by-chunk instead)
            for slot in self.kv.active_slots():
                if self.kv.is_pending(slot):
                    continue
                self.kv.ensure(slot, self.kv.tokens(slot) + s)
            self.kv.evicted_pending.clear()  # no device rows to wipe in sim
        if self.duration_source is not None:
            dt = float(self.duration_source(self._step_idx, b, s))
        else:
            dt = self.model.t_verify(bk, s) + s * self.model.t_s[bk]
        if self.accept_source is not None:
            accepted = np.asarray(
                self.accept_source(self._step_idx, self.rids[active], s))
        else:
            accepted = self.acceptance.draw(b, s)
        committed = np.zeros(self.capacity, dtype=np.int64)
        # accepted = -1 encodes a replayed zero-commit step (the live engine
        # had already stopped this request: EOS / engine-level max_new);
        # mirror the live backend by marking the slot done so the scheduler
        # retires it the same iteration
        committed[active] = np.maximum(accepted + 1, 0)
        self.done[active[committed[active] == 0]] = True
        if self.done_source is not None:
            rec = {int(r) for r in self.done_source(self._step_idx)}
            for slot in active:
                if int(self.rids[slot]) in rec:
                    self.done[slot] = True
        if self.kv is not None:
            for slot in self.kv.active_slots():
                if not self.kv.is_pending(slot):
                    self.kv.commit(slot, int(committed[slot]))
        self._step_idx += 1
        return dt, committed, self.done.copy()

    def preempt(self, slot: int, req: Request) -> None:
        self.done[slot] = True
        self.rids[slot] = -1
        if self.kv is not None:
            self.kv.release(slot)

    def retire(self, slot: int, req: Optional[Request] = None) -> None:
        self.done[slot] = True
        self.rids[slot] = -1
        if self.kv is not None:
            self.kv.release(slot)

    # ------------------------------------------------------------------
    # prefix-cache protocol — same five methods as the live backend, over
    # the same PrefixCache machinery, so lock/attach/insert block
    # accounting (and therefore every admission/preemption decision)
    # re-derives identically; only device work (and its clock cost) is
    # absent.

    def match_and_lock(self, req: Request) -> int:
        """Longest cached prefix of the *prompt*, locked; returns tokens."""
        if self.cache is None:
            return 0
        blocks = self.cache.lock(req.tokens[:req.prompt_len])
        if not blocks:
            return 0
        self._locked[req.rid] = blocks
        return len(blocks) * self.kv.block_size

    def cancel_match(self, req: Request) -> None:
        """Drop the lock taken by :meth:`match_and_lock` (admission abort)."""
        blocks = self._locked.pop(req.rid, None)
        if blocks:
            self.cache.unlock(blocks)

    def attach(self, req: Request, slot: int, n_prefix: int) -> float:
        """Map the locked prefix blocks into ``slot``'s table at ref+1."""
        blocks = self._locked.pop(req.rid)
        self.done[slot] = True            # mid-admission: out of decode batch
        self.rids[slot] = req.rid
        self.kv.attach(slot, blocks, n_prefix)
        self.kv.mark_pending(slot)
        self.cache.unlock(blocks)
        return 0.0

    def commit_attached(self, req: Request, slot: int) -> float:
        """Zero-suffix admission: the whole feedable prompt was cached.

        Mirrors the live engine's commit: COW the block holding row
        total-1 if it is shared, grow to cover the first decode row, and
        join the decode batch.
        """
        total_len = req.prompt_len + req.n_generated
        self.kv.cow_for_range(slot, total_len - 1, total_len)
        self.kv.ensure(slot, total_len)
        self.kv.commit(slot, total_len - self.kv.tokens(slot))
        self.kv.clear_pending(slot)
        self.kv.evicted_pending.clear()  # no device rows to wipe in sim
        self.done[slot] = False
        if self.prefill_source is not None:
            return float(self.prefill_source(req.rid))
        return 0.0

    def cache_insert(self, req: Request, slot: int) -> None:
        """Publish ``slot``'s full prompt blocks into the prefix index."""
        if self.cache is None:
            return
        total_len = req.prompt_len + req.n_generated
        # never index the block holding row total-1: the slot's own decode
        # writes land there, and indexed blocks must stay immutable
        rows = min(req.prompt_len, total_len - 1)
        n_ins = rows // self.kv.block_size
        if n_ins:
            self.cache.insert(req.tokens[:n_ins * self.kv.block_size],
                              self.kv.table(slot)[:n_ins])


# ---------------------------------------------------------------------------
# the scheduler


@dataclass
class StepTrace:
    """Per-iteration scheduling record (drives sim-vs-live parity tests)."""
    clock: float
    occupancy: int
    s: int
    rids: Tuple[int, ...]
    committed: Dict[int, int]          # rid -> raw committed this step
    admitted: Tuple[int, ...] = ()
    duration: float = 0.0              # step duration charged to the clock
    prefill_s: Tuple[float, ...] = ()  # per-admission prefill seconds
                                       # (-1.0 => admitted via chunks)
    preempted: Tuple[int, ...] = ()    # rids evicted before this step
    done_rids: Tuple[int, ...] = ()    # rids the backend flagged done after
    chunked: Tuple[Tuple[int, int], ...] = ()  # (rid, tokens) chunk events
    chunk_s: Tuple[float, ...] = ()    # per-chunk-event seconds
    cache_hits: Tuple[Tuple[int, int], ...] = ()  # (rid, prefix tokens)
                                       # per prefix-cache-hit admission


def replay_sources(trace: Sequence[StepTrace]):
    """(accept, duration, prefill, done, chunk) replay callbacks from a
    trace.

    Feeding these into :class:`SimStepBackend` pins every *outcome* (commit
    counts, step durations, prefill and chunk costs, per-step done flags)
    to the recorded run, so a second scheduler run over the sim backend
    must reproduce the recorded admission order, chunk schedule, and
    batch-size sequence exactly — the sim-vs-live parity check.  Preemption
    decisions are NOT replayed: they are pure functions of the block-pool
    accounting plus the done flags, so a sim backend built with the live
    pool's geometry re-derives them (and the parity test checks they
    match).  Chunk *sizes* are likewise re-derived (they are pure functions
    of the admission budget) — only their durations are replayed.

    ``step_idx`` counts executed steps: iterations that only fed prefill
    chunks (no live decode row) record a trace entry but no backend step,
    so the replay indexes into the occupancy > 0 subset of the trace.

    A preempted request is admitted (and so prefilled) more than once, so
    per-rid prefill/chunk costs replay as FIFO queues of the recorded
    durations.

    Prefix-cache admissions need no extra channel: cache decisions are
    re-derived by the sim backend's own cache mirror, a zero-suffix hit
    records its attach+commit seconds as an ordinary ``prefill_s`` entry
    (consumed by the sim's ``commit_attached``), and a hit with an
    uncached suffix folds its attach seconds into the first suffix
    chunk's recorded duration.
    """
    steps = [t for t in trace if t.occupancy > 0]
    prefill: Dict[int, List[float]] = {}
    chunks: Dict[int, List[float]] = {}
    for t in trace:
        for rid, dt in zip(t.admitted, t.prefill_s):
            if dt >= 0:                # -1.0 marks a chunked admission
                prefill.setdefault(rid, []).append(dt)
        for (rid, _m), dt in zip(t.chunked, t.chunk_s):
            chunks.setdefault(rid, []).append(dt)

    def accept(step_idx, rids, s):
        # committed - 1; a recorded 0 maps to -1 (zero-commit step: the
        # recorded run had retired this request via EOS / engine max_new)
        rec = steps[step_idx].committed
        return np.array([rec.get(int(r), 1) - 1 for r in rids])

    def duration(step_idx, b, s):
        return steps[step_idx].duration

    def prefill_src(rid):
        q = prefill.get(rid)
        return q.pop(0) if q else 0.0

    def done_src(step_idx):
        return steps[step_idx].done_rids

    def chunk_src(rid):
        q = chunks.get(rid)
        return q.pop(0) if q else 0.0

    return accept, duration, prefill_src, done_src, chunk_src


class ContinuousScheduler:
    """Iteration-level serving loop over any step backend.

    After :meth:`run`, ``self.trace`` holds one :class:`StepTrace` per
    iteration (admission order, live batch size, per-request commits,
    chunked-prefill events) — the observable scheduling behaviour compared
    in parity tests.
    """

    def __init__(self, backend, controller: AdaptiveController,
                 policy: Optional[AdmissionPolicy] = None,
                 observe: bool = False,
                 telemetry=None):
        self.backend = backend
        self.controller = controller
        self.policy = policy or ImmediateAdmit()
        self.observe = observe
        self.telemetry = telemetry
        # zero-overhead-when-off: every hook in run() fires through _tel,
        # which is None unless an *enabled* hub was supplied — a disabled
        # (or absent) hub leaves the hot path with no telemetry branches,
        # no perf_counter calls, and no event construction
        self._tel = (telemetry if telemetry is not None
                     and getattr(telemetry, "enabled", True) else None)
        if (self._tel is not None
                and getattr(self._tel, "expected_acceptance", None) is None
                and getattr(controller, "model", None) is not None):
            # the controller carries the analytical model: wire the
            # acceptance observatory's drift baseline automatically
            model = controller.model
            self._tel.attach_expected_acceptance(
                lambda s: model.l_of_s(s) / s)
        self.trace: List[StepTrace] = []
        # the controller's speculation ceiling, not the global S_MAX, is the
        # worst-case reservation unit for admission/overflow checks
        self.s_cap = controller_s_cap(controller)
        if hasattr(backend, "s_cap"):
            backend.s_cap = self.s_cap

    @staticmethod
    def _select_victim(slots: Sequence[int], pool: SlotPool,
                       admit_seq: Dict[int, int]) -> int:
        """Preemption victim: longest remaining token budget, ties broken
        LIFO by admission order (the most recently admitted goes first)."""
        return max(slots, key=lambda sl: (pool.remaining(sl),
                                          admit_seq[pool.request_at(sl).rid]))

    def run(self, requests: Sequence[Request]):
        from repro.serving.server import ServeResult   # avoid import cycle
        pending = sorted(requests, key=lambda r: r.arrival)
        pool = SlotPool(self.backend.capacity)
        # sharded pool: round-robin slot placement across the data shards
        # (placement only — admission order and the trace are unaffected)
        n_shards = getattr(self.backend, "n_shards", 1)
        shardq = (HostShardQueue(self.backend.capacity, n_shards)
                  if n_shards > 1 else None)
        backlog: List[Request] = []
        batches: List[BatchRecord] = []
        self.trace = []
        kv = getattr(self.backend, "kv", None)
        # prefix cache: both stock backends expose .cache (None unless built
        # with prefix_cache=True); foreign backends without the attribute
        # simply never enter the cache paths
        cache_on = getattr(self.backend, "cache", None) is not None
        max_ctx = getattr(self.backend, "max_context", None)
        s_cap = self.s_cap
        chunk_cfg = getattr(self.policy, "chunk_tokens", None)
        budget_cfg = getattr(self.policy, "token_budget", None)
        chunking = (chunk_cfg is not None
                    and getattr(self.backend, "can_chunk", False))
        prefilling: Dict[int, Request] = {}   # slot -> mid-chunked-prefill
        admit_seq: Dict[int, int] = {}
        n_admits = 0
        prev_done: set = set()         # rids the backend flagged done last step

        def decode_slots() -> List[int]:
            return [sl for sl in pool.active_slots() if sl not in prefilling]

        def growth_reserve(s: int) -> int:
            """Blocks the running decode batch may claim this step."""
            return sum(
                max(0, kv.blocks_for(kv.tokens(sl) + s) - kv.allocated(sl))
                for sl in decode_slots())

        def pending_reserve(exclude: Optional[int] = None) -> int:
            """Blocks the mid-prefill slots still need to complete.  Keeping
            ``free >= this`` at all times is what guarantees every admitted
            chunked prefill can finish (no admit-then-starve)."""
            tot = 0
            for sl, rq in prefilling.items():
                if sl == exclude:
                    continue
                tot += max(0, kv.blocks_for(rq.prompt_len + rq.n_generated)
                           - kv.allocated(sl))
            return tot

        tel = self._tel
        clock, i, n_done, n = 0.0, 0, 0, len(pending)
        while n_done < n:
            while i < n and pending[i].arrival <= clock:
                backlog.append(pending[i])
                i += 1
            admitted: List[int] = []
            prefill_s: List[float] = []
            chunked: List[Tuple[int, int]] = []
            chunk_s: List[float] = []
            cache_hits: List[Tuple[int, int]] = []
            budget_left = (budget_cfg if (chunking and budget_cfg is not None)
                           else float("inf"))

            def feed_chunk(req: Request, slot: int, m: int,
                           extra: float = 0.0) -> None:
                # ``extra`` folds a cache-attach's seconds into the first
                # suffix chunk's recorded duration, so replay_sources needs
                # no extra replay channel for attach costs
                nonlocal clock
                start = req.prefill_pos
                dt = self.backend.prefill_chunk(req, slot, start, m) + extra
                clock += dt
                chunked.append((req.rid, m))
                chunk_s.append(dt)
                req.prefill_pos += m
                if (cache_on and req.prefill_pos
                        == req.prompt_len + req.n_generated - 1):
                    # final chunk: the slot joins the decode batch — publish
                    # its prompt blocks into the prefix index
                    self.backend.cache_insert(req, slot)
                if tel is not None:
                    tel.span("chunk_continue", len(self.trace), dt,
                             rid=req.rid, slot=slot, start=start, n=m)

            def claim_for(req: Request) -> int:
                """Shared admission bookkeeping (both admission modes)."""
                nonlocal n_admits
                backlog.remove(req)
                slot = (shardq.claim(pool, req) if shardq is not None
                        else pool.claim(req))
                if req.start is None:  # keep the first admission's start
                    req.start = clock
                n_admits += 1
                admit_seq[req.rid] = n_admits
                admitted.append(req.rid)
                return slot

            def attach_admit(req: Request, slot: int, P: int,
                             suffix_chunk: int) -> None:
                """Admission via a cached prefix: map the matched blocks
                into the slot, then either commit straight into the decode
                batch (zero uncached suffix) or feed the first
                ``suffix_chunk`` uncached feed-positions."""
                nonlocal clock
                total_len = req.prompt_len + req.n_generated
                feed_total = total_len - 1
                cache_hits.append((req.rid, P))
                a_dt = self.backend.attach(req, slot, P)
                req.prefill_pos = P
                if P >= feed_total:
                    c_dt = self.backend.commit_attached(req, slot)
                    clock += a_dt + c_dt
                    prefill_s.append(a_dt + c_dt)
                    self.backend.cache_insert(req, slot)
                    if tel is not None:
                        tel.span("prefill", len(self.trace), a_dt + c_dt,
                                 rid=req.rid, slot=slot,
                                 tokens=total_len - P, cached=P)
                else:
                    prefill_s.append(-1.0)
                    feed_chunk(req, slot, suffix_chunk, extra=a_dt)

            # ---- continue in-flight chunked prefills (Sarathi: ongoing
            # prefills spend the budget before new admissions) ----
            if chunking and prefilling:
                for slot in sorted(prefilling,
                                   key=lambda sl: admit_seq[
                                       prefilling[sl].rid]):
                    if budget_left <= 0:
                        break
                    req = prefilling[slot]
                    feed_total = req.prompt_len + req.n_generated - 1
                    start = req.prefill_pos
                    m = int(min(chunk_cfg, feed_total - start, budget_left))
                    if kv is not None:
                        # blocks actually available to this chunk right now
                        # (free + reclaimable cache-only blocks: the pool
                        # evicts on demand when the free list runs short)
                        avail = (kv.available_blocks - growth_reserve(s_cap)
                                 - pending_reserve(exclude=slot))
                        cap_rows = ((kv.allocated(slot) + avail)
                                    * kv.block_size - start)
                        if cap_rows < feed_total - start + 1:
                            # full completion (incl. the +1 commit row) does
                            # not fit yet: feed what fits, short of the
                            # final position
                            m = min(m, max(cap_rows, 0),
                                    feed_total - start - 1)
                    if m <= 0:
                        continue       # blocked on blocks; retry next step
                    feed_chunk(req, slot, m)
                    budget_left -= m
                    if req.prefill_pos == feed_total:
                        del prefilling[slot]
            # ---- admissions ----
            if chunking:
                # budgeted admission supersedes policy.select(): its
                # whole-prompt budget semantics (skip over-budget heads)
                # exist precisely because chunk-incapable backends cannot
                # split a prompt — here an over-budget prompt is admitted
                # chunked instead, in the same FCFS order select() uses
                for req in list(backlog):
                    if pool.free_count == 0 or budget_left <= 0:
                        break
                    if max_ctx is not None:
                        _reject_oversize(req, max_ctx, s_cap)
                    total_len = req.prompt_len + req.n_generated
                    # longest cached prefix of the prompt, pinned against
                    # eviction until attach (or the break below)
                    P = (self.backend.match_and_lock(req) if cache_on
                         else 0)
                    if kv is not None:
                        # reserve the full prompt + first-step worst case up
                        # front (plus the running batch's growth and the
                        # other pending prefills' completion) — a chunked
                        # admission that could not finish would hold blocks
                        # forever.  A cache hit attaches P // block_size
                        # blocks for free (+1 only for the COW copy when the
                        # whole prompt is cached); reclaimable cache-only
                        # blocks count as available (eviction on demand).
                        need = (kv.blocks_for(total_len + s_cap)
                                - P // kv.block_size
                                + (1 if P == total_len else 0))
                        if (need + growth_reserve(s_cap) + pending_reserve()
                                > kv.available_blocks):
                            if P:
                                self.backend.cancel_match(req)
                            break      # head-of-line: wait for free blocks
                    slot = claim_for(req)
                    req.prefill_pos = 0
                    if P:
                        feed_total = total_len - 1
                        if P >= feed_total:
                            attach_admit(req, slot, P, 0)
                        else:
                            m = int(min(chunk_cfg, budget_left,
                                        feed_total - P))
                            attach_admit(req, slot, P, m)
                            budget_left -= m
                            if req.prefill_pos < feed_total:
                                prefilling[slot] = req
                    elif total_len <= budget_left:
                        p_dt = self.backend.prefill(req, slot)
                        clock += p_dt
                        prefill_s.append(p_dt)
                        budget_left -= total_len
                        if cache_on:
                            self.backend.cache_insert(req, slot)
                        if tel is not None:
                            tel.span("prefill", len(self.trace), p_dt,
                                     rid=req.rid, slot=slot,
                                     tokens=total_len)
                    else:
                        # over the remaining budget: admit CHUNKED — never a
                        # whole-prompt burst (bounds this iteration's stall)
                        prefill_s.append(-1.0)
                        feed_total = total_len - 1
                        m = int(min(chunk_cfg, budget_left, feed_total))
                        feed_chunk(req, slot, m)
                        budget_left -= m
                        if req.prefill_pos < feed_total:
                            prefilling[slot] = req
            else:
                for req in self.policy.select(backlog, pool.free_count,
                                              clock):
                    if max_ctx is not None:
                        # oversized requests can NEVER be served (deferring
                        # would spin forever); fail loudly before claiming
                        _reject_oversize(req, max_ctx, s_cap)
                    total_len = req.prompt_len + req.n_generated
                    P = (self.backend.match_and_lock(req) if cache_on
                         else 0)
                    if kv is not None:
                        # admit only if the free list covers the prompt
                        # (plus stash), this request's worst-case first
                        # step, AND the running batch's own worst-case
                        # growth — otherwise a fresh admit pays a full B=1
                        # prefill just to be evicted by the pressure check
                        # below (prefill thrash).  Cache hits and
                        # reclaimable blocks discount as in the chunked
                        # branch above.
                        need = (kv.blocks_for(total_len + s_cap)
                                - P // kv.block_size
                                + (1 if P == total_len else 0))
                        if need + growth_reserve(s_cap) > kv.available_blocks:
                            if P:
                                self.backend.cancel_match(req)
                            break      # head-of-line: wait for free blocks
                    slot = claim_for(req)
                    if P:
                        # no per-iteration budget here: any uncached suffix
                        # is fed as one chunk (cache_on implies can_chunk)
                        req.prefill_pos = 0
                        attach_admit(req, slot, P, total_len - 1 - P)
                        continue
                    p_dt = self.backend.prefill(req, slot)
                    clock += p_dt
                    prefill_s.append(p_dt)
                    if cache_on:
                        self.backend.cache_insert(req, slot)
                    if tel is not None:
                        tel.span("prefill", len(self.trace), p_dt,
                                 rid=req.rid, slot=slot,
                                 tokens=total_len)
            if tel is not None and admitted:
                tel.span("admit", len(self.trace),
                         sum(dt for dt in prefill_s if dt > 0),
                         rids=tuple(admitted),
                         n_chunked=sum(1 for dt in prefill_s if dt < 0))
            if pool.occupancy == 0:
                if not backlog and i < n:
                    clock = max(clock, pending[i].arrival)
                continue
            # ---- preemption under memory pressure (paged pool only) ----
            # worst case this step commits s+1 tokens per decode slot, i.e.
            # KV writes up to seq_len + s rows; if covering that (plus the
            # pending prefills' completion) could exhaust the free list,
            # evict victims back to the backlog (they re-prefill from
            # prompt + generated stash later).  A lone slot always fits:
            # admission bounds every request to the pool.
            preempted: List[int] = []
            if kv is not None:
                while pool.occupancy > 1:
                    ds = decode_slots()
                    s = self.controller.choose(len(ds))
                    need = (growth_reserve(s) + pending_reserve())
                    if need <= kv.available_blocks:
                        break
                    # never evict a slot the backend already flagged done
                    # (EOS'd, awaiting its zero-commit retirement step):
                    # re-prefilling it would resurrect a finished request
                    # and generate past its EOS.  Mid-prefill slots are not
                    # eligible either: their completion is what the
                    # reservation protects.
                    eligible = [sl for sl in ds
                                if pool.request_at(sl).rid not in prev_done]
                    if not eligible:
                        break          # done slots free their blocks shortly
                    victim = self._select_victim(eligible, pool, admit_seq)
                    req = pool.retire(victim)
                    self.backend.preempt(victim, req)
                    req.prefill_pos = 0
                    backlog.insert(0, req)
                    preempted.append(req.rid)
                    if tel is not None:
                        tel.span("preempt", len(self.trace), 0.0,
                                 rid=req.rid, slot=victim,
                                 n_generated=req.n_generated)
            ds = decode_slots()
            b = len(ds)
            if b > 0:
                s = self.controller.choose(b)
                dt, committed, backend_done = self.backend.step(s)
                done_rids = tuple(sorted(
                    pool.request_at(sl).rid for sl in ds
                    if backend_done[sl]))
                clock += dt
                if tel is not None:
                    tel.span("decode_verify", len(self.trace), dt,
                             s=s, batch=b)
                    t_commit0 = time.perf_counter()
                n_done0 = n_done
                toks = 0
                raw: Dict[int, int] = {}
                accepted_live: List[int] = []
                for slot in ds:
                    req = pool.request_at(slot)
                    c_raw = int(committed[slot])
                    raw[req.rid] = c_raw
                    accepted_live.append(max(c_raw - 1, 0))
                    c = min(c_raw, pool.remaining(slot))
                    if c > 0 and req.first_token is None:
                        req.first_token = clock
                    pool.consume(slot, c)
                    req.n_generated += c
                    toks += c
                    # finished: served its token budget, or the backend
                    # stopped committing for it (EOS / engine-level max_new)
                    if pool.remaining(slot) <= 0 or (c_raw == 0
                                                     and backend_done[slot]):
                        req.finish = clock
                        pool.retire(slot)
                        self.backend.retire(slot, req)
                        n_done += 1
                        if tel is not None:
                            tel.span("retire", len(self.trace), 0.0,
                                     rid=req.rid, slot=slot,
                                     n_generated=req.n_generated)
                if tel is not None:
                    tel.span("commit", len(self.trace),
                             time.perf_counter() - t_commit0,
                             tokens=toks, batch=b, retired=n_done - n_done0)
                    tel.observe_step(s=s, batch=b, accepted=accepted_live,
                                     duration=dt)
                if self.observe and s > 0:
                    # lint: allow-host-sync(accepted_live is already a host list; no device transfer)
                    self.controller.observe(np.asarray(accepted_live), s)
                batches.append(BatchRecord(
                    start=clock - dt, duration=dt, batch_size=b, s_used=s,
                    tokens_generated=toks, n_steps=1,
                    rids=tuple(sorted(raw))))
            else:
                # no live decode row this iteration (all occupied slots are
                # mid-chunked-prefill): the clock advanced by chunk work only
                if not chunked and not admitted and not preempted:
                    raise RuntimeError(
                        "scheduler stalled: occupied slots but no decode "
                        "step, chunk, admission, or preemption this "
                        "iteration (block accounting out of sync?)")
                s, dt, raw, done_rids = 0, 0.0, {}, ()
            self.trace.append(StepTrace(
                clock=clock - dt, occupancy=b, s=s,
                rids=tuple(sorted(raw)), committed=raw,
                admitted=tuple(admitted), duration=dt,
                prefill_s=tuple(prefill_s), preempted=tuple(preempted),
                done_rids=done_rids, chunked=tuple(chunked),
                chunk_s=tuple(chunk_s), cache_hits=tuple(cache_hits)))
            prev_done = set(done_rids)
            if tel is not None:
                g = dict(occupancy=pool.occupancy, decode_batch=b, s=s,
                         prefilling=len(prefilling), backlog=len(backlog),
                         free_slots=pool.free_count,
                         capacity=self.backend.capacity)
                if kv is not None:
                    # ragged-grid occupancy: the share of the dense
                    # B*MAXB attention grid the ragged kernel actually
                    # launches this iteration (read-only over the host
                    # block tables; kernels/tuning.py owns the arithmetic
                    # so the gauge can never drift from the real grid)
                    tabs = kv.device_tables(exclude_pending=True)
                    g.update(free_blocks=kv.free_blocks,
                             used_blocks=kv.num_blocks - kv.free_blocks,
                             fragmentation=kv.fragmentation,
                             grid_occupancy=(grid_steps_ragged(tabs)
                                             / float(grid_steps_dense(tabs))))
                if cache_on:
                    cache = self.backend.cache
                    g.update(shared_blocks=kv.shared_blocks,
                             cached_blocks=kv.cached_blocks,
                             evicted_blocks=kv.evicted_total,
                             cache_hit_rate=(cache.hits
                                             / max(cache.lookups, 1)),
                             cache_hit_tokens=cache.hit_tokens)
                tel.iteration(len(self.trace) - 1, clock, **g)
        return ServeResult(requests=list(pending), batches=batches)


def serve_continuous_live(requests: Sequence[Request], engine, tparams,
                          dparams, controller: AdaptiveController, *,
                          capacity: int = 8, cache_len: int = 256,
                          policy: Optional[AdmissionPolicy] = None,
                          observe: bool = False,
                          backend: Optional[ContinuousEngineBackend] = None,
                          block_size: Optional[int] = None,
                          num_blocks: Optional[int] = None,
                          mesh=None,
                          paged_fused=None,
                          prefix_cache: bool = False,
                          mixed_launch: bool = False,
                          telemetry=None):
    """Serve a request trace on a LIVE SpecDecodeEngine with iteration-level
    continuous batching: requests join/leave at speculative-step granularity
    and the controller re-chooses s from live occupancy every step.

    The virtual clock advances by measured wall time (compiles warmed
    outside the timed regions), so results are directly comparable with the
    run-to-completion :func:`repro.serving.server.serve` loop and with the
    :class:`SimStepBackend` simulation on the same trace.

    ``block_size`` switches the KV slot pool to the paged block allocator
    (``num_blocks`` sizes it; default worst-case) with preemption under
    memory pressure.  Admission hard-rejects any request whose worst-case
    KV footprint (``prompt_len + max_new`` + the controller's speculation
    ceiling) exceeds the per-request capacity — previously such a request
    silently wrapped its KV ring and corrupted itself.

    A :class:`PrefillBudgetAdmit` policy additionally enables in-step
    chunked prefill: prompts longer than the per-iteration token budget are
    admitted chunk-by-chunk, interleaved with the running batch's decode
    steps.

    ``paged_fused`` forces the paged-attention kernel path for a
    ``block_size`` run: ``True`` streams KV through the block tables with
    the fused Pallas kernel (interpret mode off-TPU), ``False`` keeps the
    materialized gather path, ``None`` (default) leaves the engine's
    current routing untouched — auto (fused on TPU) unless the engine was
    constructed with, or previously forced to, an explicit path.  Token
    outputs and the StepTrace are identical either way
    (tests/test_paged_fused_kernel.py asserts it).

    ``mixed_launch`` (requires ``block_size``) fuses each NON-final prefill
    chunk into the next speculative step as ONE mixed verify+chunk launch
    over the ragged paged kernel: the chunk's prefix-extension queries ride
    the same real-length grid as the batch's verify queries, retiring the
    separate chunk dispatch (and its weight re-stream).  Host block
    accounting still runs at feed time, so admissions, preemptions, token
    outputs and the StepTrace scheduling signature are identical with the
    flag on or off (tests/test_ragged_paged_attn.py asserts it).

    ``prefix_cache`` (requires ``block_size``) turns on cross-request
    prefix sharing: admission matches the longest cached prefix of each
    prompt in a radix index over the block pool, maps those blocks into
    the new slot at refcount+1 and prefills only the uncached suffix;
    shared blocks are copy-on-write and eviction is LRU over cache-only
    blocks.  Token outputs and the StepTrace scheduling signature are
    identical to a cold run (tests/test_prefix_cache.py asserts it).

    ``mesh`` runs the slot pool sharded over the mesh's data axes (SPMD
    serving step, replicated params, round-robin slot placement across the
    data shards via :class:`HostShardQueue`) — token outputs and the
    StepTrace are identical to the single-device run on the same trace.
    On CPU, force multiple host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before importing
    jax to try this without accelerators.

    ``telemetry`` attaches a :class:`repro.serving.telemetry.Telemetry` hub:
    phase spans, the (s, batch) acceptance observatory, and pool/scheduler
    gauges, plus — when the hub was built with ``annotate_device`` or
    ``profile_dir`` — per-phase ``jax.profiler.TraceAnnotation`` scopes on
    the engine's jit dispatches (and a profiler trace around the run).
    Telemetry only *reads* the pipeline: token outputs and the StepTrace
    are identical with it on or off, and a disabled hub costs nothing.
    """
    for r in requests:
        if r.max_new > engine.max_new:
            raise ValueError(
                f"request {r.rid} wants {r.max_new} tokens but the engine "
                f"slot pool is sized for max_new={engine.max_new}")
    s_cap = controller_s_cap(controller)
    if (backend is not None and mesh is not None
            and getattr(backend, "mesh", None) is not mesh):
        # an explicit backend owns its pool placement; silently dropping
        # mesh here would let a caller believe a sharded run happened
        raise ValueError(
            "serve_continuous_live: `mesh` conflicts with the explicit "
            "`backend` (which was built with a different mesh, or none); "
            "construct the backend with mesh=... or omit one of the two")
    if backend is not None and paged_fused is not None:
        # the backend compiled its pool with a kernel path already; silently
        # dropping the flag would let a caller believe it took effect
        raise ValueError(
            "serve_continuous_live: pass paged_fused to the "
            "ContinuousEngineBackend constructor when supplying an explicit "
            "backend (the kernel path is baked in at pool init)")
    if backend is not None and mixed_launch:
        # the defer/flush bookkeeping lives on the backend; silently
        # dropping the flag would let a caller believe fusion was on
        raise ValueError(
            "serve_continuous_live: pass mixed_launch=True to the "
            "ContinuousEngineBackend constructor when supplying an explicit "
            "backend (the deferred-chunk bookkeeping lives on it)")
    if backend is not None and prefix_cache:
        # the cache wraps the backend's pool at construction time; silently
        # dropping the flag would let a caller believe sharing was on
        raise ValueError(
            "serve_continuous_live: pass prefix_cache=True to the "
            "ContinuousEngineBackend constructor when supplying an explicit "
            "backend (the cache wraps the pool at init)")
    if backend is None:
        warm = sorted(set(controller.lut.table.values()))
        backend = ContinuousEngineBackend(engine, tparams, dparams,
                                          capacity=capacity,
                                          cache_len=cache_len, warm_s=warm,
                                          block_size=block_size,
                                          num_blocks=num_blocks,
                                          s_cap=s_cap, mesh=mesh,
                                          paged_fused=paged_fused,
                                          prefix_cache=prefix_cache,
                                          mixed_launch=mixed_launch)
    for r in requests:
        if r.prompt_len + r.max_new + s_cap > backend.max_context:
            raise ValueError(
                f"request {r.rid}: prompt_len={r.prompt_len} + "
                f"max_new={r.max_new} + s_cap={s_cap} exceeds the "
                f"per-request KV capacity {backend.max_context}; the KV "
                f"ring would wrap and corrupt itself")
    sched = ContinuousScheduler(backend, controller, policy, observe=observe,
                                telemetry=telemetry)
    tel = sched._tel
    prev_annotate = getattr(engine, "annotate", False)
    if tel is not None and tel.annotate_device:
        engine.annotate = True
    if tel is not None:
        tel.start()
    try:
        result = sched.run(requests)
    finally:
        if tel is not None:
            tel.stop()
        engine.annotate = prev_annotate
    result.trace = sched.trace
    return result
