"""Iteration-level (Orca-style) continuous-batching scheduler.

Requests JOIN and LEAVE the running batch at *speculative-step* granularity:
every iteration the scheduler (1) admits arrived requests into free KV slots
via a pluggable :class:`AdmissionPolicy`, (2) asks the
:class:`~repro.core.adaptive.AdaptiveController` for the speculation length
at the **live occupancy** — the finest-grained use of the paper's b -> s_opt
LUT — and (3) runs one speculative step, retiring finished slots.

Two step backends answer the same protocol, so the identical scheduling code
runs against hardware truth and against the fitted simulation:

  * :class:`ContinuousEngineBackend` — a live
    :class:`~repro.core.spec_decode.SpecDecodeEngine` slot pool
    (``prefill_into`` / masked step / ``retire_slot``), wall-clock timed
    with compiles warmed outside the timed region;
  * :class:`SimStepBackend` — one discrete-event step from a fitted
    :class:`~repro.core.analytical.LatencyModel` with the shared
    truncated-geometric acceptance process (serving/acceptance.py).

``serve_continuous_live()`` is the live entrypoint mirroring
:func:`repro.serving.server.serve_continuous` (which now runs this same
scheduler over :class:`SimStepBackend`), so Fig. 5-7 traffic studies can be
replayed on a real engine and validated against the simulation
(sim-vs-live parity on identical traces).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.adaptive import AdaptiveController
from repro.core.analytical import LatencyModel
from repro.serving.acceptance import GeometricAcceptance
from repro.serving.request import BatchRecord, Request
from repro.serving.slots import SlotPool


# ---------------------------------------------------------------------------
# admission policies


class AdmissionPolicy:
    """Chooses which backlog requests to admit into free slots this step."""

    def select(self, backlog: Sequence[Request], free_slots: int,
               clock: float) -> List[Request]:
        raise NotImplementedError


class ImmediateAdmit(AdmissionPolicy):
    """Admit FCFS into every free slot (Orca-style, the default)."""

    def select(self, backlog, free_slots, clock):
        return list(backlog[:free_slots])


class PrefillBudgetAdmit(AdmissionPolicy):
    """Chunked-prefill-style admission: cap the prefill tokens injected per
    iteration so admission work cannot starve the running batch (bounds the
    inter-token latency hit of each admission burst; SNIPPETS §2).

    Always admits at least one request when a slot is free, so the policy
    never deadlocks on a prompt longer than the budget.
    """

    def __init__(self, token_budget: int = 64):
        self.token_budget = token_budget

    def select(self, backlog, free_slots, clock):
        out: List[Request] = []
        used = 0
        for req in backlog[:free_slots]:
            if out and used + req.prompt_len > self.token_budget:
                break
            out.append(req)
            used += req.prompt_len
        return out


class FCFSBacklog(AdmissionPolicy):
    """At most ``max_per_step`` admissions per iteration (rate-limited FCFS,
    the gentlest admission schedule)."""

    def __init__(self, max_per_step: int = 1):
        self.max_per_step = max_per_step

    def select(self, backlog, free_slots, clock):
        return list(backlog[:min(free_slots, self.max_per_step)])


# ---------------------------------------------------------------------------
# step backends


class ContinuousEngineBackend:
    """Live-engine step backend: a SpecDecodeEngine slot pool on hardware.

    Prefill compiles (per prompt bucket) and step compiles (per s) are warmed
    outside the timed regions — serving latency is steady-state, matching
    EngineBackend's treatment of compile time.
    """

    def __init__(self, engine, tparams, dparams, capacity: int,
                 cache_len: int = 256, warm_s: Sequence[int] = ()):
        if engine.tcfg.family in ("encdec", "audio", "vlm"):
            # these families need per-request modality extras (src_embeds /
            # prefix_embeds) that the admission path does not plumb yet; see
            # ROADMAP open items
            raise NotImplementedError(
                f"continuous batching does not support family "
                f"'{engine.tcfg.family}' yet (per-request modality extras)")
        self.engine = engine
        self.tparams = tparams
        self.dparams = dparams
        self.capacity = capacity
        self.cache_len = cache_len
        self.state = engine.init_slots(capacity, cache_len)
        self._warm_prefill: set = set()
        self._warm_step: set = set()
        for s in warm_s:
            self.warm_step(s)

    def warm_step(self, s: int) -> None:
        if s not in self._warm_step:
            self.engine.step(self.tparams, self.dparams, self.state, s)
            self._warm_step.add(s)

    @staticmethod
    def _bucket(n: int) -> int:
        p = 4
        while p < n:
            p *= 2
        return p

    def prefill(self, req: Request, slot: int) -> float:
        """Inject ``req`` into ``slot``; returns seconds of prefill work."""
        P = self._bucket(req.prompt_len)
        toks = np.ones((P,), np.int32)
        toks[:req.prompt_len] = req.tokens[:req.prompt_len]
        if P not in self._warm_prefill:
            # compile the B=1 prefill + inject for this bucket off the clock
            self.engine.prefill_into(self.tparams, self.dparams, self.state,
                                     slot, toks, req.prompt_len, self.cache_len)
            self._warm_prefill.add(P)
        t0 = time.perf_counter()
        self.state = self.engine.prefill_into(
            self.tparams, self.dparams, self.state, slot, toks,
            req.prompt_len, self.cache_len)
        np.asarray(self.state.seq_lens)          # block until ready
        return time.perf_counter() - t0

    def step(self, s: int) -> Tuple[float, np.ndarray, np.ndarray]:
        """One speculative step at live occupancy.  Returns
        (wall seconds, committed[capacity], done[capacity])."""
        self.warm_step(s)
        t0 = time.perf_counter()
        self.state, st = self.engine.step(self.tparams, self.dparams,
                                          self.state, s)
        committed = np.asarray(st.committed)     # forces sync
        dt = time.perf_counter() - t0
        return dt, committed, np.asarray(self.state.done)

    def retire(self, slot: int) -> None:
        self.state = self.engine.retire_slot(self.state, slot)

    def output_for(self, slot: int) -> np.ndarray:
        return np.asarray(self.state.out)[slot, :self.engine.max_new]


class SimStepBackend:
    """Discrete-event step backend over a fitted LatencyModel.

    Step duration at live occupancy b is t_L(bk, s) + s * t_S(bk, 1) with bk
    the nearest profiled batch size >= b; acceptance is the shared
    truncated-geometric process — or, for sim-vs-live parity tests, a
    replayed ``accept_source(step_idx, rids, s) -> accepted`` trace.
    """

    def __init__(self, model: LatencyModel, capacity: int, seed: int = 0,
                 accept_source: Optional[Callable] = None,
                 duration_source: Optional[Callable] = None,
                 prefill_source: Optional[Callable] = None):
        self.model = model
        self.capacity = capacity
        self.acceptance = GeometricAcceptance(model, seed)
        self.accept_source = accept_source
        self.duration_source = duration_source
        self.prefill_source = prefill_source
        self.done = np.ones(capacity, dtype=bool)
        self.rids = np.full(capacity, -1, dtype=np.int64)
        self._step_idx = 0

    def _batch_key(self, b: int) -> int:
        for x in self.model.batch_sizes:
            if x >= b:
                return x
        return self.model.batch_sizes[-1]

    def prefill(self, req: Request, slot: int) -> float:
        self.done[slot] = False
        self.rids[slot] = req.rid
        if self.prefill_source is not None:
            return float(self.prefill_source(req.rid))
        return 0.0                     # prefill is outside the fitted model

    def step(self, s: int) -> Tuple[float, np.ndarray, np.ndarray]:
        active = np.where(~self.done)[0]
        b = len(active)
        bk = self._batch_key(b)
        if self.duration_source is not None:
            dt = float(self.duration_source(self._step_idx, b, s))
        else:
            dt = self.model.t_verify(bk, s) + s * self.model.t_s[bk]
        if self.accept_source is not None:
            accepted = np.asarray(
                self.accept_source(self._step_idx, self.rids[active], s))
        else:
            accepted = self.acceptance.draw(b, s)
        committed = np.zeros(self.capacity, dtype=np.int64)
        # accepted = -1 encodes a replayed zero-commit step (the live engine
        # had already stopped this request: EOS / engine-level max_new);
        # mirror the live backend by marking the slot done so the scheduler
        # retires it the same iteration
        committed[active] = np.maximum(accepted + 1, 0)
        self.done[active[committed[active] == 0]] = True
        self._step_idx += 1
        return dt, committed, self.done.copy()

    def retire(self, slot: int) -> None:
        self.done[slot] = True
        self.rids[slot] = -1


# ---------------------------------------------------------------------------
# the scheduler


@dataclass
class StepTrace:
    """Per-iteration scheduling record (drives sim-vs-live parity tests)."""
    clock: float
    occupancy: int
    s: int
    rids: Tuple[int, ...]
    committed: Dict[int, int]          # rid -> raw committed this step
    admitted: Tuple[int, ...] = ()
    duration: float = 0.0              # step duration charged to the clock
    prefill_s: Tuple[float, ...] = ()  # per-admission prefill seconds


def replay_sources(trace: Sequence[StepTrace]):
    """(accept, duration, prefill) replay callbacks from a recorded trace.

    Feeding these into :class:`SimStepBackend` pins every *outcome* (commit
    counts, step durations, prefill costs) to the recorded run, so a second
    scheduler run over the sim backend must reproduce the recorded admission
    order and batch-size sequence exactly — the sim-vs-live parity check.
    """
    prefill: Dict[int, float] = {}
    for t in trace:
        for rid, dt in zip(t.admitted, t.prefill_s):
            prefill[rid] = dt

    def accept(step_idx, rids, s):
        # committed - 1; a recorded 0 maps to -1 (zero-commit step: the
        # recorded run had retired this request via EOS / engine max_new)
        rec = trace[step_idx].committed
        return np.array([rec.get(int(r), 1) - 1 for r in rids])

    def duration(step_idx, b, s):
        return trace[step_idx].duration

    def prefill_src(rid):
        return prefill.get(rid, 0.0)

    return accept, duration, prefill_src


class ContinuousScheduler:
    """Iteration-level serving loop over any step backend.

    After :meth:`run`, ``self.trace`` holds one :class:`StepTrace` per
    iteration (admission order, live batch size, per-request commits) —
    the observable scheduling behaviour compared in parity tests.
    """

    def __init__(self, backend, controller: AdaptiveController,
                 policy: Optional[AdmissionPolicy] = None,
                 observe: bool = False):
        self.backend = backend
        self.controller = controller
        self.policy = policy or ImmediateAdmit()
        self.observe = observe
        self.trace: List[StepTrace] = []

    def run(self, requests: Sequence[Request]):
        from repro.serving.server import ServeResult   # avoid import cycle
        pending = sorted(requests, key=lambda r: r.arrival)
        pool = SlotPool(self.backend.capacity)
        backlog: List[Request] = []
        batches: List[BatchRecord] = []
        self.trace = []
        clock, i, n_done, n = 0.0, 0, 0, len(pending)
        while n_done < n:
            while i < n and pending[i].arrival <= clock:
                backlog.append(pending[i])
                i += 1
            admitted: List[int] = []
            prefill_s: List[float] = []
            for req in self.policy.select(backlog, pool.free_count, clock):
                backlog.remove(req)
                slot = pool.claim(req)
                req.start = clock
                p_dt = self.backend.prefill(req, slot)
                clock += p_dt
                admitted.append(req.rid)
                prefill_s.append(p_dt)
            if pool.occupancy == 0:
                if not backlog and i < n:
                    clock = max(clock, pending[i].arrival)
                continue
            b = pool.occupancy
            s = self.controller.choose(b)
            dt, committed, backend_done = self.backend.step(s)
            clock += dt
            toks = 0
            raw: Dict[int, int] = {}
            accepted_live: List[int] = []
            for slot in pool.active_slots():
                req = pool.request_at(slot)
                c_raw = int(committed[slot])
                raw[req.rid] = c_raw
                accepted_live.append(max(c_raw - 1, 0))
                c = min(c_raw, pool.remaining(slot))
                if c > 0 and req.first_token is None:
                    req.first_token = clock
                pool.consume(slot, c)
                req.n_generated += c
                toks += c
                # finished: served its token budget, or the backend stopped
                # committing for it (EOS / engine-level max_new)
                if pool.remaining(slot) <= 0 or (c_raw == 0 and backend_done[slot]):
                    req.finish = clock
                    pool.retire(slot)
                    self.backend.retire(slot)
                    n_done += 1
            if self.observe and s > 0:
                self.controller.observe(np.asarray(accepted_live), s)
            batches.append(BatchRecord(
                start=clock - dt, duration=dt, batch_size=b, s_used=s,
                tokens_generated=toks, n_steps=1,
                rids=tuple(sorted(raw))))
            self.trace.append(StepTrace(
                clock=clock - dt, occupancy=b, s=s,
                rids=tuple(sorted(raw)), committed=raw,
                admitted=tuple(admitted), duration=dt,
                prefill_s=tuple(prefill_s)))
        return ServeResult(requests=list(pending), batches=batches)


def serve_continuous_live(requests: Sequence[Request], engine, tparams,
                          dparams, controller: AdaptiveController, *,
                          capacity: int = 8, cache_len: int = 256,
                          policy: Optional[AdmissionPolicy] = None,
                          observe: bool = False,
                          backend: Optional[ContinuousEngineBackend] = None):
    """Serve a request trace on a LIVE SpecDecodeEngine with iteration-level
    continuous batching: requests join/leave at speculative-step granularity
    and the controller re-chooses s from live occupancy every step.

    The virtual clock advances by measured wall time (compiles warmed
    outside the timed regions), so results are directly comparable with the
    run-to-completion :func:`repro.serving.server.serve` loop and with the
    :class:`SimStepBackend` simulation on the same trace.
    """
    for r in requests:
        if r.max_new > engine.max_new:
            raise ValueError(
                f"request {r.rid} wants {r.max_new} tokens but the engine "
                f"slot pool is sized for max_new={engine.max_new}")
    if backend is None:
        warm = sorted(set(controller.lut.table.values()))
        backend = ContinuousEngineBackend(engine, tparams, dparams,
                                          capacity=capacity,
                                          cache_len=cache_len, warm_s=warm)
    sched = ContinuousScheduler(backend, controller, policy, observe=observe)
    result = sched.run(requests)
    result.trace = sched.trace
    return result
