"""Iteration-level (Orca-style) continuous-batching scheduler.

Requests JOIN and LEAVE the running batch at *speculative-step* granularity:
every iteration the scheduler (1) admits arrived requests into free KV slots
via a pluggable :class:`AdmissionPolicy`, (2) asks the
:class:`~repro.core.adaptive.AdaptiveController` for the speculation length
at the **live occupancy** — the finest-grained use of the paper's b -> s_opt
LUT — and (3) runs one speculative step, retiring finished slots.

Two step backends answer the same protocol, so the identical scheduling code
runs against hardware truth and against the fitted simulation:

  * :class:`ContinuousEngineBackend` — a live
    :class:`~repro.core.spec_decode.SpecDecodeEngine` slot pool
    (``prefill_into`` / masked step / ``retire_slot``), wall-clock timed
    with compiles warmed outside the timed region;
  * :class:`SimStepBackend` — one discrete-event step from a fitted
    :class:`~repro.core.analytical.LatencyModel` with the shared
    truncated-geometric acceptance process (serving/acceptance.py).

``serve_continuous_live()`` is the live entrypoint mirroring
:func:`repro.serving.server.serve_continuous` (which now runs this same
scheduler over :class:`SimStepBackend`), so Fig. 5-7 traffic studies can be
replayed on a real engine and validated against the simulation
(sim-vs-live parity on identical traces).

Paged KV + preemption: when the engine slot pool is paged (fixed-size
blocks + a free list, core/spec_decode.py design note), the scheduler also
(a) admits by block feasibility — a prompt only enters when the free list
covers it, (b) hard-rejects requests whose worst-case footprint
(prompt + max_new + S_MAX) exceeds the per-request capacity (previously
they silently wrapped their KV ring), and (c) preempts under memory
pressure: if covering this step's worst-case commit (s+1 tokens per live
slot) could exhaust the free list, the victim with the longest remaining
budget (ties: most recently admitted, i.e. LIFO) is evicted back to the
backlog and later re-prefilled from prompt + its generated-token stash.
Preemptions are recorded in :class:`StepTrace`; because they are pure
functions of the block accounting, a :class:`SimStepBackend` built with
the same pool geometry re-derives them exactly during replay.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.adaptive import AdaptiveController
from repro.core.analytical import LatencyModel
from repro.core.spec_decode import S_MAX
from repro.serving.acceptance import GeometricAcceptance
from repro.serving.request import BatchRecord, Request
from repro.serving.slots import PagedKVTables, SlotPool


# ---------------------------------------------------------------------------
# admission policies


class AdmissionPolicy:
    """Chooses which backlog requests to admit into free slots this step."""

    def select(self, backlog: Sequence[Request], free_slots: int,
               clock: float) -> List[Request]:
        raise NotImplementedError


class ImmediateAdmit(AdmissionPolicy):
    """Admit FCFS into every free slot (Orca-style, the default)."""

    def select(self, backlog, free_slots, clock):
        return list(backlog[:free_slots])


class PrefillBudgetAdmit(AdmissionPolicy):
    """Chunked-prefill-style admission: cap the prefill tokens injected per
    iteration so admission work cannot starve the running batch (bounds the
    inter-token latency hit of each admission burst; SNIPPETS §2).

    Always admits at least one request when a slot is free, so the policy
    never deadlocks on a prompt longer than the budget.
    """

    def __init__(self, token_budget: int = 64):
        self.token_budget = token_budget

    def select(self, backlog, free_slots, clock):
        out: List[Request] = []
        used = 0
        for req in backlog[:free_slots]:
            if out and used + req.prompt_len > self.token_budget:
                break
            out.append(req)
            used += req.prompt_len
        return out


class FCFSBacklog(AdmissionPolicy):
    """At most ``max_per_step`` admissions per iteration (rate-limited FCFS,
    the gentlest admission schedule)."""

    def __init__(self, max_per_step: int = 1):
        self.max_per_step = max_per_step

    def select(self, backlog, free_slots, clock):
        return list(backlog[:min(free_slots, self.max_per_step)])


# ---------------------------------------------------------------------------
# step backends


def _reject_oversize(req: Request, max_context: int) -> None:
    """Hard admission bound: a request whose worst-case KV footprint exceeds
    the per-request capacity can never be served — deferring it would spin
    forever, and admitting it would silently wrap the ring / overrun the
    block table and corrupt the KV (the PR-1 bug this check closes)."""
    if req.prompt_len + req.max_new + S_MAX > max_context:
        raise ValueError(
            f"request {req.rid}: prompt_len={req.prompt_len} + "
            f"max_new={req.max_new} + S_MAX={S_MAX} exceeds the per-request "
            f"KV capacity {max_context}; the KV ring would wrap and corrupt "
            f"itself")


class ContinuousEngineBackend:
    """Live-engine step backend: a SpecDecodeEngine slot pool on hardware.

    Prefill compiles (per prompt bucket) and step compiles (per s) are warmed
    outside the timed regions — serving latency is steady-state, matching
    EngineBackend's treatment of compile time.

    With ``block_size`` set, the engine slot pool is the paged KV block pool
    (``self.kv`` holds its host free list / block tables) and the scheduler
    gains admission feasibility checks and preemption under memory pressure.
    A preempted request's generated tokens are stashed host-side; on
    re-admission it re-prefills from prompt + stash (recompute-style
    restore) and greedy decoding continues exactly where it left off.
    """

    def __init__(self, engine, tparams, dparams, capacity: int,
                 cache_len: int = 256, warm_s: Sequence[int] = (),
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 collect_outputs: bool = False):
        if engine.tcfg.family in ("encdec", "audio", "vlm"):
            # these families need per-request modality extras (src_embeds /
            # prefix_embeds) that the admission path does not plumb yet; see
            # ROADMAP open items
            raise NotImplementedError(
                f"continuous batching does not support family "
                f"'{engine.tcfg.family}' yet (per-request modality extras)")
        self.engine = engine
        self.tparams = tparams
        self.dparams = dparams
        self.capacity = capacity
        self.state = engine.init_slots(capacity, cache_len,
                                       block_size=block_size,
                                       num_blocks=num_blocks)
        self.kv = self.state.paged               # None => contiguous rings
        self.cache_len = (self.kv.logical_len if self.kv is not None
                          else cache_len)
        self.collect_outputs = collect_outputs
        self.outputs: Dict[int, np.ndarray] = {}   # rid -> generated tokens
        self._stash: Dict[int, np.ndarray] = {}    # rid -> pre-preempt tokens
        self._warm_prefill: set = set()
        self._warm_step: set = set()
        for s in warm_s:
            self.warm_step(s)

    @property
    def max_context(self) -> int:
        """Per-request KV capacity in tokens (admission hard limit)."""
        return self.cache_len

    def warm_step(self, s: int) -> None:
        if s not in self._warm_step:
            self.engine.step(self.tparams, self.dparams, self.state, s,
                             warm=True)
            self._warm_step.add(s)

    def _bucket(self, n: int) -> int:
        p = 4
        while p < n:
            p *= 2
        return min(p, self.cache_len)   # never wider than the KV capacity

    def _full_prompt(self, req: Request) -> np.ndarray:
        """Prompt plus any tokens generated before a preemption."""
        stash = self._stash.get(req.rid)
        if stash is None:
            return np.asarray(req.tokens[:req.prompt_len], np.int32)
        return np.concatenate(
            [np.asarray(req.tokens[:req.prompt_len], np.int32), stash])

    def prefill(self, req: Request, slot: int) -> float:
        """Inject ``req`` into ``slot``; returns seconds of prefill work."""
        _reject_oversize(req, self.max_context)   # defense in depth
        prompt = self._full_prompt(req)
        plen = len(prompt)
        P = self._bucket(plen)
        toks = np.ones((P,), np.int32)
        toks[:plen] = prompt
        if P not in self._warm_prefill:
            # compile the B=1 prefill + inject for this bucket off the clock
            self.engine.prefill_into(self.tparams, self.dparams, self.state,
                                     slot, toks, plen, self.cache_len,
                                     warm=True)
            self._warm_prefill.add(P)
        t0 = time.perf_counter()
        self.state = self.engine.prefill_into(
            self.tparams, self.dparams, self.state, slot, toks,
            plen, self.cache_len)
        np.asarray(self.state.seq_lens)          # block until ready
        return time.perf_counter() - t0

    def step(self, s: int) -> Tuple[float, np.ndarray, np.ndarray]:
        """One speculative step at live occupancy.  Returns
        (wall seconds, committed[capacity], done[capacity])."""
        self.warm_step(s)
        t0 = time.perf_counter()
        self.state, st = self.engine.step(self.tparams, self.dparams,
                                          self.state, s)
        committed = np.asarray(st.committed)     # forces sync
        dt = time.perf_counter() - t0
        return dt, committed, np.asarray(self.state.done)

    def preempt(self, slot: int, req: Request) -> None:
        """Evict ``req`` under memory pressure: stash its generated tokens,
        free the slot's KV blocks, and mark the row done."""
        dev_n = int(np.asarray(self.state.n_generated)[slot])
        fresh = np.asarray(self.state.out)[slot, :dev_n].astype(np.int32)
        old = self._stash.get(req.rid)
        self._stash[req.rid] = (fresh if old is None
                                else np.concatenate([old, fresh]))
        self.state = self.engine.retire_slot(self.state, slot)

    def retire(self, slot: int, req: Optional[Request] = None) -> None:
        if req is not None:
            if self.collect_outputs:
                # stitch ever-preempted requests now, before the slot (and
                # its out row) is recycled
                self.outputs[req.rid] = self.output_for(slot, req)
            # always drop the stash: keeping it for callers who opted out of
            # output collection would leak memory on long-lived backends
            self._stash.pop(req.rid, None)
        self.state = self.engine.retire_slot(self.state, slot)

    def output_for(self, slot: int, req: Optional[Request] = None) -> np.ndarray:
        """Generated tokens of the request in ``slot``.

        With ``req`` given, the result is truncated to ``req.n_generated``
        (a request with a smaller ``max_new`` than the engine's must not
        surface tokens past its budget) and stitched with any pre-preemption
        stash; without it, the legacy engine-sized row is returned.
        """
        out = np.asarray(self.state.out)[slot]
        if req is None:
            return out[:self.engine.max_new]
        stash = self._stash.get(req.rid)
        if stash is None:
            return out[:req.n_generated].astype(np.int32)
        cont = out[:req.n_generated - len(stash)].astype(np.int32)
        return np.concatenate([stash, cont])


class SimStepBackend:
    """Discrete-event step backend over a fitted LatencyModel.

    Step duration at live occupancy b is t_L(bk, s) + s * t_S(bk, 1) with bk
    the nearest profiled batch size >= b; acceptance is the shared
    truncated-geometric process — or, for sim-vs-live parity tests, a
    replayed ``accept_source(step_idx, rids, s) -> accepted`` trace.
    """

    def __init__(self, model: LatencyModel, capacity: int, seed: int = 0,
                 accept_source: Optional[Callable] = None,
                 duration_source: Optional[Callable] = None,
                 prefill_source: Optional[Callable] = None,
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 max_context: int = 256,
                 done_source: Optional[Callable] = None):
        self.model = model
        self.capacity = capacity
        self.acceptance = GeometricAcceptance(model, seed)
        self.accept_source = accept_source
        self.duration_source = duration_source
        self.prefill_source = prefill_source
        # replayed per-step done sets: the live engine marks a slot done on
        # its EOS step (commit > 0) one iteration before it commits 0, and
        # victim selection must see the same flag to replay identically
        self.done_source = done_source
        self.done = np.ones(capacity, dtype=bool)
        self.rids = np.full(capacity, -1, dtype=np.int64)
        self._step_idx = 0
        # paged-KV mirror: same geometry as the live pool => the scheduler's
        # preemption decisions (functions of free/allocated/token counts
        # only) replay count-for-count against the live run
        if block_size is not None:
            max_blocks = -(-max_context // block_size)
            if num_blocks is None:
                num_blocks = capacity * max_blocks
            self.kv: Optional[PagedKVTables] = PagedKVTables(
                num_blocks, block_size, capacity, max_blocks)
        else:
            self.kv = None
        # the plain sim has no KV to overflow, so no admission hard limit
        self.max_context = (self.kv.logical_len if self.kv is not None
                            else None)

    def _batch_key(self, b: int) -> int:
        for x in self.model.batch_sizes:
            if x >= b:
                return x
        return self.model.batch_sizes[-1]

    def prefill(self, req: Request, slot: int) -> float:
        self.done[slot] = False
        self.rids[slot] = req.rid
        if self.kv is not None:
            # a re-admitted (preempted) request re-prefills prompt + stash
            self.kv.prefill(slot, req.prompt_len + req.n_generated)
        if self.prefill_source is not None:
            return float(self.prefill_source(req.rid))
        return 0.0                     # prefill is outside the fitted model

    def step(self, s: int) -> Tuple[float, np.ndarray, np.ndarray]:
        active = np.where(~self.done)[0]
        b = len(active)
        bk = self._batch_key(b)
        if self.kv is not None:
            # same slot set as the live engine's pre-step growth: every slot
            # still holding blocks (incl. EOS'd rows awaiting retirement)
            for slot in self.kv.active_slots():
                self.kv.ensure(slot, self.kv.tokens(slot) + s)
        if self.duration_source is not None:
            dt = float(self.duration_source(self._step_idx, b, s))
        else:
            dt = self.model.t_verify(bk, s) + s * self.model.t_s[bk]
        if self.accept_source is not None:
            accepted = np.asarray(
                self.accept_source(self._step_idx, self.rids[active], s))
        else:
            accepted = self.acceptance.draw(b, s)
        committed = np.zeros(self.capacity, dtype=np.int64)
        # accepted = -1 encodes a replayed zero-commit step (the live engine
        # had already stopped this request: EOS / engine-level max_new);
        # mirror the live backend by marking the slot done so the scheduler
        # retires it the same iteration
        committed[active] = np.maximum(accepted + 1, 0)
        self.done[active[committed[active] == 0]] = True
        if self.done_source is not None:
            rec = {int(r) for r in self.done_source(self._step_idx)}
            for slot in active:
                if int(self.rids[slot]) in rec:
                    self.done[slot] = True
        if self.kv is not None:
            for slot in self.kv.active_slots():
                self.kv.commit(slot, int(committed[slot]))
        self._step_idx += 1
        return dt, committed, self.done.copy()

    def preempt(self, slot: int, req: Request) -> None:
        self.done[slot] = True
        self.rids[slot] = -1
        if self.kv is not None:
            self.kv.release(slot)

    def retire(self, slot: int, req: Optional[Request] = None) -> None:
        self.done[slot] = True
        self.rids[slot] = -1
        if self.kv is not None:
            self.kv.release(slot)


# ---------------------------------------------------------------------------
# the scheduler


@dataclass
class StepTrace:
    """Per-iteration scheduling record (drives sim-vs-live parity tests)."""
    clock: float
    occupancy: int
    s: int
    rids: Tuple[int, ...]
    committed: Dict[int, int]          # rid -> raw committed this step
    admitted: Tuple[int, ...] = ()
    duration: float = 0.0              # step duration charged to the clock
    prefill_s: Tuple[float, ...] = ()  # per-admission prefill seconds
    preempted: Tuple[int, ...] = ()    # rids evicted before this step
    done_rids: Tuple[int, ...] = ()    # rids the backend flagged done after


def replay_sources(trace: Sequence[StepTrace]):
    """(accept, duration, prefill, done) replay callbacks from a trace.

    Feeding these into :class:`SimStepBackend` pins every *outcome* (commit
    counts, step durations, prefill costs, per-step done flags) to the
    recorded run, so a second scheduler run over the sim backend must
    reproduce the recorded admission order and batch-size sequence exactly
    — the sim-vs-live parity check.  Preemption decisions are NOT replayed:
    they are pure functions of the block-pool accounting plus the done
    flags, so a sim backend built with the live pool's geometry re-derives
    them (and the parity test checks they match).

    A preempted request is admitted (and so prefilled) more than once, so
    per-rid prefill costs replay as a FIFO queue of the recorded durations.
    """
    prefill: Dict[int, List[float]] = {}
    for t in trace:
        for rid, dt in zip(t.admitted, t.prefill_s):
            prefill.setdefault(rid, []).append(dt)

    def accept(step_idx, rids, s):
        # committed - 1; a recorded 0 maps to -1 (zero-commit step: the
        # recorded run had retired this request via EOS / engine max_new)
        rec = trace[step_idx].committed
        return np.array([rec.get(int(r), 1) - 1 for r in rids])

    def duration(step_idx, b, s):
        return trace[step_idx].duration

    def prefill_src(rid):
        q = prefill.get(rid)
        return q.pop(0) if q else 0.0

    def done_src(step_idx):
        return trace[step_idx].done_rids

    return accept, duration, prefill_src, done_src


class ContinuousScheduler:
    """Iteration-level serving loop over any step backend.

    After :meth:`run`, ``self.trace`` holds one :class:`StepTrace` per
    iteration (admission order, live batch size, per-request commits) —
    the observable scheduling behaviour compared in parity tests.
    """

    def __init__(self, backend, controller: AdaptiveController,
                 policy: Optional[AdmissionPolicy] = None,
                 observe: bool = False):
        self.backend = backend
        self.controller = controller
        self.policy = policy or ImmediateAdmit()
        self.observe = observe
        self.trace: List[StepTrace] = []

    @staticmethod
    def _select_victim(slots: Sequence[int], pool: SlotPool,
                       admit_seq: Dict[int, int]) -> int:
        """Preemption victim: longest remaining token budget, ties broken
        LIFO by admission order (the most recently admitted goes first)."""
        return max(slots, key=lambda sl: (pool.remaining(sl),
                                          admit_seq[pool.request_at(sl).rid]))

    def run(self, requests: Sequence[Request]):
        from repro.serving.server import ServeResult   # avoid import cycle
        pending = sorted(requests, key=lambda r: r.arrival)
        pool = SlotPool(self.backend.capacity)
        backlog: List[Request] = []
        batches: List[BatchRecord] = []
        self.trace = []
        kv = getattr(self.backend, "kv", None)
        max_ctx = getattr(self.backend, "max_context", None)
        admit_seq: Dict[int, int] = {}
        n_admits = 0
        prev_done: set = set()         # rids the backend flagged done last step
        clock, i, n_done, n = 0.0, 0, 0, len(pending)
        while n_done < n:
            while i < n and pending[i].arrival <= clock:
                backlog.append(pending[i])
                i += 1
            admitted: List[int] = []
            prefill_s: List[float] = []
            for req in self.policy.select(backlog, pool.free_count, clock):
                if max_ctx is not None:
                    # oversized requests can NEVER be served (deferring would
                    # spin forever); fail loudly before claiming a slot
                    _reject_oversize(req, max_ctx)
                if kv is not None:
                    # admit only if the free list covers the prompt (plus
                    # stash), this request's worst-case first step, AND the
                    # running batch's own worst-case growth — otherwise a
                    # fresh admit pays a full B=1 prefill just to be evicted
                    # by the pressure check below (prefill thrash)
                    growth = sum(
                        max(0, kv.blocks_for(kv.tokens(sl) + S_MAX)
                            - kv.allocated(sl))
                        for sl in pool.active_slots())
                    need = kv.blocks_for(req.prompt_len + req.n_generated
                                         + S_MAX)
                    if need + growth > kv.free_blocks:
                        break          # head-of-line: wait for free blocks
                backlog.remove(req)
                slot = pool.claim(req)
                if req.start is None:  # keep the first admission's start
                    req.start = clock
                p_dt = self.backend.prefill(req, slot)
                clock += p_dt
                admitted.append(req.rid)
                prefill_s.append(p_dt)
                n_admits += 1
                admit_seq[req.rid] = n_admits
            if pool.occupancy == 0:
                if not backlog and i < n:
                    clock = max(clock, pending[i].arrival)
                continue
            # ---- preemption under memory pressure (paged pool only) ----
            # worst case this step commits s+1 tokens per slot, i.e. KV
            # writes up to seq_len + s rows; if covering that could exhaust
            # the free list, evict victims back to the backlog (they
            # re-prefill from prompt + generated stash later).  A lone slot
            # always fits: admission bounds every request to the pool.
            preempted: List[int] = []
            if kv is not None:
                while pool.occupancy > 1:
                    s = self.controller.choose(pool.occupancy)
                    need = sum(
                        max(0, kv.blocks_for(kv.tokens(sl) + s)
                            - kv.allocated(sl))
                        for sl in pool.active_slots())
                    if need <= kv.free_blocks:
                        break
                    # never evict a slot the backend already flagged done
                    # (EOS'd, awaiting its zero-commit retirement step):
                    # re-prefilling it would resurrect a finished request
                    # and generate past its EOS
                    eligible = [sl for sl in pool.active_slots()
                                if pool.request_at(sl).rid not in prev_done]
                    if not eligible:
                        break          # done slots free their blocks shortly
                    victim = self._select_victim(eligible, pool, admit_seq)
                    req = pool.retire(victim)
                    self.backend.preempt(victim, req)
                    backlog.insert(0, req)
                    preempted.append(req.rid)
            b = pool.occupancy
            s = self.controller.choose(b)
            dt, committed, backend_done = self.backend.step(s)
            done_rids = tuple(sorted(
                pool.request_at(sl).rid for sl in pool.active_slots()
                if backend_done[sl]))
            clock += dt
            toks = 0
            raw: Dict[int, int] = {}
            accepted_live: List[int] = []
            for slot in pool.active_slots():
                req = pool.request_at(slot)
                c_raw = int(committed[slot])
                raw[req.rid] = c_raw
                accepted_live.append(max(c_raw - 1, 0))
                c = min(c_raw, pool.remaining(slot))
                if c > 0 and req.first_token is None:
                    req.first_token = clock
                pool.consume(slot, c)
                req.n_generated += c
                toks += c
                # finished: served its token budget, or the backend stopped
                # committing for it (EOS / engine-level max_new)
                if pool.remaining(slot) <= 0 or (c_raw == 0 and backend_done[slot]):
                    req.finish = clock
                    pool.retire(slot)
                    self.backend.retire(slot, req)
                    n_done += 1
            if self.observe and s > 0:
                self.controller.observe(np.asarray(accepted_live), s)
            batches.append(BatchRecord(
                start=clock - dt, duration=dt, batch_size=b, s_used=s,
                tokens_generated=toks, n_steps=1,
                rids=tuple(sorted(raw))))
            self.trace.append(StepTrace(
                clock=clock - dt, occupancy=b, s=s,
                rids=tuple(sorted(raw)), committed=raw,
                admitted=tuple(admitted), duration=dt,
                prefill_s=tuple(prefill_s), preempted=tuple(preempted),
                done_rids=done_rids))
            prev_done = set(done_rids)
        return ServeResult(requests=list(pending), batches=batches)


def serve_continuous_live(requests: Sequence[Request], engine, tparams,
                          dparams, controller: AdaptiveController, *,
                          capacity: int = 8, cache_len: int = 256,
                          policy: Optional[AdmissionPolicy] = None,
                          observe: bool = False,
                          backend: Optional[ContinuousEngineBackend] = None,
                          block_size: Optional[int] = None,
                          num_blocks: Optional[int] = None):
    """Serve a request trace on a LIVE SpecDecodeEngine with iteration-level
    continuous batching: requests join/leave at speculative-step granularity
    and the controller re-chooses s from live occupancy every step.

    The virtual clock advances by measured wall time (compiles warmed
    outside the timed regions), so results are directly comparable with the
    run-to-completion :func:`repro.serving.server.serve` loop and with the
    :class:`SimStepBackend` simulation on the same trace.

    ``block_size`` switches the KV slot pool to the paged block allocator
    (``num_blocks`` sizes it; default worst-case) with preemption under
    memory pressure.  Admission hard-rejects any request whose worst-case
    KV footprint (``prompt_len + max_new + S_MAX``) exceeds the per-request
    capacity — previously such a request silently wrapped its KV ring and
    corrupted itself.
    """
    for r in requests:
        if r.max_new > engine.max_new:
            raise ValueError(
                f"request {r.rid} wants {r.max_new} tokens but the engine "
                f"slot pool is sized for max_new={engine.max_new}")
    if backend is None:
        warm = sorted(set(controller.lut.table.values()))
        backend = ContinuousEngineBackend(engine, tparams, dparams,
                                          capacity=capacity,
                                          cache_len=cache_len, warm_s=warm,
                                          block_size=block_size,
                                          num_blocks=num_blocks)
    for r in requests:
        if r.prompt_len + r.max_new + S_MAX > backend.max_context:
            raise ValueError(
                f"request {r.rid}: prompt_len={r.prompt_len} + "
                f"max_new={r.max_new} + S_MAX={S_MAX} exceeds the "
                f"per-request KV capacity {backend.max_context}; the KV "
                f"ring would wrap and corrupt itself")
    sched = ContinuousScheduler(backend, controller, policy, observe=observe)
    result = sched.run(requests)
    result.trace = sched.trace
    return result
