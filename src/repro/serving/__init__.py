"""Serving layer: request/traffic modelling, the run-to-completion server,
the iteration-level continuous-batching scheduler (live engine + simulation
backends behind one protocol), slot/block-pool bookkeeping, and latency
metrics.  See docs/ARCHITECTURE.md for the end-to-end picture."""
from repro.serving.acceptance import GeometricAcceptance, match_prob
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import BatchRecord, Request
from repro.serving.scheduler import (AdmissionPolicy, ContinuousEngineBackend,
                                     ContinuousScheduler, FCFSBacklog,
                                     HostShardQueue, ImmediateAdmit,
                                     PrefillBudgetAdmit, SimStepBackend,
                                     controller_s_cap, replay_sources,
                                     serve_continuous_live)
from repro.serving.server import (EngineBackend, ServeResult, SimBackend,
                                  serve, serve_continuous)
from repro.serving.slots import (BlockPool, BlockPoolExhausted, PagedKVTables,
                                 SlotPool)
from repro.serving.telemetry import PHASES, Telemetry
from repro.serving.traffic import (TrafficPhase, alternating_traffic,
                                   make_requests, uniform_traffic)
