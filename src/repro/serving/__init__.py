from repro.serving.request import BatchRecord, Request
from repro.serving.server import EngineBackend, ServeResult, SimBackend, serve
from repro.serving.traffic import (TrafficPhase, alternating_traffic,
                                   make_requests, uniform_traffic)
