"""Stochastic acceptance shared by every simulation path (paper Eq. 4).

The simulated acceptance process is a truncated geometric: each of the ``s``
draft positions is independently "correct" with probability ``p``, and the
accepted run is the number of leading correct drafts.  ``p`` is chosen so the
*expected* run length matches the fitted acceptance curve l(s), i.e. it
inverts  sum_{i=1..s} p^i = l(s).

One :class:`GeometricAcceptance` instance owns the rng and the per-``s``
``p`` cache; :class:`~repro.serving.server.SimBackend`, the continuous-
batching simulation, and the iteration-level scheduler's sim backend all
draw from it, so every scheduling comparison uses the identical acceptance
process.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.analytical import LatencyModel


def match_prob(l_target: float, s: int) -> float:
    """p such that the truncated-geometric expected run sum_{i=1..s} p^i
    equals ``l_target``."""
    l_target = min(max(l_target, 0.0), s - 1e-9)
    lo, hi = 0.0, 1.0 - 1e-12
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        val = sum(mid ** i for i in range(1, s + 1))
        if val < l_target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


class GeometricAcceptance:
    """rng + p-cache for truncated-geometric acceptance draws."""

    def __init__(self, model: LatencyModel, seed: int = 0):
        self.model = model
        self.rng = np.random.default_rng(seed)
        self._p_cache: Dict[int, float] = {}

    def p(self, s: int) -> float:
        if s not in self._p_cache:
            self._p_cache[s] = match_prob(self.model.l_of_s(s), s)
        return self._p_cache[s]

    def draw(self, b: int, s: int) -> np.ndarray:
        """Accepted-run lengths for ``b`` live requests at speculation ``s``."""
        if s <= 0:
            return np.zeros(b, dtype=np.int64)
        u = self.rng.random((b, s))
        return (np.cumprod(u < self.p(s), axis=1)).sum(axis=1)
