"""Request / completion records for the serving layer (paper §5.3).

Latency is measured exactly as the paper does: ``t_b - t_a`` where ``t_a`` is
the client send time and ``t_b`` the time the server finishes the request —
queueing time included.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Request:
    rid: int
    arrival: float                 # t_a, seconds
    tokens: np.ndarray             # [Tp] prompt token ids
    prompt_len: int
    max_new: int = 128
    # filled in by the server
    start: Optional[float] = None        # batch execution start
    finish: Optional[float] = None       # t_b
    first_token: Optional[float] = None  # first committed token (TTFT end)
    n_generated: int = 0                 # tokens actually committed
    # chunked-prefill cursor: positions of the (prompt + stash) feed already
    # written into this request's slot.  0 while queued; advances as the
    # iteration-level scheduler feeds chunks; reset to 0 on preemption (a
    # re-admission re-prefills — chunked again if still over the budget).
    prefill_pos: int = 0

    @property
    def latency(self) -> float:
        assert self.finish is not None
        return self.finish - self.arrival

    @property
    def queue_wait(self) -> float:
        assert self.start is not None
        return self.start - self.arrival

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (iteration-level schedulers fill this in)."""
        return None if self.first_token is None else self.first_token - self.arrival

    @property
    def itl(self) -> Optional[float]:
        """Mean inter-token latency after the first token."""
        if self.first_token is None or self.finish is None or self.n_generated < 2:
            return None
        return (self.finish - self.first_token) / (self.n_generated - 1)


@dataclass
class BatchRecord:
    """One executed batch (for timelines and per-batch diagnostics)."""
    start: float
    duration: float
    batch_size: int
    s_used: int
    tokens_generated: int
    n_steps: int
    rids: tuple = ()

    @property
    def per_token_latency(self) -> float:
        return self.duration / max(self.tokens_generated, 1)
