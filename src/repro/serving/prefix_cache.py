"""Radix-tree prefix index over the paged KV block pool.

Cross-request prefix sharing: admission looks up the longest cached prefix
of an incoming prompt (:meth:`PrefixCache.match` / :meth:`~PrefixCache.lock`),
maps those blocks into the new slot's table at refcount+1
(:meth:`~repro.serving.slots.PagedKVTables.attach`) and prefills only the
uncached suffix; commit publishes the slot's own full prompt blocks back
into the index (:meth:`~PrefixCache.insert`), so templated traffic — many
requests sharing a system prompt or few-shot preamble — pays the shared
prefill exactly once.

The tree is a radix trie whose edges are *whole* KV blocks: every node
owns exactly one block and is keyed by the ``block_size``-tuple of tokens
that block holds.  Fixed-width keys mean lookup is a straight dictionary
walk with no edge splitting — a block either matches all of its tokens or
none of them, which is also the granularity at which block tables can
share physical storage.

Reference-count protocol: the cache holds its own +1 on every block it
indexes, taken at :meth:`insert` and dropped at eviction.  A block at
refcount 1 therefore belongs to the cache alone and is *reclaimable*;
:meth:`reclaim` evicts such blocks LRU-first (deepest-first within a
subtree: only leaves are evicted, which is sound because a refcount-1
node can never have a refcount>1 descendant — any slot attached to the
descendant's prefix holds references on every ancestor too).  Blocks that
are matched but not yet attached are protected by :meth:`lock`, which
takes a temporary reference so a concurrent admission cannot reclaim them
between feasibility check and attach.

Determinism: recency is a monotone integer clock bumped once per mutating
operation, never wall time, so eviction order — and therefore every
downstream scheduling decision — replays identically sim vs live.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.slots import BlockPool


class _Node:
    """One trie node = one KV block = ``block_size`` prompt tokens."""

    __slots__ = ("key", "block", "parent", "children", "last_used")

    def __init__(self, key: Optional[Tuple[int, ...]], block: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = 0


class PrefixCache:
    """Block-granular radix index of prompt prefixes held in the pool."""

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.block_size = pool.block_size
        self._root = _Node(None, -1, None)
        self._blocks: Dict[int, _Node] = {}
        self._clock = 0
        # cumulative counters for telemetry (the scheduler reads these)
        self.hits = 0
        self.hit_tokens = 0
        self.lookups = 0

    # ------------------------------------------------------------------
    # internals

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _keys(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        bs = self.block_size
        n = len(tokens) // bs
        return [tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
                for i in range(n)]

    def _walk(self, tokens: Sequence[int]) -> List[_Node]:
        """Longest chain of nodes matching ``tokens`` block-by-block."""
        path: List[_Node] = []
        node = self._root
        for key in self._keys(tokens):
            child = node.children.get(key)
            if child is None:
                break
            path.append(child)
            node = child
        return path

    # ------------------------------------------------------------------
    # queries

    @property
    def size(self) -> int:
        """Number of blocks currently indexed."""
        return len(self._blocks)

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest cached prefix of ``tokens`` as block ids (pure read)."""
        return [n.block for n in self._walk(tokens)]

    def reclaimable_ids(self) -> List[int]:
        """Ids of indexed blocks held by the cache alone (refcount 1)."""
        return [b for b in self._blocks if self.pool.refcount(b) == 1]

    def reclaimable(self) -> int:
        """How many indexed blocks eviction could free right now."""
        return sum(self.pool.refcount(b) == 1 for b in self._blocks)

    # ------------------------------------------------------------------
    # admission protocol

    def lock(self, tokens: Sequence[int]) -> List[int]:
        """Match and pin: the returned prefix blocks each gain a temporary
        reference so reclaim cannot evict them between the admission
        feasibility check and :meth:`~repro.serving.slots.PagedKVTables.attach`.
        The caller must drop the references with :meth:`unlock` (after
        attach takes the slot's own, or on admission abort)."""
        self.lookups += 1
        path = self._walk(tokens)
        now = self._tick()
        for n in path:
            n.last_used = now
            self.pool.incref(n.block)
        if path:
            self.hits += 1
            self.hit_tokens += len(path) * self.block_size
        return [n.block for n in path]

    def unlock(self, blocks: Sequence[int]) -> None:
        """Drop the temporary references taken by :meth:`lock`."""
        for b in blocks:
            self.pool.decref(b)

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Index ``tokens`` (full blocks only) backed by ``blocks``.

        ``blocks[i]`` is the slot-table block holding tokens
        ``[i*bs, (i+1)*bs)``.  Prefixes already indexed keep their existing
        node (and block id — the first writer wins; later duplicates stay
        exclusively owned by their slot); only genuinely new nodes take a
        cache reference.  Returns the number of new blocks indexed.
        """
        keys = self._keys(tokens)
        if len(blocks) < len(keys):
            raise ValueError(
                f"insert of {len(keys)} blocks of tokens backed by only "
                f"{len(blocks)} table blocks")
        now = self._tick()
        node = self._root
        added = 0
        for i, key in enumerate(keys):
            child = node.children.get(key)
            if child is None:
                b = int(blocks[i])
                if b in self._blocks:
                    raise RuntimeError(
                        f"block {b} already indexed elsewhere in the trie")
                self.pool.incref(b)
                child = _Node(key, b, node)
                node.children[key] = child
                self._blocks[b] = child
                added += 1
            child.last_used = now
            node = child
        return added

    # ------------------------------------------------------------------
    # eviction

    def reclaim(self, n: int) -> List[int]:
        """Evict up to ``n`` LRU cache-only blocks; returns evicted ids.

        Only leaves are evicted (children would be orphaned otherwise);
        evicting a leaf can expose its parent, so the scan repeats until
        ``n`` blocks freed or nothing is evictable.  Order is deterministic:
        oldest ``last_used`` first, lowest block id on ties.
        """
        evicted: List[int] = []
        while len(evicted) < n:
            best: Optional[_Node] = None
            for b, node in self._blocks.items():
                if node.children or self.pool.refcount(b) != 1:
                    continue
                if best is None or (node.last_used, node.block) < \
                        (best.last_used, best.block):
                    best = node
            if best is None:
                break
            del self._blocks[best.block]
            assert best.parent is not None
            del best.parent.children[best.key]
            self.pool.decref(best.block)
            evicted.append(best.block)
        return evicted
