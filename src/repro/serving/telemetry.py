"""Serving telemetry hub: phase-span tracing, the live (s, batch) acceptance
observatory, and pool/scheduler gauges for the continuous-batching runtime.

The hub is strictly **read-only observability**: it never touches the step
pipeline's decisions, so token outputs and the :class:`StepTrace` are
bit-identical with telemetry on or off (tests/test_telemetry.py enforces the
contract on the live engine for the contiguous, paged-under-preemption, and
chunked-admission paths).  It is also **zero-overhead when off**: the
scheduler only wires its hooks when an *enabled* hub is supplied — with
``enabled=False`` (or no hub at all) the hot path contains no telemetry
branches, no ``perf_counter`` calls, and no event construction.

Three instruments, one object:

* **Phase spans** — every iteration of the scheduler emits structured spans
  (``admit`` / ``prefill`` / ``chunk_continue`` / ``decode_verify`` /
  ``commit`` / ``preempt`` / ``retire``) with the seconds charged to each
  phase, buffered in memory and optionally streamed as a JSONL event log
  (``jsonl_path=``).  On the device side,
  :class:`~repro.core.spec_decode.SpecDecodeEngine` wraps each jit dispatch
  (step, B=1 prefill/chunk forwards, inject/retire scatters) in a
  ``jax.profiler.TraceAnnotation`` scope when ``engine.annotate`` is set, so
  a profiler trace (``profile_dir=``) attributes device time per phase.

* **The (s, batch) acceptance observatory** — per executed decode step the
  accepted-draft counts accumulate into histograms keyed by (chosen s, live
  decode batch size).  With an expected-acceptance callable attached
  (``attach_expected_acceptance``; the scheduler wires the controller's
  analytical model automatically when it has one), the observatory surfaces
  observed-vs-predicted acceptance drift per cell and in aggregate — the
  paper's l(s) model validated online rather than only at profile time.

* **Pool and scheduler gauges** — per-iteration snapshots of slot occupancy
  vs parked-PREFILLING count, backlog depth, block-pool free/used depth and
  free-list fragmentation, plus monotone counters for every span phase.
  :meth:`prometheus_text` renders a Prometheus-style text exposition;
  :meth:`dashboard` renders a console summary (printed every
  ``dashboard_every`` iterations when set).

The standing regression surface over these metrics is
``benchmarks/serving_bench.py`` -> ``results/BENCH_serving.json``.
"""
from __future__ import annotations

import json
import sys
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

# the span taxonomy; scheduler hooks only ever emit these phases
PHASES = ("admit", "prefill", "chunk_continue", "decode_verify", "commit",
          "preempt", "retire")


class Telemetry:
    """Serving telemetry hub (see module docstring).

    Every recording method is a no-op when ``enabled=False`` — but the
    scheduler goes further and never calls them at all in that case, so a
    disabled hub costs exactly nothing on the hot path.

    ``profile_dir`` arms :meth:`start`/:meth:`stop` to wrap the serving run
    in a ``jax.profiler`` trace (and implies ``annotate_device=True`` so the
    trace carries per-phase scopes).  ``jsonl_path`` streams every event as
    one JSON line at emit time; the in-memory ``events`` buffer always holds
    the same records (see :meth:`write_jsonl`).
    """

    def __init__(self, enabled: bool = True,
                 jsonl_path: Optional[str] = None,
                 dashboard_every: int = 0,
                 annotate_device: bool = False,
                 profile_dir: Optional[str] = None,
                 stream=None):
        self.enabled = bool(enabled)
        self.profile_dir = profile_dir
        self.annotate_device = bool(annotate_device or profile_dir)
        self.dashboard_every = int(dashboard_every)
        self.stream = stream
        self.events: List[dict] = []
        self.counters: Dict[str, int] = {}
        self.tokens_committed = 0
        self.iterations = 0
        self.gauges: Dict[str, float] = {}
        self.peaks: Dict[str, float] = {}
        # observatory cells: (s, batch) -> accumulators
        self._acc: Dict[Tuple[int, int], dict] = {}
        # s -> expected normalized acceptance (l(s) / s), if a model exists
        self.expected_acceptance: Optional[Callable[[int], float]] = None
        self._jsonl = open(jsonl_path, "w") if jsonl_path else None
        self._profiling = False

    # ------------------------------------------------------------------
    # recording hooks (called by the scheduler only when enabled)

    def _emit(self, ev: dict) -> None:
        self.events.append(ev)
        if self._jsonl is not None:
            self._jsonl.write(json.dumps(ev, default=float) + "\n")

    def span(self, phase: str, iteration: int, dt: float, **attrs) -> None:
        """Record one completed phase span: ``dt`` seconds charged to
        ``phase`` during scheduler iteration ``iteration``."""
        if not self.enabled:
            return
        self.counters[phase] = self.counters.get(phase, 0) + 1
        if phase == "commit":
            self.tokens_committed += int(attrs.get("tokens", 0))
        self._emit({"ev": "span", "phase": phase, "iter": int(iteration),
                    "dt": float(dt), **attrs})

    def observe_step(self, *, s: int, batch: int, accepted,
                     duration: float) -> None:
        """Feed one executed decode step into the acceptance observatory:
        per-row accepted-draft counts at (chosen s, decode batch size)."""
        if not self.enabled or s <= 0:
            return
        key = (int(s), int(batch))
        rec = self._acc.get(key)
        if rec is None:
            rec = self._acc[key] = {"hist": np.zeros(s + 1, np.int64),
                                    "draws": 0, "accepted": 0,
                                    "steps": 0, "time": 0.0}
        a = np.asarray(accepted, dtype=np.int64)
        np.add.at(rec["hist"], np.clip(a, 0, s), 1)
        rec["draws"] += int(a.size)
        rec["accepted"] += int(a.sum())
        rec["steps"] += 1
        rec["time"] += float(duration)

    def iteration(self, iteration: int, clock: float, **vals) -> None:
        """End-of-iteration gauge snapshot (occupancy, backlog, block pool,
        ...); also drives the periodic console dashboard."""
        if not self.enabled:
            return
        self.iterations += 1
        self.gauges.update(vals)
        self.gauges["clock"] = float(clock)
        for k in ("occupancy", "backlog", "used_blocks", "prefilling",
                  "grid_occupancy"):
            if k in vals:
                self.peaks[k] = max(self.peaks.get(k, 0), vals[k])
        self._emit({"ev": "gauges", "iter": int(iteration),
                    "clock": float(clock), **vals})
        if self.dashboard_every and self.iterations % self.dashboard_every == 0:
            print(self.dashboard(), file=self.stream or sys.stdout, flush=True)

    def attach_expected_acceptance(self, fn: Callable[[int], float]) -> None:
        """Attach ``s -> expected normalized acceptance`` (typically
        ``model.l_of_s(s) / s``); enables the drift gauge."""
        self.expected_acceptance = fn

    # ------------------------------------------------------------------
    # observatory views

    def acceptance_table(self) -> List[dict]:
        """One row per observed (s, batch) cell: accepted-token histogram,
        observed normalized acceptance, and — with an expected-acceptance
        model attached — the observed-minus-predicted drift."""
        rows = []
        for (s, b) in sorted(self._acc):
            rec = self._acc[(s, b)]
            observed = (rec["accepted"] / (rec["draws"] * s)
                        if rec["draws"] else None)
            expected = (min(float(self.expected_acceptance(s)), 1.0)
                        if self.expected_acceptance is not None else None)
            drift = (observed - expected
                     if observed is not None and expected is not None
                     else None)
            rows.append({
                "s": s, "batch": b, "steps": rec["steps"],
                "draws": rec["draws"],
                "mean_accepted": rec["accepted"] / max(rec["draws"], 1),
                "acceptance": observed, "expected": expected, "drift": drift,
                "hist": rec["hist"].tolist(),
                "mean_step_s": rec["time"] / max(rec["steps"], 1),
            })
        return rows

    def acceptance_drift(self) -> Optional[float]:
        """Draw-weighted mean observed-minus-predicted acceptance across all
        (s, batch) cells; None without a model or without observations."""
        num = den = 0.0
        for row in self.acceptance_table():
            if row["drift"] is not None:
                num += row["drift"] * row["draws"]
                den += row["draws"]
        return num / den if den else None

    # ------------------------------------------------------------------
    # expositions

    def prometheus_text(self) -> str:
        """Prometheus text exposition of counters, gauges, peaks, and the
        per-(s, batch) acceptance observatory."""
        out = ["# TYPE repro_serving_spans_total counter"]
        for phase in sorted(self.counters):
            out.append(f'repro_serving_spans_total{{phase="{phase}"}} '
                       f"{self.counters[phase]}")
        out.append("# TYPE repro_serving_tokens_committed_total counter")
        out.append(f"repro_serving_tokens_committed_total "
                   f"{self.tokens_committed}")
        out.append("# TYPE repro_serving_iterations_total counter")
        out.append(f"repro_serving_iterations_total {self.iterations}")
        for name in sorted(self.gauges):
            out.append(f"# TYPE repro_serving_{name} gauge")
            out.append(f"repro_serving_{name} {self.gauges[name]}")
        for name in sorted(self.peaks):
            out.append(f"# TYPE repro_serving_peak_{name} gauge")
            out.append(f"repro_serving_peak_{name} {self.peaks[name]}")
        acc = self.acceptance_table()
        if acc:
            out.append("# TYPE repro_serving_acceptance_observed gauge")
            for r in acc:
                if r["acceptance"] is not None:
                    out.append(
                        f'repro_serving_acceptance_observed{{s="{r["s"]}",'
                        f'batch="{r["batch"]}"}} {r["acceptance"]:.6f}')
            if any(r["drift"] is not None for r in acc):
                out.append("# TYPE repro_serving_acceptance_drift gauge")
                for r in acc:
                    if r["drift"] is not None:
                        out.append(
                            f'repro_serving_acceptance_drift{{s="{r["s"]}",'
                            f'batch="{r["batch"]}"}} {r["drift"]:+.6f}')
            out.append("# TYPE repro_serving_step_seconds gauge")
            for r in acc:
                out.append(f'repro_serving_step_seconds{{s="{r["s"]}",'
                           f'batch="{r["batch"]}"}} {r["mean_step_s"]:.6g}')
        return "\n".join(out) + "\n"

    def dashboard(self) -> str:
        """Multi-line console summary of the latest gauges, counters, and
        the busiest acceptance cells."""
        g = self.gauges
        lines = [f"── serving telemetry · iter {self.iterations} · "
                 f"clock {g.get('clock', 0.0):.3f}s ──"]
        occ = g.get("occupancy", 0)
        cap = g.get("capacity", "?")
        lines.append(
            f" slots {occ}/{cap} occupied · {g.get('prefilling', 0)} "
            f"prefilling · backlog {g.get('backlog', 0)} · decode batch "
            f"{g.get('decode_batch', 0)} (s={g.get('s', 0)})")
        if "free_blocks" in g:
            lines.append(
                f" blocks {g['free_blocks']} free / {g.get('used_blocks', 0)}"
                f" used · fragmentation {g.get('fragmentation', 0.0):.2f}"
                f" · grid occupancy {g.get('grid_occupancy', 0.0):.2f}")
        cnt = " ".join(f"{k}={v}" for k, v in sorted(self.counters.items()))
        lines.append(f" counters: {cnt or '(none)'} · tokens "
                     f"{self.tokens_committed}")
        acc = sorted(self.acceptance_table(), key=lambda r: -r["draws"])[:3]
        for r in acc:
            drift = ("" if r["drift"] is None
                     else f", drift {r['drift']:+.3f}")
            lines.append(
                f" acceptance s={r['s']} b={r['batch']}: "
                f"{r['acceptance']:.3f} over {r['draws']} draws{drift}")
        return "\n".join(lines)

    def summary(self) -> dict:
        """JSON-friendly roll-up (the serving benchmark embeds this)."""
        return {
            "iterations": self.iterations,
            "tokens_committed": self.tokens_committed,
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "peaks": dict(self.peaks),
            "acceptance": self.acceptance_table(),
            "acceptance_drift": self.acceptance_drift(),
        }

    # ------------------------------------------------------------------
    # persistence / profiler lifecycle

    def write_jsonl(self, path: str) -> None:
        """Dump the buffered event log to ``path`` (one JSON per line)."""
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev, default=float) + "\n")

    def start(self) -> None:
        """Begin the jax profiler trace when ``profile_dir`` is set (no-op
        otherwise); the serving entry points call this around the run."""
        if self.enabled and self.profile_dir and not self._profiling:
            import jax
            jax.profiler.start_trace(self.profile_dir)
            self._profiling = True

    def stop(self) -> None:
        if self._profiling:
            import jax
            jax.profiler.stop_trace()
            self._profiling = False
        if self._jsonl is not None:
            self._jsonl.flush()

    def close(self) -> None:
        self.stop()
        if self._jsonl is not None:
            self._jsonl.close()
            self._jsonl = None
