"""Paged-attention kernel microbenchmark: materialized gather vs the fused
block-table-streaming Pallas kernel, across a (batch, s, blocks) grid.

Measures, per shape:

* ``fused_us``          — the fused kernel (kernels/paged_verify_attn.py);
                          native on TPU, interpret mode elsewhere
* ``gather_pallas_us``  — gather the logical view, then the shared Pallas
                          verify kernel at the *matched* tile size
                          (``block_k = block_size``) — the apples-to-apples
                          "same tiles, plus the copy" baseline
* ``gather_ref_us``     — gather + the pure-XLA reference attention (the
                          CPU serving path)
* ``gather_view_bytes`` — the transient ``[B, MAXB*bs, KVH, hd]`` k+v copy
                          the gather path materializes per call (and per
                          layer, per step, on the serving path) — the
                          fused path's figure is 0 by construction
* ``*_temp_bytes``      — XLA's compiled temp-allocation sizes where the
                          backend reports them
* ``fused/gather_materializes`` — jaxpr inspection: does any op output a
                          ``MAXB*bs``-row logical view?  Must be False for
                          the fused path (the kernel's whole point) and
                          True for the gather path (keeps the check
                          honest).

``--check`` is the CI smoke mode: on the reference shape it exits nonzero
if the fused path materializes a gathered view, if the gather path
mysteriously stops materializing one (the check would be vacuous), or if
the fused kernel is slower than gather+verify at matched tiles — so a perf
regression on the hot path fails loudly.  Off-TPU both paths execute in
interpret mode, which prices grid steps rather than HBM, so the matched-
tile comparison is the meaningful one there; on TPU the same code compares
the native kernels.  Results land in results/BENCH_kernels.json.

  PYTHONPATH=src python benchmarks/kernel_bench.py [--quick] [--check]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:           # `python benchmarks/kernel_bench.py`
    sys.path.insert(0, _ROOT)       # puts benchmarks/ first, not the root

from repro.kernels.paged import gather_verify_attn, paged_verify_attn
from tools.graphlint.passes.materialize import find_gathered_views

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
OUT_PATH = os.path.join(RESULTS, "BENCH_kernels.json")

# model-ish head geometry (bench-scale): 4 q heads over 2 kv heads, hd=64
H, KVH, HD = 4, 2, 64
BLOCK_SIZE = 16
CHECK_SHAPE = (4, 3, 8)                  # (batch, s, max_blocks) for --check


def build_case(B: int, s: int, MAXB: int, bs: int = BLOCK_SIZE,
               seed: int = 0):
    """A ragged paged pool + verify-step inputs for one grid point."""
    rng = np.random.default_rng(seed)
    T = s + 1
    NB = B * MAXB + 4                    # slack blocks (unowned => garbage)
    k = jnp.asarray(rng.normal(size=(NB, bs, KVH, HD)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(NB, bs, KVH, HD)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, T, H, HD)), jnp.float32)
    bt = np.full((B, MAXB), -1, np.int32)
    pos = np.full((NB, bs), -1, np.int32)
    order = rng.permutation(NB)
    nxt = 0
    lens = rng.integers(max(1, (MAXB - 2) * bs), MAXB * bs - T, size=B)
    for b, L in enumerate(lens):
        for j in range(-(-int(L) // bs)):
            pb = int(order[nxt]); nxt += 1
            bt[b, j] = pb
            rows = np.arange(bs) + j * bs
            write = rows < L
            pos[pb, write[: bs].nonzero()[0]] = rows[write]
    qp = jnp.asarray(np.stack([np.arange(T, dtype=np.int32) + int(L) - 1
                               for L in lens]))
    return q, k, v, qp, jnp.asarray(pos), jnp.asarray(bt)


def best_us(fn, args, repeats: int = 7, inner: int = 10) -> float:
    """Best-of-N timing: the min over repeats is the standard noise-robust
    microbenchmark estimator (scheduler contention only ever adds time)."""
    fn(*args).block_until_ready()        # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        out.block_until_ready()
        ts.append((time.perf_counter() - t0) / inner)
    return float(np.min(ts) * 1e6)


def materializes_view(fn, args, B: int, MAXB: int, bs: int) -> bool:
    """True iff the traced computation builds a [.., MAXB*bs, ..] logical
    view (the gathered copy the fused kernel exists to eliminate).

    Detection lives in tools/graphlint (the engine-level
    no-materialization pass uses the same ``find_gathered_views`` over
    every registered step/chunk jit); here the bare kernel call is the
    whole trace, so no trailing-dims narrowing is needed."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return bool(find_gathered_views(jaxpr.jaxpr, MAXB * bs))


def temp_bytes(fn, args) -> Optional[int]:
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(ma.temp_size_in_bytes)
    except Exception:
        return None


def bench_case(B: int, s: int, MAXB: int, bs: int = BLOCK_SIZE) -> Dict:
    q, k, v, qp, pos, bt = build_case(B, s, MAXB, bs)
    fused = jax.jit(lambda *a: paged_verify_attn(*a, use_pallas=True))
    gpal = jax.jit(lambda *a: gather_verify_attn(*a, use_pallas=True,
                                                 block_k=bs))
    gref = jax.jit(lambda *a: gather_verify_attn(*a, use_pallas=False))
    args = (q, k, v, qp, pos, bt)

    # parity first: a microbenchmark of a wrong kernel is worse than none
    np.testing.assert_allclose(np.asarray(fused(*args)),
                               np.asarray(gref(*args)), rtol=2e-4, atol=2e-4)

    itemsize = np.dtype(np.float32).itemsize
    view_bytes = 2 * B * MAXB * bs * KVH * HD * itemsize   # k + v copies
    rec = {
        "batch": B, "s": s, "max_blocks": MAXB, "block_size": bs,
        "kv_heads": KVH, "q_heads": H, "head_dim": HD,
        "fused_us": best_us(fused, args),
        "gather_pallas_us": best_us(gpal, args),
        "gather_ref_us": best_us(gref, args),
        "gather_view_bytes": view_bytes,
        "fused_view_bytes": 0,
        "fused_temp_bytes": temp_bytes(
            lambda *a: paged_verify_attn(*a, use_pallas=True), args),
        "gather_ref_temp_bytes": temp_bytes(
            lambda *a: gather_verify_attn(*a, use_pallas=False), args),
        "fused_materializes": materializes_view(
            lambda *a: paged_verify_attn(*a, use_pallas=True),
            args, B, MAXB, bs),
        "gather_materializes": materializes_view(
            lambda *a: gather_verify_attn(*a, use_pallas=False),
            args, B, MAXB, bs),
    }
    rec["fused_vs_gather_pallas"] = (
        rec["gather_pallas_us"] / max(rec["fused_us"], 1e-9))
    return rec


def run(quick: bool = False, check: bool = False) -> Dict:
    on_tpu = jax.default_backend() == "tpu"
    if check or quick:
        grid: List[Tuple[int, int, int]] = [CHECK_SHAPE]
        if quick and not check:
            grid += [(1, 1, 4)]
    else:
        grid = [(B, s, MAXB)
                for B in (1, 4, 8)
                for s in (1, 3)
                for MAXB in (4, 8, 16)]
    records = [bench_case(B, s, MAXB) for (B, s, MAXB) in grid]

    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "execution": "native" if on_tpu else "interpret",
            "note": ("off-TPU the Pallas kernels run in interpret mode, "
                     "which prices grid steps rather than HBM traffic; "
                     "gather_pallas_us uses the matched tile size "
                     "block_k=block_size so fused-vs-gather compares the "
                     "same tiles with and without the materialized copy"),
            "block_size": BLOCK_SIZE,
            "check_shape": list(CHECK_SHAPE),
        },
        "grid": records,
    }

    problems = []
    ref = next(r for r in records
               if (r["batch"], r["s"], r["max_blocks"]) == CHECK_SHAPE)
    if ref["fused_materializes"]:
        problems.append("fused path materializes a gathered KV view")
    if not ref["gather_materializes"]:
        problems.append("gather path no longer materializes a view — the "
                        "no-materialization check is vacuous")
    # native TPU timings are stable: 10% headroom over best-of-N.  Interpret
    # mode prices Python grid steps, not HBM, and is contention-sensitive,
    # so off-TPU the gate only trips at the >=2x an actual regression (the
    # fused path re-growing a gather, tiling collapse) actually produces —
    # the materialization checks above stay hard either way
    factor = 1.10 if on_tpu else 2.0
    if ref["fused_us"] > factor * ref["gather_pallas_us"]:
        problems.append(
            f"fused kernel slower than gather+verify on the reference "
            f"shape: {ref['fused_us']:.0f}us vs "
            f"{ref['gather_pallas_us']:.0f}us")
    payload["check"] = {"ok": not problems, "problems": problems}

    # --check / --quick are smoke gates, not the artifact: never clobber an
    # existing full-grid BENCH_kernels.json with their 1-2 point grids
    os.makedirs(RESULTS, exist_ok=True)
    if not (check or quick) or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(payload, f, indent=1, default=float)
        print(f"wrote {os.path.relpath(OUT_PATH)} "
              f"({len(records)} grid points, backend={jax.default_backend()})")
    else:
        print(f"kept existing {os.path.relpath(OUT_PATH)} "
              f"(smoke mode, {len(records)} grid points measured)")
    for r in records:
        print(f"  B={r['batch']} s={r['s']} blocks={r['max_blocks']}: "
              f"fused {r['fused_us']:.0f}us  gather+pallas "
              f"{r['gather_pallas_us']:.0f}us  gather-ref "
              f"{r['gather_ref_us']:.0f}us  view {r['gather_view_bytes']}B")
    if problems:
        for p in problems:
            print(f"CHECK FAILED: {p}")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reference shape + one small point only")
    ap.add_argument("--check", action="store_true",
                    help="smoke mode: reference shape only; exit nonzero "
                         "if the fused path regresses (slower than gather, "
                         "or materializes the view)")
    args = ap.parse_args(argv)
    payload = run(quick=args.quick, check=args.check)
    if args.check and not payload["check"]["ok"]:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
