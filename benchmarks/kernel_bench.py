"""Paged-attention kernel microbenchmark: materialized gather vs the fused
block-table-streaming Pallas kernel vs the RAGGED real-length-grid kernel,
across a (batch, s, blocks) grid.

Measures, per shape:

* ``fused_us``          — the dense fused kernel (``B * MAXB`` grid;
                          kernels/paged_verify_attn.py); native on TPU,
                          interpret mode elsewhere
* ``ragged_us``         — the ragged kernel: grid sized by the REAL
                          allocated blocks via the scalar-prefetched
                          ``cu_blocks`` plan (kernels/tuning.py)
* ``grid_steps_dense`` / ``grid_steps_ragged`` / ``dead_tile_fraction``
                        — the launch-grid accounting for the case's block
                          tables: how many tiles the dense grid wastes on
                          ``-1`` entries and how many the ragged grid
                          actually launches
* ``gather_pallas_us``  — gather the logical view, then the shared Pallas
                          verify kernel at the *matched* tile size
                          (``block_k = block_size``) — the apples-to-apples
                          "same tiles, plus the copy" baseline
* ``gather_ref_us``     — gather + the pure-XLA reference attention (the
                          CPU serving path)
* ``gather_view_bytes`` — the transient ``[B, MAXB*bs, KVH, hd]`` k+v copy
                          the gather path materializes per call (and per
                          layer, per step, on the serving path) — the
                          fused path's figure is 0 by construction
* ``*_temp_bytes``      — XLA's compiled temp-allocation sizes where the
                          backend reports them
* ``fused/gather_materializes`` — jaxpr inspection: does any op output a
                          ``MAXB*bs``-row logical view?  Must be False for
                          the fused path (the kernel's whole point) and
                          True for the gather path (keeps the check
                          honest).

``--autotune`` searches the ragged kernel's launch knobs (``num_buffers``
manual-DMA depth x ``vmem_limit_bytes``) per grid cell and caches the
winners under ``"autotune"`` in results/BENCH_kernels.json — the serving
dispatch (kernels/tuning.py ``lookup_config``) reads exactly that section,
so re-tuning here retunes serving.  ``--profile-dma`` additionally times
the manual-DMA path's ``profile='dma'`` / ``profile='compute'`` variants
(each skips the other half of the loop body), splitting tile-stream time
from flash-tile compute time per cell.

``--check`` is the CI smoke mode: on the reference shape it exits nonzero
if the fused path materializes a gathered view, if the gather path
mysteriously stops materializing one (the check would be vacuous), if the
fused kernel is slower than gather+verify at matched tiles, or if the
ragged grid regresses — its step count must stay strictly below the dense
``B * MAXB`` count on the (deterministically ragged) reference shape AND
match the block tables' ``sum(max(live, 1))`` exactly, so the real-length
grid failing back to dense launches fails loudly.  Off-TPU both paths
execute in interpret mode, which prices grid steps rather than HBM, so the
matched-tile and grid-step comparisons are the meaningful ones there; on
TPU the same code compares the native kernels.  Results land in
results/BENCH_kernels.json.

  PYTHONPATH=src python benchmarks/kernel_bench.py \
      [--quick] [--check] [--autotune] [--profile-dma]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:           # `python benchmarks/kernel_bench.py`
    sys.path.insert(0, _ROOT)       # puts benchmarks/ first, not the root

from repro.kernels.paged import gather_verify_attn, paged_verify_attn
from repro.kernels.tuning import (RaggedConfig, SEARCH_NUM_BUFFERS,
                                  SEARCH_VMEM_LIMITS, cell_key,
                                  clear_config_cache, dead_tile_fraction,
                                  grid_steps_dense, grid_steps_ragged,
                                  host_cu_blocks)
from tools.graphlint.passes.materialize import find_gathered_views

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
OUT_PATH = os.path.join(RESULTS, "BENCH_kernels.json")

# model-ish head geometry (bench-scale): 4 q heads over 2 kv heads, hd=64
H, KVH, HD = 4, 2, 64
BLOCK_SIZE = 16
CHECK_SHAPE = (4, 3, 8)                  # (batch, s, max_blocks) for --check


def build_case(B: int, s: int, MAXB: int, bs: int = BLOCK_SIZE,
               seed: int = 0):
    """A ragged paged pool + verify-step inputs for one grid point."""
    rng = np.random.default_rng(seed)
    T = s + 1
    NB = B * MAXB + 4                    # slack blocks (unowned => garbage)
    k = jnp.asarray(rng.normal(size=(NB, bs, KVH, HD)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(NB, bs, KVH, HD)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, T, H, HD)), jnp.float32)
    bt = np.full((B, MAXB), -1, np.int32)
    pos = np.full((NB, bs), -1, np.int32)
    order = rng.permutation(NB)
    nxt = 0
    lens = rng.integers(max(1, (MAXB - 2) * bs), MAXB * bs - T, size=B)
    for b, L in enumerate(lens):
        for j in range(-(-int(L) // bs)):
            pb = int(order[nxt]); nxt += 1
            bt[b, j] = pb
            rows = np.arange(bs) + j * bs
            write = rows < L
            pos[pb, write[: bs].nonzero()[0]] = rows[write]
    qp = jnp.asarray(np.stack([np.arange(T, dtype=np.int32) + int(L) - 1
                               for L in lens]))
    return q, k, v, qp, jnp.asarray(pos), jnp.asarray(bt)


def best_us(fn, args, repeats: int = 7, inner: int = 10) -> float:
    """Best-of-N timing: the min over repeats is the standard noise-robust
    microbenchmark estimator (scheduler contention only ever adds time)."""
    fn(*args).block_until_ready()        # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        out.block_until_ready()
        ts.append((time.perf_counter() - t0) / inner)
    return float(np.min(ts) * 1e6)


def materializes_view(fn, args, B: int, MAXB: int, bs: int) -> bool:
    """True iff the traced computation builds a [.., MAXB*bs, ..] logical
    view (the gathered copy the fused kernel exists to eliminate).

    Detection lives in tools/graphlint (the engine-level
    no-materialization pass uses the same ``find_gathered_views`` over
    every registered step/chunk jit); here the bare kernel call is the
    whole trace, so no trailing-dims narrowing is needed."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return bool(find_gathered_views(jaxpr.jaxpr, MAXB * bs))


def temp_bytes(fn, args) -> Optional[int]:
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(ma.temp_size_in_bytes)
    except Exception:
        return None


def _profiled_ragged(q, k, v, qp, pos, bt, cu, *, config, profile):
    from repro.kernels.paged_verify_attn import ragged_paged_verify_attn_pallas
    return ragged_paged_verify_attn_pallas(
        q, k, v, qp, pos, bt, cu,
        num_buffers=config.num_buffers,
        vmem_limit_bytes=config.vmem_limit_bytes,
        profile=profile, interpret=jax.default_backend() != "tpu")


def _ragged_fn(config: RaggedConfig, profile: Optional[str] = None):
    """A jitted ragged-kernel closure with the launch knobs pinned (the
    explicit ``config`` bypasses the autotune-cache lookup, so the bench
    measures exactly the knobs it thinks it measures)."""
    if profile is None:
        return jax.jit(lambda *a: paged_verify_attn(
            *a[:6], use_pallas=True, cu_blocks=a[6], config=config))
    return jax.jit(lambda *a: _profiled_ragged(*a, config=config,
                                               profile=profile))


def bench_case(B: int, s: int, MAXB: int, bs: int = BLOCK_SIZE,
               config: Optional[RaggedConfig] = None,
               profile_dma: bool = False) -> Dict:
    q, k, v, qp, pos, bt = build_case(B, s, MAXB, bs)
    fused = jax.jit(lambda *a: paged_verify_attn(*a, use_pallas=True))
    gpal = jax.jit(lambda *a: gather_verify_attn(*a, use_pallas=True,
                                                 block_k=bs))
    gref = jax.jit(lambda *a: gather_verify_attn(*a, use_pallas=False))
    args = (q, k, v, qp, pos, bt)
    tables = np.asarray(bt)
    cu = jnp.asarray(host_cu_blocks(tables))
    config = config or RaggedConfig()
    ragged = _ragged_fn(config)
    rargs = args + (cu,)

    # parity first: a microbenchmark of a wrong kernel is worse than none
    ref_out = np.asarray(gref(*args))
    np.testing.assert_allclose(np.asarray(fused(*args)), ref_out,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(ragged(*rargs)), ref_out,
                               rtol=2e-4, atol=2e-4)

    itemsize = np.dtype(np.float32).itemsize
    view_bytes = 2 * B * MAXB * bs * KVH * HD * itemsize   # k + v copies
    rec = {
        "batch": B, "s": s, "max_blocks": MAXB, "block_size": bs,
        "kv_heads": KVH, "q_heads": H, "head_dim": HD,
        "fused_us": best_us(fused, args),
        "ragged_us": best_us(ragged, rargs),
        "ragged_config": config.to_json(),
        "grid_steps_dense": grid_steps_dense(tables),
        "grid_steps_ragged": grid_steps_ragged(tables),
        "dead_tile_fraction": dead_tile_fraction(tables),
        "gather_pallas_us": best_us(gpal, args),
        "gather_ref_us": best_us(gref, args),
        "gather_view_bytes": view_bytes,
        "fused_view_bytes": 0,
        "fused_temp_bytes": temp_bytes(
            lambda *a: paged_verify_attn(*a, use_pallas=True), args),
        "gather_ref_temp_bytes": temp_bytes(
            lambda *a: gather_verify_attn(*a, use_pallas=False), args),
        "fused_materializes": materializes_view(
            lambda *a: paged_verify_attn(*a, use_pallas=True),
            args, B, MAXB, bs),
        "gather_materializes": materializes_view(
            lambda *a: gather_verify_attn(*a, use_pallas=False),
            args, B, MAXB, bs),
    }
    rec["fused_vs_gather_pallas"] = (
        rec["gather_pallas_us"] / max(rec["fused_us"], 1e-9))
    rec["ragged_vs_fused"] = rec["fused_us"] / max(rec["ragged_us"], 1e-9)
    if profile_dma:
        # DMA-vs-compute split: each profile variant skips the OTHER half
        # of the manual-DMA loop body, so the pair brackets where the
        # per-tile time goes.  Needs the manual-DMA path (depth >= 2).
        pcfg = (config if config.num_buffers >= 2
                else RaggedConfig(num_buffers=2,
                                  vmem_limit_bytes=config.vmem_limit_bytes))
        rec["profile_config"] = pcfg.to_json()
        rec["ragged_dma_us"] = best_us(
            _ragged_fn(pcfg, profile="dma"), rargs, repeats=3, inner=3)
        rec["ragged_compute_us"] = best_us(
            _ragged_fn(pcfg, profile="compute"), rargs, repeats=3, inner=3)
    return rec


def autotune_case(B: int, s: int, MAXB: int, bs: int = BLOCK_SIZE) -> Dict:
    """Search the ragged launch knobs for one ``(B, T, MAXB)`` cell; the
    winner is what ``lookup_config`` hands the serving dispatch."""
    q, k, v, qp, pos, bt = build_case(B, s, MAXB, bs)
    cu = jnp.asarray(host_cu_blocks(np.asarray(bt)))
    rargs = (q, k, v, qp, pos, bt, cu)
    vmem_limits = (SEARCH_VMEM_LIMITS if jax.default_backend() == "tpu"
                   else (None,))   # interpret mode ignores the VMEM budget
    trials = []
    for nbuf in SEARCH_NUM_BUFFERS:
        for vmem in vmem_limits:
            cfg = RaggedConfig(num_buffers=nbuf, vmem_limit_bytes=vmem)
            us = best_us(_ragged_fn(cfg), rargs, repeats=3, inner=3)
            trials.append((us, cfg))
    best = min(trials, key=lambda t: t[0])
    return {
        "config": best[1].to_json(),
        "us": best[0],
        "searched": len(trials),
        "trials": [{"config": c.to_json(), "us": u} for u, c in trials],
    }


def run(quick: bool = False, check: bool = False, autotune: bool = False,
        profile_dma: bool = False) -> Dict:
    on_tpu = jax.default_backend() == "tpu"
    if check or quick:
        grid: List[Tuple[int, int, int]] = [CHECK_SHAPE]
        if quick and not check:
            grid += [(1, 1, 4)]
    else:
        grid = [(B, s, MAXB)
                for B in (1, 4, 8)
                for s in (1, 3)
                for MAXB in (4, 8, 16)]

    # autotune first, bench each cell at its tuned knobs (what serving runs)
    tuned: Dict[str, Dict] = {}
    if autotune:
        for (B, s, MAXB) in grid:
            tuned[cell_key(B, s + 1, MAXB)] = autotune_case(B, s, MAXB)
    records = []
    for (B, s, MAXB) in grid:
        rec_cfg = tuned.get(cell_key(B, s + 1, MAXB))
        cfg = (RaggedConfig.from_json(rec_cfg["config"])
               if rec_cfg is not None else None)
        records.append(bench_case(B, s, MAXB, config=cfg,
                                  profile_dma=profile_dma))

    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "execution": "native" if on_tpu else "interpret",
            "note": ("off-TPU the Pallas kernels run in interpret mode, "
                     "which prices grid steps rather than HBM traffic; "
                     "gather_pallas_us uses the matched tile size "
                     "block_k=block_size so fused-vs-gather compares the "
                     "same tiles with and without the materialized copy; "
                     "ragged_us runs the real-length-grid kernel at the "
                     "autotuned (or default) launch knobs"),
            "block_size": BLOCK_SIZE,
            "check_shape": list(CHECK_SHAPE),
        },
        "grid": records,
    }
    # the autotune section IS the serving dispatch table
    # (kernels/tuning.py lookup_config) — keep the existing one when this
    # invocation did not re-tune, so a smoke run can't drop tuned configs
    if tuned:
        payload["autotune"] = tuned
    elif os.path.exists(OUT_PATH):
        try:
            with open(OUT_PATH, encoding="utf-8") as f:
                prev = json.load(f).get("autotune")
            if prev:
                payload["autotune"] = prev
        except (OSError, ValueError):
            pass

    problems = []
    ref = next(r for r in records
               if (r["batch"], r["s"], r["max_blocks"]) == CHECK_SHAPE)
    if ref["fused_materializes"]:
        problems.append("fused path materializes a gathered KV view")
    if not ref["gather_materializes"]:
        problems.append("gather path no longer materializes a view — the "
                        "no-materialization check is vacuous")
    # native TPU timings are stable: 10% headroom over best-of-N.  Interpret
    # mode prices Python grid steps, not HBM, and is contention-sensitive,
    # so off-TPU the gate only trips at the >=2x an actual regression (the
    # fused path re-growing a gather, tiling collapse) actually produces —
    # the materialization checks above stay hard either way
    factor = 1.10 if on_tpu else 2.0
    if ref["fused_us"] > factor * ref["gather_pallas_us"]:
        problems.append(
            f"fused kernel slower than gather+verify on the reference "
            f"shape: {ref['fused_us']:.0f}us vs "
            f"{ref['gather_pallas_us']:.0f}us")
    # ragged-grid gates: the reference shape is deterministically ragged
    # (seeded lens), so the real-length grid must launch strictly fewer
    # steps than the dense B*MAXB grid — and exactly the tables'
    # sum(max(live, 1)), else the cu_blocks plan drifted from the kernel
    if ref["grid_steps_ragged"] >= ref["grid_steps_dense"]:
        problems.append(
            f"ragged grid launches {ref['grid_steps_ragged']} steps, not "
            f"below the dense {ref['grid_steps_dense']} — the real-length "
            f"grid regressed to dense launches")
    _, _, _, _, _, chk_bt = build_case(*CHECK_SHAPE)
    expect = int(np.maximum((np.asarray(chk_bt) >= 0).sum(axis=1), 1).sum())
    if ref["grid_steps_ragged"] != expect:
        problems.append(
            f"ragged grid-step count {ref['grid_steps_ragged']} does not "
            f"match the block tables' live count {expect} — the cu_blocks "
            f"plan drifted from the tables")
    if ref["ragged_us"] > factor * ref["fused_us"]:
        problems.append(
            f"ragged kernel slower than the dense fused kernel on the "
            f"reference shape: {ref['ragged_us']:.0f}us vs "
            f"{ref['fused_us']:.0f}us — fewer grid steps should never "
            f"cost more")
    payload["check"] = {"ok": not problems, "problems": problems}

    # --check / --quick are smoke gates, not the artifact: never clobber an
    # existing full-grid BENCH_kernels.json with their 1-2 point grids
    os.makedirs(RESULTS, exist_ok=True)
    if not (check or quick) or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(payload, f, indent=1, default=float)
        print(f"wrote {os.path.relpath(OUT_PATH)} "
              f"({len(records)} grid points, backend={jax.default_backend()})")
    elif tuned:
        # smoke grid + --autotune: merge the newly tuned cells into the
        # existing full-grid artifact instead of clobbering it
        with open(OUT_PATH, encoding="utf-8") as f:
            existing = json.load(f)
        existing.setdefault("autotune", {}).update(tuned)
        with open(OUT_PATH, "w") as f:
            json.dump(existing, f, indent=1, default=float)
        print(f"merged {len(tuned)} autotuned cell(s) into "
              f"{os.path.relpath(OUT_PATH)} (smoke mode)")
    else:
        print(f"kept existing {os.path.relpath(OUT_PATH)} "
              f"(smoke mode, {len(records)} grid points measured)")
    for r in records:
        extra = ""
        if "ragged_dma_us" in r:
            extra = (f"  dma {r['ragged_dma_us']:.0f}us / compute "
                     f"{r['ragged_compute_us']:.0f}us")
        print(f"  B={r['batch']} s={r['s']} blocks={r['max_blocks']}: "
              f"fused {r['fused_us']:.0f}us  ragged {r['ragged_us']:.0f}us "
              f"(grid {r['grid_steps_ragged']}/{r['grid_steps_dense']}, "
              f"dead {r['dead_tile_fraction']:.2f})  gather+pallas "
              f"{r['gather_pallas_us']:.0f}us  gather-ref "
              f"{r['gather_ref_us']:.0f}us  view {r['gather_view_bytes']}B"
              + extra)
    if tuned:
        # new configs are live for the NEXT lookup in this process too
        clear_config_cache()
        for key, rec_cfg in sorted(tuned.items()):
            print(f"  autotune {key}: {rec_cfg['config']} "
                  f"({rec_cfg['us']:.0f}us over {rec_cfg['searched']} "
                  f"trials)")
    if problems:
        for p in problems:
            print(f"CHECK FAILED: {p}")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reference shape + one small point only")
    ap.add_argument("--check", action="store_true",
                    help="smoke mode: reference shape only; exit nonzero "
                         "if the fused path regresses (slower than gather, "
                         "materializes the view, or the ragged grid stops "
                         "tracking real block counts)")
    ap.add_argument("--autotune", action="store_true",
                    help="search the ragged kernel's launch knobs per grid "
                         "cell and cache the winners into "
                         "results/BENCH_kernels.json (the serving dispatch "
                         "table)")
    ap.add_argument("--profile-dma", action="store_true",
                    help="also time the manual-DMA path's profile='dma' / "
                         "'compute' variants (DMA-vs-compute split)")
    args = ap.parse_args(argv)
    payload = run(quick=args.quick, check=args.check,
                  autotune=args.autotune, profile_dma=args.profile_dma)
    if args.check and not payload["check"]["ok"]:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
