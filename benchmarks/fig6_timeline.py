"""Fig. 6: latency timeline under alternating intense/sparse traffic for the
four schemes; adaptive must track whichever fixed scheme currently wins.

The client alternates every `period` between intense (0.25x base interval)
and sparse (2.5x base interval), CV = 1 — the scaled analogue of the paper's
0.2 s / 1.0 s alternation every 50 s.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import VOCAB, write_result
from benchmarks.fig5_dynamic import MAX_BATCH, MAX_NEW, build_model_from_measurements, schemes
from repro.serving.metrics import summarize, timeline_groups
from repro.serving.server import SimBackend, serve
from repro.serving.traffic import alternating_traffic


def run(n_requests: int = 1000, group: int = 40, quick: bool = False) -> Dict:
    if quick:
        n_requests, group = 240, 20
    model = build_model_from_measurements(quick=quick)
    ctrls, lut = schemes(model)
    b0 = MAX_BATCH // 2
    base = model.per_token_time(b0, lut.lookup(b0)) * MAX_NEW
    period = base * 60
    results, timelines = {}, {}
    for name, ctrl in ctrls.items():
        reqs = alternating_traffic(n_requests, VOCAB, seed=42,
                                   intense=0.25 * base, sparse=2.5 * base,
                                   period=period, cv=1.0, max_new=MAX_NEW)
        res = serve(reqs, SimBackend(model, seed=1), ctrl, max_batch=MAX_BATCH)
        results[name] = summarize(res).mean
        timelines[name] = timeline_groups(res, group=group)

    # adaptive vs pointwise best/worst fixed scheme per group
    f2 = np.array([v for _, v in timelines["fixed_s2"]])
    f4 = np.array([v for _, v in timelines["fixed_s4"]])
    ad = np.array([v for _, v in timelines["adaptive"]])
    n = min(len(f2), len(f4), len(ad))
    f2, f4, ad = f2[:n], f4[:n], ad[:n]
    tracks_best = float(np.mean(ad <= np.minimum(f2, f4) * 1.05))
    gain_s2 = float(np.mean(f2) / np.mean(ad))
    gain_s4 = float(np.mean(f4) / np.mean(ad))
    payload = {
        "mean_latency": results,
        "timeline": {k: [[float(t), float(v)] for t, v in tl]
                     for k, tl in timelines.items()},
        "adaptive_tracks_best_frac": tracks_best,
        "gain_vs_fixed_s2": gain_s2, "gain_vs_fixed_s4": gain_s4,
        "period_s": period,
    }
    write_result("fig6_timeline", payload)
    print("\n=== Fig.6: alternating traffic timeline ===")
    print({k: round(v, 4) for k, v in results.items()})
    print(f"adaptive <= best fixed in {tracks_best*100:.0f}% of groups; "
          f"mean gain vs s=2: {gain_s2:.2f}x, vs s=4: {gain_s4:.2f}x "
          f"(paper: 9% and 14%)")
    return payload


if __name__ == "__main__":
    run()
