"""Roofline table: read results/dryrun/*.json (produced by
``python -m repro.launch.dryrun``) and print/emit the per-(arch x shape x
mesh) three-term roofline with the dominant bottleneck.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import RESULTS, write_result

DRYRUN_DIR = os.path.join(RESULTS, "dryrun")


def load_records(mesh: str = None) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(p))
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def run(quick: bool = False) -> Dict:
    recs = load_records()
    if not recs:
        print("\n=== Roofline: no dry-run records yet "
              "(run python -m repro.launch.dryrun --all) ===")
        return {}
    rows = []
    for r in recs:
        rf, an = r["roofline"], r["analytic"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "kind": r["kind"], "chips": r["chips"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "useful_ratio": an["useful_compute_ratio"],
            "mem_per_dev_gib": r["memory"]["per_device_total"] / 2**30,
            "arg_per_dev_gib": r["memory"]["argument_bytes"] / 2**30,
        })
    payload = {"rows": rows, "n": len(rows)}
    write_result("roofline", payload)
    print("\n=== Roofline (from dry-run artifacts) ===")
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':9s} {'compute':>10s} "
           f"{'memory':>10s} {'collectv':>10s}  dom       {'useful':>6s} {'GiB/dev':>8s}")
    print(hdr)
    for x in sorted(rows, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        print(f"{x['arch']:24s} {x['shape']:12s} {x['mesh']:9s} "
              f"{x['compute_s']:10.3e} {x['memory_s']:10.3e} "
              f"{x['collective_s']:10.3e}  {x['dominant']:9s} "
              f"{x['useful_ratio']:6.2f} {x['arg_per_dev_gib']:8.2f}")
    return payload


if __name__ == "__main__":
    run()
