"""Fig. 7 (ours, beyond-paper): iteration-level continuous batching x
adaptive speculation.

The paper's server (§5.3) runs each merged batch to completion; Orca-style
continuous batching admits/retires requests at speculative-step granularity,
so the controller re-chooses s from the LIVE batch size each iteration.
Same latency model, same stochastic acceptance, same traces as Fig. 5 —
only the scheduling policy changes.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import VOCAB, write_result
from benchmarks.fig5_dynamic import (MAX_BATCH, MAX_NEW,
                                     build_model_from_measurements, schemes)
from repro.serving.metrics import summarize
from repro.serving.server import SimBackend, serve, serve_continuous
from repro.serving.traffic import uniform_traffic


def run(n_requests: int = 600, cvs=(1.0, 5.0),
        interval_mults=(0.25, 0.5, 1.0, 2.0, 4.0), quick: bool = False) -> Dict:
    if quick:
        n_requests, cvs, interval_mults = 150, (2.0,), (0.5, 2.0)
    model = build_model_from_measurements(quick=quick)
    ctrls, lut = schemes(model)
    b0 = MAX_BATCH // 2
    base = model.per_token_time(b0, lut.lookup(b0)) * MAX_NEW
    grid: Dict[str, Dict[str, float]] = {}
    for cv in cvs:
        for m in interval_mults:
            key = f"cv={cv}_int={m}x"
            cell = {}
            for name, ctrl in ctrls.items():
                reqs = uniform_traffic(n_requests, base * m, cv, VOCAB,
                                       seed=42, max_new=MAX_NEW)
                res = serve(reqs, SimBackend(model, seed=1), ctrl,
                            max_batch=MAX_BATCH)
                cell[f"rtc/{name}"] = summarize(res).mean
                reqs = uniform_traffic(n_requests, base * m, cv, VOCAB,
                                       seed=42, max_new=MAX_NEW)
                res = serve_continuous(reqs, model, ctrl,
                                       max_batch=MAX_BATCH, seed=1)
                cell[f"cont/{name}"] = summarize(res).mean
            grid[key] = cell
    gain_adaptive = float(np.mean([c["rtc/adaptive"] / c["cont/adaptive"]
                                   for c in grid.values()]))
    cont_ad_vs_fixed = float(np.mean(
        [min(c["cont/fixed_s2"], c["cont/fixed_s4"]) / c["cont/adaptive"]
         for c in grid.values()]))
    payload = {
        "grid": grid,
        "continuous_gain_at_adaptive": gain_adaptive,
        "cont_adaptive_vs_cont_best_fixed": cont_ad_vs_fixed,
    }
    write_result("fig7_continuous", payload)
    print("\n=== Fig.7 (ours): continuous batching x adaptive speculation ===")
    names = list(next(iter(grid.values())))
    print(f"{'cell':>16s}  " + "".join(f"{n:>16s}" for n in names))
    for key, cell in grid.items():
        print(f"{key:>16s}  " + "".join(f"{cell[n]:16.4f}" for n in names))
    print(f"continuous vs run-to-completion (adaptive): {gain_adaptive:.2f}x; "
          f"adaptive still >= best fixed under continuous: "
          f"{cont_ad_vs_fixed:.2f}x")
    return payload


if __name__ == "__main__":
    run()
