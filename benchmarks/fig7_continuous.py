"""Fig. 7 (ours, beyond-paper): iteration-level continuous batching x
adaptive speculation.

The paper's server (§5.3) runs each merged batch to completion; Orca-style
continuous batching admits/retires requests at speculative-step granularity,
so the controller re-chooses s from the LIVE batch size each iteration.
Same latency model, same stochastic acceptance, same traces as Fig. 5 —
only the scheduling policy changes.

``--live`` runs the same study on a REAL SpecDecodeEngine (the trained
benchmark pair) through serving/scheduler.py's slot-pool runtime: a 100+-
request Poisson trace with requests joining/leaving at speculative-step
granularity, wall-clock timed, plus a sim-vs-live scheduling parity check
(replayed acceptance) and the run-to-completion comparison on a bursty
trace at equal max_batch.

``--live`` additionally runs the paged-KV study: a mixed short/long-prompt
trace served (a) on the contiguous slot pool, where every slot pays the
longest request's worst-case ``cache_len``, and (b) on the paged block
pool at EQUAL total KV memory, where short requests only hold the blocks
they touch — so peak live occupancy rises and mean latency drops.  A third
run shrinks the block pool below the trace's aggregate demand to exercise
preemption + re-prefill, with the block-mirror sim replay checking exact
StepTrace parity (admissions, occupancies, commits, preemptions).

``--live`` finally runs the chunked-admission study: long prompts arriving
into a live decode batch, admitted whole (one big stall per admission) vs
chunked under a per-iteration token budget (in-step chunked prefill) — the
max admission-iteration gap imposed on running requests must drop.

``--live --shards N`` additionally runs the SHARDED study: the same slot
pool served on an N-way data mesh (``serve_continuous_live(mesh=...)``)
vs the single-device run, asserting token-identical outputs and an
identical StepTrace.  ``--shards`` forces N host devices via XLA_FLAGS, so
it works on a CPU-only box; without it the study is skipped unless
multiple devices are already visible.
"""
from __future__ import annotations

import os
import sys

# must run before jax initialises (any repro import below pulls it in):
# --shards N forces N virtual host devices for the sharded study
def _early_shards_arg(argv):
    """Parse --shards N / --shards=N before argparse (and before jax)."""
    for i, a in enumerate(argv):
        if a == "--shards" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--shards="):
            return int(a.split("=", 1)[1])
    return 0


if __name__ == "__main__":
    _n = _early_shards_arg(sys.argv)
    if _n > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n} "
            + os.environ.get("XLA_FLAGS", ""))

# make the benchmarks package importable when run as a script
# (PYTHONPATH=src python benchmarks/fig7_continuous.py ...)
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import time
from typing import Dict

import numpy as np

from benchmarks.common import VOCAB, bench_prompts, get_trained_pair, write_result
from benchmarks.fig5_dynamic import (MAX_BATCH, MAX_NEW,
                                     build_model_from_measurements, schemes)
from repro.core.adaptive import AdaptiveController, profile_engine
from repro.core.analytical import LatencyModel
from repro.serving.metrics import (admission_gaps, mean_occupancy, summarize,
                                   ttft_summary)
from repro.serving.scheduler import (ContinuousScheduler, PrefillBudgetAdmit,
                                     SimStepBackend, replay_sources,
                                     serve_continuous_live)
from repro.serving.server import EngineBackend, SimBackend, serve, serve_continuous
from repro.serving.traffic import TrafficPhase, make_requests, uniform_traffic


def run(n_requests: int = 600, cvs=(1.0, 5.0),
        interval_mults=(0.25, 0.5, 1.0, 2.0, 4.0), quick: bool = False) -> Dict:
    if quick:
        n_requests, cvs, interval_mults = 150, (2.0,), (0.5, 2.0)
    model = build_model_from_measurements(quick=quick)
    ctrls, lut = schemes(model)
    b0 = MAX_BATCH // 2
    base = model.per_token_time(b0, lut.lookup(b0)) * MAX_NEW
    grid: Dict[str, Dict[str, float]] = {}
    for cv in cvs:
        for m in interval_mults:
            key = f"cv={cv}_int={m}x"
            cell = {}
            for name, ctrl in ctrls.items():
                reqs = uniform_traffic(n_requests, base * m, cv, VOCAB,
                                       seed=42, max_new=MAX_NEW)
                res = serve(reqs, SimBackend(model, seed=1), ctrl,
                            max_batch=MAX_BATCH)
                cell[f"rtc/{name}"] = summarize(res).mean
                reqs = uniform_traffic(n_requests, base * m, cv, VOCAB,
                                       seed=42, max_new=MAX_NEW)
                res = serve_continuous(reqs, model, ctrl,
                                       max_batch=MAX_BATCH, seed=1)
                cell[f"cont/{name}"] = summarize(res).mean
            grid[key] = cell
    gain_adaptive = float(np.mean([c["rtc/adaptive"] / c["cont/adaptive"]
                                   for c in grid.values()]))
    cont_ad_vs_fixed = float(np.mean(
        [min(c["cont/fixed_s2"], c["cont/fixed_s4"]) / c["cont/adaptive"]
         for c in grid.values()]))
    payload = {
        "grid": grid,
        "continuous_gain_at_adaptive": gain_adaptive,
        "cont_adaptive_vs_cont_best_fixed": cont_ad_vs_fixed,
    }
    write_result("fig7_continuous", payload)
    print("\n=== Fig.7 (ours): continuous batching x adaptive speculation ===")
    names = list(next(iter(grid.values())))
    print(f"{'cell':>16s}  " + "".join(f"{n:>16s}" for n in names))
    for key, cell in grid.items():
        print(f"{key:>16s}  " + "".join(f"{cell[n]:16.4f}" for n in names))
    print(f"continuous vs run-to-completion (adaptive): {gain_adaptive:.2f}x; "
          f"adaptive still >= best fixed under continuous: "
          f"{cont_ad_vs_fixed:.2f}x")
    return payload


def run_live(n_requests: int = 120, capacity: int = 8, cache_len: int = 256,
             quick: bool = False) -> Dict:
    """The live half of the study (acceptance gate of the runtime): the
    trained tiny pair served through the slot-pool scheduler."""
    if quick:
        n_requests, capacity = 100, 4
    engine, tparams, dparams, _ = get_trained_pair()
    engine.max_new = 32
    pp, pl = bench_prompts(8, seed=5)
    lut = profile_engine(engine, tparams, dparams, pp, pl,
                         batch_sizes=(1, 2, 4, capacity), s_values=range(0, 7),
                         gen_tokens=8 if quick else 16, cache_len=cache_len)
    ctrl = AdaptiveController(lut=lut)

    # -- 100+-request Poisson trace on the live engine --------------------
    rng = np.random.default_rng(1)
    poisson = make_requests(n_requests, [TrafficPhase(0.01, 1.0, float("inf"))],
                            VOCAB, seed=21, max_new=24)
    for r in poisson:
        r.max_new = int(rng.integers(8, 25))
    t0 = time.time()
    res_live = serve_continuous_live(poisson, engine, tparams, dparams, ctrl,
                                     capacity=capacity, cache_len=cache_len)
    wall = time.time() - t0
    occs = [t.occupancy for t in res_live.trace]
    s_by_occ = {int(b): int(ctrl.choose(int(b))) for b in sorted(set(occs))}

    # -- sim-vs-live scheduling parity on the same trace ------------------
    # the sim backend replays the live run's observed outcomes (commit
    # counts, durations); the scheduler over it must reproduce the live
    # admission order and batch-size sequence exactly
    live_trace = res_live.trace
    accept, duration, prefill, done, _chunk = replay_sources(live_trace)
    # every model quantity is overridden by the replay sources, so a stub
    # LatencyModel suffices (no need to re-profile the engine here)
    bs = (1, 2, 4, capacity)
    model = LatencyModel(alpha={b: 1e-4 for b in bs}, beta={b: 1e-3 for b in bs},
                         t_s={b: 1e-4 for b in bs}, c=0.9, gamma=0.548)
    poisson2 = make_requests(n_requests, [TrafficPhase(0.01, 1.0, float("inf"))],
                             VOCAB, seed=21, max_new=24)
    rng2 = np.random.default_rng(1)
    for r in poisson2:
        r.max_new = int(rng2.integers(8, 25))
    sim = ContinuousScheduler(
        SimStepBackend(model, capacity=capacity, accept_source=accept,
                       duration_source=duration, prefill_source=prefill,
                       done_source=done),
        AdaptiveController(lut=lut))
    sim.run(poisson2)
    parity = ([t.admitted for t in sim.trace] == [t.admitted for t in live_trace]
              and [t.occupancy for t in sim.trace] == occs)

    # -- bursty trace: live continuous vs run-to-completion ---------------
    def bursty():
        reqs = make_requests(max(24, n_requests // 4),
                             [TrafficPhase(0.004, 5.0, float("inf"))],
                             VOCAB, seed=9, max_new=24)
        r3 = np.random.default_rng(3)
        for r in reqs:
            r.max_new = int(r3.integers(6, 25))
        return reqs

    res_cont = serve_continuous_live(bursty(), engine, tparams, dparams, ctrl,
                                     capacity=capacity, cache_len=cache_len)
    rtc = EngineBackend(engine, tparams, dparams, cache_len=cache_len)
    res_rtc = serve(bursty(), rtc, ctrl, max_batch=capacity)

    # -- paged KV pool: mixed short/long trace at equal total KV memory ----
    # 75% short prompts (<= 32 tokens) / 25% long (>= 192): the contiguous
    # pool must size EVERY slot for the long requests, so at a fixed KV
    # budget it only fits a few slots; the paged pool spends the same rows
    # as 16-token blocks and lets short requests ride along.
    long_len, block = 192, 16
    cache_long = 240                       # covers long + max_new + S_MAX
    cap_contig = 4
    total_kv = cap_contig * cache_long     # equal-memory budget (KV rows)
    cap_paged = 10
    n_blocks = total_kv // block

    def mixed_trace(n=32, seed=13, budget=(8, 25)):
        reqs = make_requests(n, [TrafficPhase(0.002, 1.0, float("inf"))],
                             VOCAB, seed=seed, max_new=24)
        r = np.random.default_rng(seed)
        for q in reqs:
            if r.random() < 0.25:
                L = int(r.integers(long_len, long_len + 9))
            else:
                L = int(r.integers(8, 33))
            q.tokens = r.integers(0, VOCAB, (L,)).astype(np.int32)
            q.prompt_len = L
            q.max_new = int(r.integers(*budget))
        return reqs

    n_mixed = 20 if quick else 32
    res_ct = serve_continuous_live(mixed_trace(n_mixed), engine, tparams,
                                   dparams, ctrl, capacity=cap_contig,
                                   cache_len=cache_long)
    res_pg = serve_continuous_live(mixed_trace(n_mixed), engine, tparams,
                                   dparams, ctrl, capacity=cap_paged,
                                   cache_len=cache_long, block_size=block,
                                   num_blocks=n_blocks)
    peak_ct = max(t.occupancy for t in res_ct.trace)
    peak_pg = max(t.occupancy for t in res_pg.trace)

    # -- preemption: aggregate KV demand beyond the pool ------------------
    # Half the equal-memory budget and near-engine-max token budgets (so
    # requests outgrow the admission-time S_MAX reservation mid-flight):
    # the live set no longer fits, the scheduler evicts (longest-remaining,
    # LIFO-admitted) victims and re-prefills them, and the block-mirror sim
    # must re-derive the identical schedule from the replayed outcomes.
    small_blocks = n_blocks // 2
    pre_trace = lambda: mixed_trace(n_mixed, budget=(24, 33))
    res_pre = serve_continuous_live(pre_trace(), engine, tparams,
                                    dparams, ctrl, capacity=cap_paged,
                                    cache_len=cache_long, block_size=block,
                                    num_blocks=small_blocks)
    n_preempt = sum(len(t.preempted) for t in res_pre.trace)
    acc2, dur2, pre2, done2, _ch2 = replay_sources(res_pre.trace)
    sim_pre = ContinuousScheduler(
        SimStepBackend(model, capacity=cap_paged, accept_source=acc2,
                       duration_source=dur2, prefill_source=pre2,
                       done_source=done2, block_size=block,
                       num_blocks=small_blocks, max_context=cache_long),
        AdaptiveController(lut=lut))
    sim_pre.run(pre_trace())
    preempt_parity = (
        [t.admitted for t in sim_pre.trace] == [t.admitted for t in res_pre.trace]
        and [t.preempted for t in sim_pre.trace] == [t.preempted for t in res_pre.trace]
        and [t.occupancy for t in sim_pre.trace] == [t.occupancy for t in res_pre.trace]
        and [t.committed for t in sim_pre.trace] == [t.committed for t in res_pre.trace])

    # -- chunked prefill: long-prompt admission without decode stalls ------
    # Short requests keep a decode batch live; long prompts then arrive.
    # Whole-prompt admission stalls every running decode for a full long
    # prefill; chunked admission (PrefillBudgetAdmit + in-step chunked
    # prefill) caps the admission work per iteration, so the max
    # inter-token gap imposed on the running batch drops.
    chunk_budget = 32

    def stall_trace(n=16, seed=33):
        reqs = make_requests(n, [TrafficPhase(0.02, 1.0, float("inf"))],
                             VOCAB, seed=seed, max_new=24)
        r = np.random.default_rng(seed)
        for j, q in enumerate(reqs):
            L = int(r.integers(150, 180)) if j % 4 == 3 else int(
                r.integers(8, 25))
            q.tokens = r.integers(0, VOCAB, (L,)).astype(np.int32)
            q.prompt_len = L
            q.max_new = int(r.integers(12, 25))
        return reqs

    res_burst = serve_continuous_live(stall_trace(), engine, tparams, dparams,
                                      ctrl, capacity=4, cache_len=cache_long)
    res_chunk = serve_continuous_live(stall_trace(), engine, tparams, dparams,
                                      ctrl, capacity=4, cache_len=cache_long,
                                      policy=PrefillBudgetAdmit(
                                          token_budget=chunk_budget))
    def _max_gap(res, name):
        gaps = admission_gaps(res)
        if not gaps:
            print(f"WARNING: no admission overlapped a running batch in the "
                  f"{name} run (trace too sparse for the chunked study)")
            return float("nan")
        return max(gaps)

    gap_burst = _max_gap(res_burst, "whole-prompt-burst")
    gap_chunk = _max_gap(res_chunk, "chunked")
    n_chunk_events = sum(len(t.chunked) for t in res_chunk.trace)

    # -- sharded serving: the same pool on an N-way data mesh --------------
    # The parity contract of docs/ARCHITECTURE.md: sharding the slot pool's
    # capacity axis over the mesh's data shards changes WHERE rows live,
    # never what they compute — outputs and the StepTrace must be identical
    # to the single-device run.  Requires >= 2 devices (run with --shards N
    # on CPU, which forces N virtual host devices).
    import jax
    from repro.launch.mesh import make_serving_mesh
    from repro.serving.scheduler import ContinuousEngineBackend
    n_dev = jax.device_count()
    if n_dev < 2:
        sharded = {"skipped": "1 device visible; rerun with --live "
                              "--shards 2 (or more) to force host devices"}
    else:
        divisors = [d for d in range(2, min(n_dev, capacity) + 1)
                    if capacity % d == 0]
        n_sh = max(divisors) if divisors else n_dev
        # no divisor => slot_pool_specs falls back to a replicated pool
        # (n_shards = 1); the study still runs and reports that honestly
        mesh = make_serving_mesh(n_sh)

        def shard_trace():
            reqs = mixed_trace(n_mixed, seed=17)
            for r in reqs:
                # arrival = 0: admission composition must not depend on the
                # two runs' measured wall clocks or the exact-trace check
                # below would be timing-sensitive
                r.arrival = 0.0
            return reqs

        def shard_run(m):
            be = ContinuousEngineBackend(engine, tparams, dparams,
                                         capacity=capacity,
                                         cache_len=cache_long,
                                         warm_s=sorted(set(lut.table.values())),
                                         collect_outputs=True, mesh=m)
            t0 = time.time()
            res = serve_continuous_live(shard_trace(), engine, tparams,
                                        dparams,
                                        AdaptiveController(lut=lut),
                                        backend=be)
            return res, be, time.time() - t0

        res_1d, be_1d, wall_1d = shard_run(None)
        res_sh, be_sh, wall_sh = shard_run(mesh)
        trace_ok = (
            [t.admitted for t in res_1d.trace] == [t.admitted for t in res_sh.trace]
            and [t.occupancy for t in res_1d.trace] == [t.occupancy for t in res_sh.trace]
            and [t.committed for t in res_1d.trace] == [t.committed for t in res_sh.trace])
        toks_ok = (set(be_1d.outputs) == set(be_sh.outputs) and all(
            np.array_equal(be_1d.outputs[r], be_sh.outputs[r])
            for r in be_1d.outputs))
        sharded = {
            "device_count": n_dev, "n_shards": be_sh.n_shards,
            "trace_identical": bool(trace_ok),
            "tokens_identical": bool(toks_ok),
            "mean_latency_1dev_s": summarize(res_1d).mean,
            "mean_latency_sharded_s": summarize(res_sh).mean,
            "wall_1dev_s": wall_1d, "wall_sharded_s": wall_sh,
        }

    payload = {
        "sharded": sharded,
        "n_requests": n_requests, "capacity": capacity,
        "chunked_prefill": {
            "token_budget": chunk_budget,
            "n_chunk_events": n_chunk_events,
            "max_admission_gap_burst_s": gap_burst,
            "max_admission_gap_chunked_s": gap_chunk,
            "gap_reduction": gap_burst / max(gap_chunk, 1e-12),
            "mean_latency_burst_s": summarize(res_burst).mean,
            "mean_latency_chunked_s": summarize(res_chunk).mean,
        },
        "paged_kv": {
            "block_size": block, "total_kv_tokens": total_kv,
            "contiguous": {"capacity": cap_contig, "cache_len": cache_long,
                           "peak_occupancy": peak_ct,
                           "mean_latency_s": summarize(res_ct).mean},
            "paged": {"capacity": cap_paged, "num_blocks": n_blocks,
                      "peak_occupancy": peak_pg,
                      "mean_latency_s": summarize(res_pg).mean},
            "peak_occupancy_gain": peak_pg / max(peak_ct, 1),
            "preemption": {"num_blocks": small_blocks,
                           "n_preemptions": n_preempt,
                           "completed": all(r.finish is not None
                                            for r in res_pre.requests),
                           "sim_live_parity": bool(preempt_parity)},
        },
        "poisson_mean_latency_s": summarize(res_live).mean,
        "poisson_ttft_s": ttft_summary(res_live).mean,
        "poisson_mean_occupancy": mean_occupancy(res_live),
        "poisson_steps": len(res_live.trace),
        "s_by_occupancy": s_by_occ,
        "sim_live_parity": bool(parity),
        "bursty_continuous_mean_s": summarize(res_cont).mean,
        "bursty_rtc_mean_s": summarize(res_rtc).mean,
        "continuous_gain_live": summarize(res_rtc).mean / summarize(res_cont).mean,
        "wall_s": wall,
    }
    write_result("fig7_continuous_live", payload)
    print("\n=== Fig.7 live: continuous batching on the real engine ===")
    print(f"{n_requests}-request Poisson trace: mean latency "
          f"{payload['poisson_mean_latency_s']:.3f}s  TTFT "
          f"{payload['poisson_ttft_s']:.3f}s  mean occupancy "
          f"{payload['poisson_mean_occupancy']:.2f}  "
          f"({payload['poisson_steps']} spec steps)")
    print(f"adaptive s by live occupancy: {s_by_occ}")
    print(f"sim-vs-live scheduling parity: {payload['sim_live_parity']}")
    print(f"bursty trace: continuous {payload['bursty_continuous_mean_s']:.3f}s "
          f"vs run-to-completion {payload['bursty_rtc_mean_s']:.3f}s "
          f"-> {payload['continuous_gain_live']:.2f}x")
    pk = payload["paged_kv"]
    print(f"paged KV (equal {pk['total_kv_tokens']}-token KV budget, mixed "
          f"75/25 short/long trace): peak occupancy "
          f"{pk['contiguous']['peak_occupancy']} (contiguous, "
          f"{cap_contig} x {cache_long}) -> {pk['paged']['peak_occupancy']} "
          f"(paged, {pk['paged']['num_blocks']} x {block}-token blocks), "
          f"mean latency {pk['contiguous']['mean_latency_s']:.3f}s -> "
          f"{pk['paged']['mean_latency_s']:.3f}s")
    pr = pk["preemption"]
    print(f"preemption at {pr['num_blocks']} blocks (half budget, "
          f"24-32-token requests): {pr['n_preemptions']} evictions, "
          f"completed={pr['completed']}, "
          f"sim-vs-live StepTrace parity={pr['sim_live_parity']}")
    if pk["paged"]["peak_occupancy"] <= pk["contiguous"]["peak_occupancy"]:
        print("WARNING: paged pool did not beat contiguous peak occupancy")
    ck = payload["chunked_prefill"]
    print(f"chunked prefill ({ck['token_budget']}-token budget, "
          f"{ck['n_chunk_events']} chunk events): max admission-iteration "
          f"gap {ck['max_admission_gap_burst_s']*1e3:.1f}ms (whole-prompt "
          f"burst) -> {ck['max_admission_gap_chunked_s']*1e3:.1f}ms "
          f"(chunked), {ck['gap_reduction']:.2f}x lower")
    if ck["max_admission_gap_chunked_s"] >= ck["max_admission_gap_burst_s"]:
        print("WARNING: chunked admission did not lower the max "
              "admission-iteration gap")
    sd = payload["sharded"]
    if "skipped" in sd:
        print(f"sharded study: skipped ({sd['skipped']})")
    else:
        print(f"sharded serving ({sd['n_shards']} data shards over "
              f"{sd['device_count']} devices): trace identical = "
              f"{sd['trace_identical']}, tokens identical = "
              f"{sd['tokens_identical']}, mean latency "
              f"{sd['mean_latency_1dev_s']:.3f}s (1 dev) vs "
              f"{sd['mean_latency_sharded_s']:.3f}s (sharded)")
        if not (sd["trace_identical"] and sd["tokens_identical"]):
            print("WARNING: sharded run diverged from the single-device run")
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true",
                    help="run the live-engine study (slot-pool scheduler)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--shards", type=int, default=0,
                    help="force N host devices (XLA_FLAGS, set at module "
                         "import) and run the --live sharded study on an "
                         "N-way data mesh")
    args = ap.parse_args()
    if args.live:
        run_live(quick=args.quick)
    else:
        run(quick=args.quick)
