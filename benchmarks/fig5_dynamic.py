"""Fig. 5: average request latency under dynamic (Gamma) traffic, across a
grid of (request interval x CV), for four schemes: no speculation, fixed
s=2, fixed s=4, adaptive.

Methodology mirrors the paper (§5.3): one pre-generated request trace per
(interval, CV) evaluates all schemes; latency includes queueing.  Execution
uses the discrete-event SimBackend driven by a LatencyModel *fitted to the
measured tiny-pair profile* (fig3's t_L/t_S wall-clock grid + fig2's
acceptance fit), so 1000-request traces run in milliseconds while every
latency constant is a real measurement of this machine.  Intervals are
expressed as multiples of the per-request service time so the load regimes
(overloaded ... idle) match the paper's 0.1-0.8 s sweep.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import VOCAB, get_trained_pair, write_result
from benchmarks import fig2_acceptance, fig3_tl_scaling
from repro.core.adaptive import (AdaptiveController, fixed_controller,
                                 lut_from_model)
from repro.core.analytical import LatencyModel
from repro.serving.metrics import summarize
from repro.serving.server import SimBackend, serve
from repro.serving.traffic import uniform_traffic

MAX_NEW = 128
MAX_BATCH = 16


def build_model_from_measurements(quick: bool = False) -> LatencyModel:
    f3 = fig3_tl_scaling.run(quick=quick)
    f2 = fig2_acceptance.run(quick=quick)
    # clamp like core.analytical.fit_latency_model: noisy quick-mode wall
    # clocks can fit a negative slope, which would run the virtual clock
    # backwards (negative step durations -> negative latencies)
    alpha = {int(b): max(v["alpha"], 1e-9) for b, v in f3["linear_fits"].items()}
    beta = {int(b): max(v["beta"], 1e-6) for b, v in f3["linear_fits"].items()}
    t_s = {int(b): v for b, v in f3["t_S_b1"].items()}
    return LatencyModel(alpha=alpha, beta=beta, t_s=t_s,
                        c=f2["fit_c"], gamma=f2["fit_gamma"])


def schemes(model: LatencyModel):
    lut = lut_from_model(model, s_max=8)
    return {
        "no_spec": fixed_controller(0),
        "fixed_s2": fixed_controller(2),
        "fixed_s4": fixed_controller(4),
        "adaptive": AdaptiveController(lut=lut),
    }, lut


def run(n_requests: int = 1000, cvs=(0.5, 1.0, 2.0, 5.0),
        interval_mults=(0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0),
        quick: bool = False) -> Dict:
    if quick:
        n_requests, cvs, interval_mults = 200, (1.0, 5.0), (0.5, 2.0)
    model = build_model_from_measurements(quick=quick)
    ctrls, lut = schemes(model)
    # base unit: per-request service time at half the max batch, optimal s
    b0 = MAX_BATCH // 2
    base = model.per_token_time(b0, lut.lookup(b0)) * MAX_NEW
    grid: Dict[str, Dict] = {}
    wins = {k: 0 for k in ctrls}
    for cv in cvs:
        for m in interval_mults:
            interval = base * m
            key = f"cv={cv}_int={m}x"
            cell = {}
            for name, ctrl in ctrls.items():
                reqs = uniform_traffic(n_requests, interval, cv, VOCAB,
                                       seed=42, max_new=MAX_NEW)
                res = serve(reqs, SimBackend(model, seed=1), ctrl,
                            max_batch=MAX_BATCH)
                cell[name] = summarize(res).mean
            grid[key] = cell
            wins[min(cell, key=cell.get)] += 1
    # aggregate speedups
    sp_nospec = float(np.mean([c["no_spec"] / c["adaptive"] for c in grid.values()]))
    sp_fixed = float(np.mean([min(c["fixed_s2"], c["fixed_s4"]) / c["adaptive"]
                              for c in grid.values()]))
    adaptive_never_worst = all(
        c["adaptive"] <= min(c["fixed_s2"], c["fixed_s4"]) * 1.02
        for c in grid.values())
    payload = {
        "base_interval_s": base, "grid": grid, "wins": wins,
        "lut": {str(b): int(s) for b, s in lut.table.items()},
        "speedup_vs_no_spec": sp_nospec,
        "speedup_vs_best_fixed": sp_fixed,
        "adaptive_matches_best_fixed": bool(adaptive_never_worst),
    }
    write_result("fig5_dynamic", payload)
    print("\n=== Fig.5: dynamic traffic (mean latency, s) ===")
    print(f"LUT: {lut.table}  base request-interval unit: {base*1e3:.2f} ms")
    hdr = f"{'cell':>18s}  " + "".join(f"{k:>10s}" for k in ctrls)
    print(hdr)
    for key, cell in grid.items():
        print(f"{key:>18s}  " + "".join(f"{cell[k]:10.4f}" for k in ctrls))
    print(f"adaptive speedup vs no-spec {sp_nospec:.2f}x (paper: 2.3x); "
          f"vs best-fixed {sp_fixed:.2f}x (paper: up to 1.15x); "
          f"never-worse-than-fixed: {adaptive_never_worst}")
    return payload


if __name__ == "__main__":
    run()
