"""Render EXPERIMENTS.md tables (§Dry-run, §Roofline) from
results/dryrun/*.json.  Run after ``python -m repro.launch.dryrun --all``:

  PYTHONPATH=src python -m benchmarks.report > results/roofline_tables.md
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

from benchmarks.roofline import load_records


def _fmt_s(x: float) -> str:
    if x >= 1e-1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def _fmt_b(x: float) -> str:
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(recs: List[Dict]) -> str:
    out = ["| arch | shape | mesh | chips | kind | GiB/dev (args) | GiB/dev (temp) | compile | collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        coll = ", ".join(f"{k.split('-')[0][:3]}+{k.split('-')[1][:3]}={_fmt_b(v)}"
                         if "-" in k else f"{k}={_fmt_b(v)}"
                         for k, v in sorted(r["collectives"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['kind']} | {r['memory']['argument_bytes']/2**30:.2f} | "
            f"{r['memory']['temp_bytes']/2**30:.2f} | {r['compile_s']:.0f}s | "
            f"{coll} |")
    return "\n".join(out)


def roofline_table(recs: List[Dict], mesh: str = "pod") -> str:
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "MODEL_FLOPs/HLO | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        rf, an = r["roofline"], r["analytic"]
        hint = dominant_hint(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rf['compute_s'])} | "
            f"{_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} | "
            f"**{rf['dominant']}** | {an['useful_compute_ratio']:.2f} | {hint} |")
    return "\n".join(out)


def dominant_hint(r: Dict) -> str:
    rf, an = r["roofline"], r["analytic"]
    d = rf["dominant"]
    det = an["detail"]
    if d == "memory":
        w = det.get("weights_bytes", 0)
        c = det.get("cache_bytes", 0)
        if c > w:
            return ("KV-cache streaming dominates: shrink cache reads "
                    "(window/quantize) or raise s to amortize")
        return ("weight streaming dominates: larger effective batch or "
                "higher s amortizes the sweep")
    if d == "compute":
        if det.get("moe_dispatch", 0) > 0.2 * an["flops"]:
            return "one-hot MoE dispatch einsums burn flops: sort-based dispatch"
        if an["useful_compute_ratio"] < 0.6:
            return ("attention/remat overhead: causal-aware train kernel or "
                    "looser remat would cut non-model flops")
        return "near-roofline: only faster matmul tiling (Pallas) helps"
    return "collective-bound: reshard to cut the dominant collective"


def main():
    recs = load_records()
    if not recs:
        print("no dry-run records found", file=sys.stderr)
        return
    print("### Dry-run matrix\n")
    print(dryrun_table(recs))
    for mesh in ("pod", "multipod"):
        sub = [r for r in recs if r["mesh"] == mesh]
        if sub:
            print(f"\n### Roofline — {mesh} "
                  f"({sub[0]['chips']} chips)\n")
            print(roofline_table(recs, mesh))


if __name__ == "__main__":
    main()
