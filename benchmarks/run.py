"""Benchmark harness entry point: one benchmark per paper figure plus the
roofline table.

  PYTHONPATH=src python -m benchmarks.run            # full pass
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-scale
  PYTHONPATH=src python -m benchmarks.run --only fig1_grid,fig5_dynamic
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (fig1_grid, fig2_acceptance, fig3_tl_scaling,
                        fig4_uniform, fig5_dynamic, fig6_timeline,
                        fig7_continuous, kernel_bench, roofline,
                        serving_bench)

BENCHES = {
    "fig1_grid": fig1_grid.run,
    "fig2_acceptance": fig2_acceptance.run,
    "fig3_tl_scaling": fig3_tl_scaling.run,
    "fig4_uniform": fig4_uniform.run,
    "fig5_dynamic": fig5_dynamic.run,
    "fig6_timeline": fig6_timeline.run,
    "fig7_continuous": fig7_continuous.run,
    "fig7_live": fig7_continuous.run_live,
    "kernels": kernel_bench.run,
    "serving": serving_bench.run,
    "roofline": roofline.run,
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(BENCHES)
    failures = []
    for name in names:
        t0 = time.time()
        print(f"\n######## {name} ########", flush=True)
        try:
            BENCHES[name](quick=args.quick)
            print(f"[{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
