"""Fig. 2: the acceptance curve l(s) and its power-law fit c * s^gamma.

Measures per-step accepted-run lengths of the trained pair (paper Eq. 4),
builds the empirical l(s), fits the power law in log-log space, and checks
the paper's qualitative claims: l non-decreasing, sub-linear (gamma < 1).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import bench_prompts, get_trained_pair, write_result
from repro.core.adaptive import measure_acceptance
from repro.core.analytical import (acceptance_curve, fit_power_law,
                                   power_law_r2)


def run(n_prompts: int = 32, gen_tokens: int = 48, s_probe: int = 8,
        quick: bool = False) -> Dict:
    if quick:
        n_prompts, gen_tokens = 8, 24
    engine, tp, dp, meta = get_trained_pair()
    prompts, lens = bench_prompts(n_prompts)
    runs = measure_acceptance(engine, tp, dp, prompts, lens, s=s_probe,
                              gen_tokens=gen_tokens, cache_len=256)
    s_vals = list(range(1, s_probe + 1))
    ls = acceptance_curve(runs, s_vals)
    c, gamma = fit_power_law(s_vals, ls)
    r2 = power_law_r2(s_vals, ls, c, gamma)
    payload = {
        "s": s_vals, "l_of_s": [float(x) for x in ls],
        "fit_c": c, "fit_gamma": gamma, "fit_r2": r2,
        "n_run_samples": len(runs),
        "mean_accept_at_s8": float(np.mean(np.minimum(runs, 8))),
        "sublinear": bool(gamma < 1.0),
        "non_decreasing": bool(all(a <= b + 1e-9 for a, b in zip(ls, ls[1:]))),
        "paper_reference_fit": {"c": 0.9, "gamma": 0.548},
    }
    write_result("fig2_acceptance", payload)
    print("\n=== Fig.2: acceptance curve ===")
    print("  s   l(s)    c*s^gamma")
    for s, l in zip(s_vals, ls):
        print(f"  {s}  {l:6.3f}   {c * s ** gamma:6.3f}")
    print(f"fit: l(s) ~= {c:.3f} * s^{gamma:.3f}  (R2={r2:.4f}; "
          f"paper: 0.9 * s^0.548)")
    return payload


if __name__ == "__main__":
    run()
