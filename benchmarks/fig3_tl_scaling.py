"""Fig. 3: verify-step latency t_L(b, s) vs speculation length for several
batch sizes, with the paper's linear fit t_L ~= alpha_b * s + beta.

Validates: alpha_b increases with b (the slope is what pushes s_opt down as
batches grow) — the mechanism behind the whole adaptive policy.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import bench_prompts, get_trained_pair, timeit, write_result
from repro.core.analytical import fit_linear_latency


def run(batch_sizes=(1, 4, 8, 16, 32), s_values=tuple(range(0, 9)),
        quick: bool = False) -> Dict:
    if quick:
        batch_sizes, s_values = (1, 8), (0, 2, 4)
    import jax
    engine, tp, dp, _ = get_trained_pair()
    # jit once per query length (shape-polymorphic on batch via recompile)
    tstep = jax.jit(engine.target.decode_step)
    dstep = jax.jit(engine.draft.decode_step)
    tl: Dict[int, Dict[int, float]] = {}
    ts_draft: Dict[int, float] = {}
    for b in batch_sizes:
        prompts, lens = bench_prompts(b)
        state = engine.prefill(tp, dp, prompts, lens, cache_len=256)
        tl[b] = {}
        for s in s_values:
            feed = jax.numpy.asarray(
                np.tile(np.asarray(state.last2[:, 1:]), (1, s + 1))[:, :s + 1])
            fn = lambda: tstep(tp, feed, state.tcache, state.seq_lens)
            tl[b][s] = timeit(fn)
        last2 = jax.numpy.asarray(np.asarray(state.last2))
        dfn = lambda: dstep(dp, last2, state.dcache, state.seq_lens - 1)
        ts_draft[b] = timeit(dfn)

    fits = {}
    for b, d in tl.items():
        ss = sorted(d)
        alpha, beta = fit_linear_latency(ss, [d[s] for s in ss])
        fits[b] = {"alpha": alpha, "beta": beta}
    alphas = [fits[b]["alpha"] for b in sorted(fits)]
    increasing = all(a <= b * 1.25 + 1e-9 for a, b in zip(alphas, alphas[1:]))
    payload = {
        "t_L": {str(b): {str(s): v for s, v in d.items()} for b, d in tl.items()},
        "t_S_b1": {str(b): v for b, v in ts_draft.items()},
        "linear_fits": {str(b): v for b, v in fits.items()},
        "alpha_increasing_with_b": bool(increasing),
    }
    write_result("fig3_tl_scaling", payload)
    print("\n=== Fig.3: t_L(b, s) (ms) and linear fits ===")
    for b in sorted(tl):
        row = " ".join(f"{tl[b][s]*1e3:6.2f}" for s in sorted(tl[b]))
        print(f"  b={b:3d}: {row}  alpha={fits[b]['alpha']*1e3:.3f}ms/s "
              f"beta={fits[b]['beta']*1e3:.2f}ms  t_S={ts_draft[b]*1e3:.2f}ms")
    print(f"alpha_b increasing with b: {increasing}")
    return payload


if __name__ == "__main__":
    run()
