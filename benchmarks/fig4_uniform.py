"""Fig. 4: normalized end-to-end latency under uniform traffic (fixed batch
sizes), adaptive speculation vs the no-speculation baseline.

Wall-clock on the trained tiny pair: for each batch size, serve a fixed set
of prompt batches to completion with (i) s = 0 and (ii) s = LUT(b) from the
profiling stage, and report the speedup (paper: 2.73x at b=1 down to 1.31x
at b=32, mean 1.94x — ratios are hardware-specific; the *shape* — larger
gains at smaller b — is the claim we validate).
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import bench_prompts, get_trained_pair, write_result
from repro.core.adaptive import profile_engine


def _serve_fixed(engine, tp, dp, prompts, lens, s, gen_tokens=48):
    st = engine.prefill(tp, dp, prompts, lens, cache_len=256)
    engine.step(tp, dp, st, s)                       # warm
    st = engine.prefill(tp, dp, prompts, lens, cache_len=256)
    t0, tot = time.perf_counter(), 0
    while tot < gen_tokens * prompts.shape[0]:
        st, stats = engine.step(tp, dp, st, s)
        tot += int(stats.committed.sum())
        if bool(np.asarray(st.done).all()):
            break
    return time.perf_counter() - t0


def run(batch_sizes=(1, 2, 4, 8, 16, 32), gen_tokens: int = 48,
        quick: bool = False) -> Dict:
    if quick:
        batch_sizes, gen_tokens = (1, 8), 24
    engine, tp, dp, _ = get_trained_pair()
    pp, pl = bench_prompts(8, seed=999)              # profiling sample
    lut = profile_engine(engine, tp, dp, pp, pl, batch_sizes=batch_sizes,
                         s_values=range(0, 7), gen_tokens=24, cache_len=256)
    out: Dict[str, Dict] = {"lut": {str(b): int(s) for b, s in lut.table.items()}}
    rows = {}
    for b in batch_sizes:
        prompts, lens = bench_prompts(b, seed=b)     # held-out vs profiling
        t0 = _serve_fixed(engine, tp, dp, prompts, lens, 0, gen_tokens)
        s_ad = lut.lookup(b)
        t_ad = _serve_fixed(engine, tp, dp, prompts, lens, s_ad, gen_tokens)
        rows[b] = {"no_spec_s": t0, "adaptive_s": t_ad,
                   "s_used": s_ad, "speedup": t0 / t_ad}
    out["per_batch"] = {str(b): v for b, v in rows.items()}
    sp = [rows[b]["speedup"] for b in batch_sizes]
    out["mean_speedup"] = float(np.mean(sp))
    out["small_b_gain_larger"] = bool(rows[batch_sizes[0]]["speedup"]
                                      >= rows[batch_sizes[-1]]["speedup"] - 0.05)
    write_result("fig4_uniform", out)
    print("\n=== Fig.4: uniform traffic, adaptive vs no-spec ===")
    for b in batch_sizes:
        r = rows[b]
        print(f"  b={b:3d}: s_opt={r['s_used']} speedup={r['speedup']:.2f}x "
              f"(norm latency {1/r['speedup']:.2f})")
    print(f"mean speedup {out['mean_speedup']:.2f}x "
          f"(paper: 1.94x on RTX3090/OPT-6.7B)")
    return out


if __name__ == "__main__":
    run()
