"""Standing serving benchmark: fig7-style request traces through the
continuous-batching scheduler with the telemetry hub attached, on the sim
and live backends.  Results land in results/BENCH_serving.json — the
serving-layer counterpart of BENCH_kernels.json (ROADMAP item 5).

Scenarios
  sim_steady         uniform Poisson-ish traffic on the SimStepBackend with
                     the analytical latency model and the adaptive LUT.
                     Virtual clock => fully deterministic, so --check holds
                     goodput/TTFT to ~1% of the committed baseline.
  sim_paged_chunked  the same model behind a deliberately undersized paged
                     block pool plus a chunked-admission budget: exercises
                     preemption, chunk feeds, and the pool gauges.  Also
                     deterministic.
  sim_templated      templated traffic (4 system prompts shared by many
                     requests) through the paged pool with the prefix cache
                     on, against an identical cache-off run.  The sim
                     backend charges a per-token prefill cost, so the
                     cache's admission savings surface as a TTFT win;
                     --check gates hit-rate > 0 and cached TTFT strictly
                     below the cold run's.  Deterministic.
  live_smoke         the trained tiny pair (benchmarks/common.py) served by
                     serve_continuous_live with a profiled LUT and an
                     acceptance expectation calibrated from two quick
                     generate() runs.  Wall-clock, so --check only applies
                     loose factor bounds (and only with --live).

Every scenario reports goodput (committed tokens / makespan), TTFT, ITL,
time-weighted occupancy, iteration count, and the telemetry roll-up
(counters, peaks, per-(s, batch) acceptance with observed-vs-predicted
drift).  The payload also embeds a telemetry-parity self-check: the
sim_steady trace must be identical with and without the hub attached.

``--check`` is the CI gate: it re-runs the scenarios and exits nonzero when
a deterministic sim metric regresses beyond tolerance against the committed
results/BENCH_serving.json, when acceptance drift leaves its band, or when
telemetry parity breaks.  Like kernel_bench, smoke modes never clobber the
committed artifact.

  PYTHONPATH=src python benchmarks/serving_bench.py              # full + live
  PYTHONPATH=src python benchmarks/serving_bench.py --check --sim-only
  PYTHONPATH=src python benchmarks/serving_bench.py --profile-dir /tmp/tb
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np

from repro.core.adaptive import AdaptiveController, lut_from_model, profile_engine
from repro.core.analytical import LatencyModel, fit_power_law
from repro.serving.metrics import goodput, itl_summary, mean_occupancy, ttft_summary
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousScheduler, PrefillBudgetAdmit,
                                     SimStepBackend, serve_continuous_live)
from repro.serving.server import serve_continuous
from repro.serving.telemetry import Telemetry
from repro.serving.traffic import TrafficPhase, make_requests, uniform_traffic

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
OUT_PATH = os.path.join(RESULTS, "BENCH_serving.json")

VOCAB = 512
SIM_BATCHES = (1, 2, 4, 8, 16)
# sim scenarios run on a virtual clock and are bit-deterministic: 1% is pure
# float headroom, any real scheduling change trips it
SIM_RTOL = 0.01
# live_smoke is wall-clock on whatever machine runs CI: factor bounds only
LIVE_FACTOR = 2.5
DRIFT_TOL = 0.25


def sim_model() -> LatencyModel:
    """The deterministic synthetic latency model the scheduler tests use."""
    return LatencyModel(alpha={b: 1e-4 * b ** 0.8 for b in SIM_BATCHES},
                        beta={b: 5e-3 for b in SIM_BATCHES},
                        t_s={b: 2e-4 for b in SIM_BATCHES},
                        c=0.9, gamma=0.548)


def _metrics(res, tel: Optional[Telemetry] = None) -> Dict:
    t, il = ttft_summary(res), itl_summary(res)
    out = {
        "goodput_tok_per_s": goodput(res),
        "ttft_mean_s": t.mean, "ttft_p90_s": t.p90,
        "itl_mean_s": il.mean,
        "mean_occupancy": mean_occupancy(res),
        "n_requests": len(res.requests),
        "tokens": int(sum(r.n_generated for r in res.requests)),
        "n_iterations": len(res.trace),
    }
    if tel is not None:
        out["acceptance_drift"] = tel.acceptance_drift()
        out["counters"] = dict(tel.counters)
        out["peaks"] = dict(tel.peaks)
        out["acceptance"] = tel.acceptance_table()
    return out


def bench_sim_steady() -> Dict:
    m = sim_model()
    lut = lut_from_model(m, s_max=8)
    # offered load at ~3/4 of a b=8 batch's per-token service rate
    interval = m.per_token_time(8, lut.lookup(8)) * 24 * 0.75
    reqs = uniform_traffic(200, interval, 2.0, VOCAB, seed=11, max_new=24)
    tel = Telemetry()
    tel.attach_expected_acceptance(lambda s: m.l_of_s(s) / s)
    res = serve_continuous(reqs, m, AdaptiveController(lut=lut), max_batch=8,
                           seed=3, telemetry=tel)
    return _metrics(res, tel)


def bench_sim_paged_chunked() -> Dict:
    m = sim_model()
    ctrl = AdaptiveController(lut=lut_from_model(m, s_max=8))
    reqs = make_requests(64, [TrafficPhase(0.02, 2.0, float("inf"))], VOCAB,
                         seed=13, max_new=24)
    rng = np.random.default_rng(5)
    for j, r in enumerate(reqs):
        r.max_new = int(rng.integers(12, 25))
        if j % 3 == 0:
            # long prompts force chunked admission under the token budget
            L = int(rng.integers(40, 57))
            r.tokens = rng.integers(0, VOCAB, (L,)).astype(np.int32)
            r.prompt_len = L
    tel = Telemetry()
    tel.attach_expected_acceptance(lambda s: m.l_of_s(s) / s)
    # undersized pool (8 slots x up to 12 blocks each, only 18 shared):
    # guarantees preemption pressure so the bench exercises that counter
    sched = ContinuousScheduler(
        SimStepBackend(m, capacity=8, seed=2, block_size=8, num_blocks=18,
                       max_context=96), ctrl,
        policy=PrefillBudgetAdmit(token_budget=32, chunk=16), telemetry=tel)
    res = sched.run(reqs)
    res.trace = sched.trace
    out = _metrics(res, tel)
    out["n_preemptions"] = int(tel.counters.get("preempt", 0))
    out["n_chunk_feeds"] = int(tel.counters.get("chunk_continue", 0))
    return out


def bench_sim_templated() -> Dict:
    """Templated traffic through the prefix cache vs an identical cold run.

    Four 32-token system prompts fan out over 48 requests (unique tails),
    served twice with the same geometry and budget — prefix_cache on and
    off.  The sim backend charges ``prefill_token_cost`` per fed row, so
    skipping the cached prefix both shortens the prefill span and frees
    admission budget; the reported TTFT win is the paper-level payoff the
    --check gate holds on to (along with hit-rate > 0)."""
    m = sim_model()

    def reqs():
        rng = np.random.default_rng(23)
        sys_prompts = [rng.integers(0, VOCAB, (32,)).astype(np.int32)
                       for _ in range(4)]
        out = []
        for i in range(48):
            tail = rng.integers(0, VOCAB,
                                (int(rng.integers(4, 12)),)).astype(np.int32)
            toks = np.concatenate([sys_prompts[i % 4], tail])
            out.append(Request(rid=i, arrival=0.01 * i, tokens=toks,
                               prompt_len=len(toks),
                               max_new=int(rng.integers(8, 17))))
        return out

    def go(cache: bool):
        tel = Telemetry()
        be = SimStepBackend(m, capacity=8, seed=2, block_size=8,
                            num_blocks=96, max_context=96,
                            prefix_cache=cache, prefill_token_cost=2e-4)
        sched = ContinuousScheduler(
            be, AdaptiveController(lut=lut_from_model(m, s_max=8)),
            policy=PrefillBudgetAdmit(token_budget=32, chunk=16),
            telemetry=tel)
        res = sched.run(reqs())
        res.trace = sched.trace
        return res, tel, be

    res_c, tel_c, be_c = go(True)
    res_0, _, _ = go(False)
    out = _metrics(res_c, tel_c)
    cache = be_c.cache
    out["cache_hit_rate"] = cache.hits / max(cache.lookups, 1)
    out["cache_hit_tokens"] = int(cache.hit_tokens)
    out["cache_evicted_blocks"] = int(be_c.kv.evicted_total)
    out["ttft_cold_mean_s"] = ttft_summary(res_0).mean
    out["goodput_cold_tok_per_s"] = goodput(res_0)
    return out


def bench_live_smoke(profile_dir: Optional[str] = None) -> Dict:
    from benchmarks.common import bench_prompts, get_trained_pair
    engine, tparams, dparams, _ = get_trained_pair()
    capacity, cache_len = 4, 192
    pp, pl = bench_prompts(8, seed=5)
    lut = profile_engine(engine, tparams, dparams, pp, pl,
                         batch_sizes=(1, 2, capacity), s_values=range(0, 5),
                         gen_tokens=8, cache_len=cache_len)
    ctrl = AdaptiveController(lut=lut)
    # calibrate the acceptance expectation l(s) ~= c * s**gamma from two
    # quick fixed-s generates (attached to the telemetry hub directly — NOT
    # via controller.model, which would also lift the controller's s cap)
    l_obs = {}
    for s in (2, 4):
        _, stats, _ = engine.generate(tparams, dparams, pp[:4], pl[:4], s=s,
                                      cache_len=cache_len, max_new=16,
                                      collect_stats=True)
        acc = np.concatenate([np.maximum(st.accepted, 0) for st in stats])
        l_obs[s] = float(np.mean(acc))
    c, gamma = fit_power_law(list(l_obs), list(l_obs.values()))
    tel = Telemetry(profile_dir=profile_dir)
    tel.attach_expected_acceptance(lambda s: min(c * s ** gamma / s, 1.0))
    reqs = make_requests(48, [TrafficPhase(0.01, 1.0, float("inf"))], VOCAB,
                         seed=21, max_new=24)
    rng = np.random.default_rng(1)
    for r in reqs:
        r.max_new = int(rng.integers(8, 25))
    res = serve_continuous_live(reqs, engine, tparams, dparams, ctrl,
                                capacity=capacity, cache_len=cache_len,
                                telemetry=tel)
    out = _metrics(res, tel)
    out["wall_clock"] = True
    out["acceptance_fit"] = {"c": c, "gamma": gamma}
    return out


def telemetry_parity() -> Dict:
    """The standing contract, checked on every bench run: the sim schedule
    is identical with and without the telemetry hub attached."""
    m = sim_model()
    lut = lut_from_model(m, s_max=8)

    def go(tel):
        reqs = uniform_traffic(40, 0.02, 2.0, VOCAB, seed=17, max_new=16)
        return serve_continuous(reqs, m, AdaptiveController(lut=lut),
                                max_batch=8, seed=9, telemetry=tel)

    r0, r1 = go(None), go(Telemetry())
    fields = ("admitted", "occupancy", "committed", "preempted", "chunked")
    same = all([getattr(t, f) for t in r0.trace]
               == [getattr(t, f) for t in r1.trace] for f in fields)
    same = same and bool(np.allclose(r0.latencies, r1.latencies))
    return {"ok": same}


def _compare(base: Dict, cur: Dict) -> List[str]:
    """Regression comparison of the current scenarios against the committed
    baseline: deterministic sim metrics within SIM_RTOL, live within factor
    bounds, acceptance drift within its band."""
    problems = []
    # standing prefix-cache gates: properties of the current run itself,
    # not drift against the baseline
    t = cur.get("sim_templated")
    if t:
        if t["cache_hit_rate"] <= 0:
            problems.append("sim_templated: prefix-cache hit rate is zero — "
                            "templated traffic found no shared prefix")
        if t["ttft_mean_s"] >= t["ttft_cold_mean_s"]:
            problems.append(
                f"sim_templated: cached mean TTFT {t['ttft_mean_s']:.4g}s is "
                f"not below the cold run's {t['ttft_cold_mean_s']:.4g}s — "
                "the prefix cache stopped paying for itself")
    for name in ("sim_steady", "sim_paged_chunked", "sim_templated"):
        b, c = base.get(name), cur.get(name)
        if not b or not c:
            problems.append(f"{name}: missing from "
                            + ("baseline" if not b else "current run"))
            continue
        gp_rel = (c["goodput_tok_per_s"] - b["goodput_tok_per_s"]) \
            / max(abs(b["goodput_tok_per_s"]), 1e-12)
        if gp_rel < -SIM_RTOL:
            problems.append(
                f"{name}: goodput regressed {b['goodput_tok_per_s']:.4g} -> "
                f"{c['goodput_tok_per_s']:.4g} tok/s ({gp_rel:+.1%})")
        tt_rel = (c["ttft_mean_s"] - b["ttft_mean_s"]) \
            / max(abs(b["ttft_mean_s"]), 1e-12)
        if tt_rel > SIM_RTOL:
            problems.append(
                f"{name}: mean TTFT regressed {b['ttft_mean_s']:.4g} -> "
                f"{c['ttft_mean_s']:.4g} s ({tt_rel:+.1%})")
        drift = c.get("acceptance_drift")
        if drift is not None and abs(drift) > DRIFT_TOL:
            problems.append(f"{name}: acceptance drift {drift:+.3f} outside "
                            f"+/-{DRIFT_TOL} — the LUT's l(s) model no "
                            f"longer matches the observed process")
    b, c = base.get("live_smoke"), cur.get("live_smoke")
    if b and c:
        if c["goodput_tok_per_s"] < b["goodput_tok_per_s"] / LIVE_FACTOR:
            problems.append(
                f"live_smoke: goodput collapsed "
                f"{b['goodput_tok_per_s']:.3g} -> "
                f"{c['goodput_tok_per_s']:.3g} tok/s (>{LIVE_FACTOR}x)")
        if c["ttft_mean_s"] > b["ttft_mean_s"] * LIVE_FACTOR:
            problems.append(
                f"live_smoke: mean TTFT blew up {b['ttft_mean_s']:.3g} -> "
                f"{c['ttft_mean_s']:.3g} s (>{LIVE_FACTOR}x)")
    return problems


def run(quick: bool = False, check: bool = False, sim_only: bool = False,
        live: bool = False, profile_dir: Optional[str] = None) -> Dict:
    import jax
    scenarios: Dict[str, Dict] = {}
    scenarios["sim_steady"] = bench_sim_steady()
    scenarios["sim_paged_chunked"] = bench_sim_paged_chunked()
    scenarios["sim_templated"] = bench_sim_templated()
    # live is wall-clock and needs the trained pair: run it on the full
    # artifact pass or on explicit request, never in the default CI smoke
    want_live = (not sim_only) and (live or not (check or quick))
    if want_live:
        scenarios["live_smoke"] = bench_live_smoke(profile_dir=profile_dir)

    parity = telemetry_parity()
    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "sim_rtol": SIM_RTOL, "live_factor": LIVE_FACTOR,
            "drift_tol": DRIFT_TOL,
            "note": ("sim scenarios run on a virtual clock (deterministic; "
                     "--check holds them to sim_rtol); live_smoke is "
                     "wall-clock on the CI machine (factor bounds only)"),
        },
        "scenarios": scenarios,
        "telemetry_parity": parity,
    }

    problems: List[str] = []
    if not parity["ok"]:
        problems.append("telemetry parity BROKEN: the sim schedule differs "
                        "with the hub attached — telemetry is no longer "
                        "read-only")
    if check:
        if os.path.exists(OUT_PATH):
            base = json.load(open(OUT_PATH)).get("scenarios", {})
            problems += _compare(base, scenarios)
        else:
            problems.append(f"--check without a committed baseline "
                            f"({os.path.relpath(OUT_PATH)} missing)")
    payload["check"] = {"ok": not problems, "problems": problems}

    # smoke modes never clobber the committed full artifact
    os.makedirs(RESULTS, exist_ok=True)
    if not (check or quick) or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(payload, f, indent=1, default=float)
        print(f"wrote {os.path.relpath(OUT_PATH)} "
              f"({len(scenarios)} scenarios)")
    else:
        print(f"kept existing {os.path.relpath(OUT_PATH)} "
              f"(smoke mode, {len(scenarios)} scenarios measured)")
    for name, s in scenarios.items():
        drift = s.get("acceptance_drift")
        line = (f"  {name}: goodput {s['goodput_tok_per_s']:.4g} tok/s  "
                f"ttft {s['ttft_mean_s']:.4g}s  itl {s['itl_mean_s']:.4g}s  "
                f"occ {s['mean_occupancy']:.2f}  "
                f"drift {'n/a' if drift is None else format(drift, '+.3f')}")
        if "cache_hit_rate" in s:
            line += (f"  hit-rate {s['cache_hit_rate']:.2f}  "
                     f"ttft-cold {s['ttft_cold_mean_s']:.4g}s")
        print(line)
    if problems:
        for p in problems:
            print(f"CHECK FAILED: {p}")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="sim scenarios only unless --live; never clobbers "
                         "the committed artifact")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: compare against the committed "
                         "BENCH_serving.json, exit nonzero on regression")
    ap.add_argument("--sim-only", action="store_true",
                    help="skip the live engine scenario entirely")
    ap.add_argument("--live", action="store_true",
                    help="include live_smoke even under --quick/--check")
    ap.add_argument("--profile-dir", default=None,
                    help="write a jax profiler trace of the live scenario "
                         "here (implies device phase annotations)")
    args = ap.parse_args(argv)
    payload = run(quick=args.quick, check=args.check, sim_only=args.sim_only,
                  live=args.live, profile_dir=args.profile_dir)
    if args.check and not payload["check"]["ok"]:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
