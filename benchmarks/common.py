"""Shared benchmark infrastructure.

The paper's phenomenon (optimal speculation length shrinking with batch size)
is a *resource-saturation* effect, so it reproduces at CPU scale with a tiny
target/draft pair — provided acceptance l(s) is non-trivial.  Random weights
give l(s) = 0, which voids speculation; so we train both models briefly on
the same **order-2** Markov stream (training/data.py): the 4-layer target
learns the (t-2, t-1)-conditional, the under-parameterized 1-layer draft
mostly captures lower-order structure, and partial argmax agreement
(~0.49/token) emerges naturally - the distilled-draft regime of the paper.
The trained pair is cached in results/bench_models.npz.

All benchmarks write JSON into results/bench/.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig, ModelConfig
from repro.core.spec_decode import SpecDecodeEngine
from repro.training import (AdamWConfig, DataConfig, batch_at, init_adamw,
                            make_train_step, restore, save)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
BENCH_DIR = os.path.join(RESULTS, "bench")
MODELS_PATH = os.path.join(RESULTS, "bench_models.npz")

VOCAB = 512


def target_config() -> ModelConfig:
    return ModelConfig(
        name="bench-target", family="dense", n_layers=4, d_model=256,
        d_ff=1024, vocab_size=VOCAB,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=64),
        dtype="float32", source="benchmark tiny target (paper: OPT-6.7B role)")


def draft_config() -> ModelConfig:
    # deliberately under-parameterized vs the order-2 stream so per-token
    # agreement lands near the paper's ~0.5 (OPT-125M vs OPT-6.7B regime)
    return ModelConfig(
        name="bench-draft", family="dense", n_layers=1, d_model=64,
        d_ff=256, vocab_size=VOCAB,
        attn=AttnConfig(n_heads=2, n_kv_heads=2, head_dim=32),
        dtype="float32", source="benchmark tiny draft (paper: OPT-125M role)")


def _train(model, cfg, steps: int, lr: float, seed: int,
           batch=12, seq=48) -> Tuple[dict, float]:
    params = model.init(jax.random.PRNGKey(seed))
    opt = AdamWConfig(lr=lr, warmup_steps=10, total_steps=steps,
                      weight_decay=0.0)
    state = init_adamw(params)
    step = jax.jit(make_train_step(model, cfg, opt), donate_argnums=(0, 1))
    # order-2 markov: the deep target can learn the (t-2, t-1) conditional,
    # the 1-layer draft mostly cannot -> realistic partial acceptance
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=batch, seq_len=seq,
                    kind="markov2", alphabet=48, skew=0.9, seed=7)
    loss = None
    for i in range(steps):
        params, state, m = step(params, state,
                                {k: jnp.asarray(v) for k, v in batch_at(dc, i).items()})
        loss = float(m["loss"])
    return params, loss


def get_trained_pair(force: bool = False, steps: int = 150,
                     ) -> Tuple[SpecDecodeEngine, dict, dict, Dict]:
    """Returns (engine, tparams, dparams, meta); trains & caches on first use."""
    tcfg, dcfg = target_config(), draft_config()
    engine = SpecDecodeEngine(tcfg, dcfg, max_new=64)
    meta_path = MODELS_PATH + ".meta.json"
    if not force and os.path.exists(MODELS_PATH) and os.path.exists(meta_path):
        tpl = engine.target.init(jax.random.PRNGKey(0))
        dpl = engine.draft.init(jax.random.PRNGKey(1))
        blob, _, _ = restore(MODELS_PATH, {"t": tpl, "d": dpl})
        meta = json.load(open(meta_path))
        return engine, blob["t"], blob["d"], meta
    t0 = time.time()
    tparams, tloss = _train(engine.target, tcfg, steps, 3e-3, seed=0)
    dparams, dloss = _train(engine.draft, dcfg, steps, 1e-2, seed=1)
    meta = {"target_loss": tloss, "draft_loss": dloss,
            "train_s": round(time.time() - t0, 1), "steps": steps}
    os.makedirs(RESULTS, exist_ok=True)
    save(MODELS_PATH, {"t": tparams, "d": dparams})
    json.dump(meta, open(meta_path, "w"))
    return engine, tparams, dparams, meta


def bench_prompts(n: int, seed: int = 123, min_len=8, max_len=24,
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Markov-stream prompts (same distribution the pair was trained on)."""
    dc = DataConfig(vocab_size=VOCAB, batch=n, seq_len=max_len,
                    kind="markov2", alphabet=48, skew=0.9, seed=7)
    toks = batch_at(dc, 10_000 + seed)["tokens"][:, :max_len]
    rng = np.random.default_rng(seed)
    lens = rng.integers(min_len, max_len + 1, size=n).astype(np.int32)
    return toks.astype(np.int32), lens


def timeit(fn, *args, repeats: int = 3, **kw) -> float:
    """Median wall time of fn(*args) with one warmup call."""
    fn(*args, **kw)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(out)[0]) if out is not None else None
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def write_result(name: str, payload: Dict) -> str:
    os.makedirs(BENCH_DIR, exist_ok=True)
    path = os.path.join(BENCH_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path
