"""Fig. 1: per-token latency over the (batch size x speculation length) grid.

Wall-clock measurement of the real batched speculative engine on the trained
tiny pair.  The paper's claims to validate:
  * combining batching + speculation beats either alone;
  * small b -> larger s_opt; large b -> small s_opt (non-increasing trend);
  * too-large s at large b *hurts*.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import bench_prompts, get_trained_pair, write_result


def run(batch_sizes=(1, 2, 4, 8, 16, 32), s_values=tuple(range(0, 9)),
        gen_tokens: int = 48, repeats: int = 2, quick: bool = False) -> Dict:
    if quick:
        batch_sizes, s_values, gen_tokens, repeats = (1, 4, 16), (0, 2, 4), 24, 1
    engine, tp, dp, meta = get_trained_pair()
    grid: Dict[int, Dict[int, float]] = {}
    for b in batch_sizes:
        prompts, lens = bench_prompts(b)
        grid[b] = {}
        for s in s_values:
            best = float("inf")
            # warmup / compile
            st = engine.prefill(tp, dp, prompts, lens, cache_len=256)
            engine.step(tp, dp, st, s)
            for _ in range(repeats):
                st = engine.prefill(tp, dp, prompts, lens, cache_len=256)
                tot, t0 = 0, time.perf_counter()
                while tot < gen_tokens * b:
                    st, stats = engine.step(tp, dp, st, s)
                    tot += int(stats.committed.sum())
                    if bool(np.asarray(st.done).all()):
                        break
                best = min(best, (time.perf_counter() - t0) / max(tot, 1))
            grid[b][s] = best

    s_opt = {b: min(d, key=d.get) for b, d in grid.items()}
    base = {b: grid[b][0] for b in grid}
    speedup = {b: base[b] / grid[b][s_opt[b]] for b in grid}
    vals = [s_opt[b] for b in sorted(s_opt)]
    # non-increasing trend with +-1 tolerance for wall-clock noise
    monotone = all(a >= b - 1 for a, b in zip(vals, vals[1:]))
    payload = {
        "grid_per_token_s": {str(b): {str(s): v for s, v in d.items()}
                             for b, d in grid.items()},
        "s_opt": {str(b): int(v) for b, v in s_opt.items()},
        "speedup_at_s_opt": {str(b): round(v, 3) for b, v in speedup.items()},
        "s_opt_non_increasing_trend": bool(monotone),
        "pair_meta": meta,
    }
    write_result("fig1_grid", payload)
    print("\n=== Fig.1: per-token latency (ms) over (b, s) ===")
    ss = sorted(next(iter(grid.values())))
    print("  b\\s " + "".join(f"{s:>8d}" for s in ss))
    for b in sorted(grid):
        row = "".join(f"{grid[b][s]*1e3:8.2f}" for s in ss)
        print(f"{b:5d} {row}   s_opt={s_opt[b]} speedup={speedup[b]:.2f}x")
    print(f"s_opt non-increasing trend: {monotone}")
    return payload


if __name__ == "__main__":
    run()
