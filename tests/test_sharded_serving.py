"""Sharded continuous serving: `serve_continuous_live(mesh=...)` on a forced
2-device host mesh must produce token-identical outputs and an identical
StepTrace to the 1-device run — for the contiguous slot pool, the paged
block pool under preemption pressure, and chunked admission.

The comparison runs in a subprocess because the device count must be forced
(``XLA_FLAGS=--xla_force_host_platform_device_count=2``) before jax
initialises; the main test process keeps its single CPU device.  Fast tier:
the engine is the tiny smoke pair and the traces are short.
"""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                           + os.environ.get("XLA_FLAGS", ""))
import dataclasses, json
import jax, numpy as np
from repro.configs import registry as R
from repro.core.adaptive import AdaptiveController, SpeculationLUT
from repro.core.spec_decode import SpecDecodeEngine
from repro.launch.mesh import make_serving_mesh
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     PrefillBudgetAdmit,
                                     serve_continuous_live)
from repro.serving.traffic import TrafficPhase, make_requests

assert jax.device_count() == 2, jax.devices()
tcfg = R.get_smoke_config("yi-9b")
d = R.get_draft_config("yi-9b")
dcfg = dataclasses.replace(
    d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
    dtype="float32",
    attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2, head_dim=32))
eng0 = SpecDecodeEngine(tcfg, dcfg, max_new=12)
tparams = eng0.target.init(jax.random.PRNGKey(0))
dparams = eng0.draft.init(jax.random.PRNGKey(1))
mesh = make_serving_mesh(2)
ctrl = lambda: AdaptiveController(lut=SpeculationLUT({1: 3, 2: 2, 4: 2}))

def trace(long=False, hungry=False):
    reqs = make_requests(8, [TrafficPhase(0.002, 1.0, float("inf"))],
                         tcfg.vocab_size, seed=7, max_new=12)
    rng = np.random.default_rng(3)
    for j, r in enumerate(reqs):
        # arrival = 0: the scheduler clock advances by MEASURED wall times,
        # so nonzero arrivals would make admission composition depend on
        # how fast each run's prefills happened to be — the live-vs-live
        # exact-trace assertion below must be purely structural
        r.arrival = 0.0
        if long and j % 3 == 0:
            L = int(rng.integers(40, 60))
            r.tokens = rng.integers(0, tcfg.vocab_size, (L,)).astype(np.int32)
            r.prompt_len = L
        r.max_new = int(rng.integers(10, 13) if hungry
                        else rng.integers(4, 11))
    return reqs

def run(mesh, *, long=False, hungry=False, policy=None, **bkw):
    # fresh engine per run: init_slots resets the jit caches, but a fresh
    # instance makes sharded/unsharded compilations fully independent
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=12)
    be = ContinuousEngineBackend(eng, tparams, dparams, capacity=4,
                                 cache_len=96, warm_s=[2, 3],
                                 collect_outputs=True, mesh=mesh, **bkw)
    res = serve_continuous_live(trace(long=long, hungry=hungry), eng,
                                tparams, dparams, ctrl(), backend=be,
                                policy=policy)
    return res, be

def compare(name, one, two):
    (r1, b1), (r2, b2) = one, two
    t1, t2 = r1.trace, r2.trace
    assert [t.admitted for t in t1] == [t.admitted for t in t2], name
    assert [t.occupancy for t in t1] == [t.occupancy for t in t2], name
    assert [t.committed for t in t1] == [t.committed for t in t2], name
    assert [t.preempted for t in t1] == [t.preempted for t in t2], name
    assert [t.done_rids for t in t1] == [t.done_rids for t in t2], name
    assert [t.chunked for t in t1] == [t.chunked for t in t2], name
    assert set(b1.outputs) == set(b2.outputs), name
    for rid in b1.outputs:
        np.testing.assert_array_equal(b1.outputs[rid], b2.outputs[rid],
                                      err_msg=f"{name} rid {rid}")
    assert b2.n_shards == 2, (name, b2.n_shards)
    return {"iters": len(t1),
            "preempts": sum(len(t.preempted) for t in t1),
            "chunks": sum(len(t.chunked) for t in t1)}

out = {}
out["contiguous"] = compare("contiguous", run(None), run(mesh))
# undersized paged pool + near-engine-max budgets => preemption pressure
pg = dict(long=True, hungry=True, block_size=8, num_blocks=14)
out["paged"] = compare("paged", run(None, **pg), run(mesh, **pg))
ck = dict(long=True)
out["chunked"] = compare(
    "chunked",
    run(None, policy=PrefillBudgetAdmit(token_budget=16), **ck),
    run(mesh, policy=PrefillBudgetAdmit(token_budget=16), **ck))
print(json.dumps(out))
"""


def test_sharded_serve_parity_two_devices():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)           # the script forces its own devices
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    # every study compared trace-identical and token-identical inside the
    # subprocess; here we only sanity-check each actually exercised its path
    assert out["contiguous"]["iters"] > 0
    assert out["paged"]["preempts"] > 0, out
    assert out["chunked"]["chunks"] > 0, out
