"""repro-lint (tools/lint): per-rule fixtures — true positive, true
negative, pragma suppression, stale-pragma detection — plus the CLI
contract (exit codes, sorted/stable --json, baseline subtraction), the
citier ``lint`` tier failing on an injected violation, and the standing
gate that the repo's own tree is lint-clean.  All fast tier."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

from tools.lint.cli import (EXIT_CLEAN, EXIT_FINDINGS, EXIT_NO_FILES,
                            EXIT_USAGE, lint_paths, main)


def write(tmp_path, rel, body):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(body))
    return str(path)


def findings_for(tmp_path, rel, body, rule=None):
    write(tmp_path, rel, body)
    found, n = lint_paths([str(tmp_path)])
    assert n >= 1
    return [f for f in found if rule is None or f.rule == rule]


# ---------------------------------------------------------------- host-sync

HOT_SYNCS = """\
    import numpy as np
    import jax

    def kernel_wrapper(x):
        n = x.item()
        jax.device_get(x)
        x.block_until_ready()
        return np.asarray(x), n
"""


def test_host_sync_true_positive_kernels(tmp_path):
    fs = findings_for(tmp_path, "kernels/wrap.py", HOT_SYNCS, "host-sync")
    assert len(fs) == 4
    assert all(f.severity == "error" for f in fs)
    assert all(f.file.endswith("kernels/wrap.py") for f in fs)


def test_host_sync_true_negative_outside_hot_zone(tmp_path):
    # identical code in a non-hot file: the sim backend / bench layer may
    # sync freely
    assert findings_for(tmp_path, "serving/sim.py", HOT_SYNCS,
                        "host-sync") == []


def test_host_sync_hot_functions_only_in_engine_files(tmp_path):
    body = """\
        import numpy as np

        class SpecDecodeEngine:
            def step(self, state):
                return np.asarray(state.done)

            def build_report(self, state):
                return np.asarray(state.done)
    """
    fs = findings_for(tmp_path, "core/spec_decode.py", body, "host-sync")
    assert len(fs) == 1 and fs[0].line == 5


def test_host_sync_int_on_traced_value(tmp_path):
    body = """\
        import jax.numpy as jnp

        def helper(a, b):
            total = jnp.dot(a, b).sum()
            plain = len(b)
            return int(total), int(plain)
    """
    fs = findings_for(tmp_path, "kernels/wrap.py", body, "host-sync")
    assert len(fs) == 1
    assert "`total`" in fs[0].message


def test_host_sync_tolist_in_hot_zone(tmp_path):
    body = """\
        import jax.numpy as jnp

        def kernel_wrapper(x):
            rows = x.tolist()
            keep = x.tolist(0)      # not the 0-arg array method
            return rows, keep
    """
    fs = findings_for(tmp_path, "kernels/wrap.py", body, "host-sync")
    assert len(fs) == 1 and fs[0].line == 4
    assert ".tolist()" in fs[0].message


def test_host_sync_numpy_scalar_cast_on_traced_value(tmp_path):
    body = """\
        import numpy as np
        import jax.numpy as jnp

        def helper(a, b):
            total = jnp.dot(a, b).sum()
            plain = len(b)
            lit = np.float32(0.5)
            return np.float32(total), np.float64(total), np.float64(plain), lit
    """
    fs = findings_for(tmp_path, "kernels/wrap.py", body, "host-sync")
    # only the two casts of the traced local fire — the plain-int cast and
    # the literal are fine
    assert len(fs) == 2
    assert all("`total`" in f.message for f in fs)
    assert {m for f in fs for m in ("np.float32", "np.float64")
            if m in f.message} == {"np.float32", "np.float64"}


def test_host_sync_literal_conversion_is_warning(tmp_path):
    body = """\
        import numpy as np

        def scale_table(x):
            return np.asarray([1.0, 0.5, 0.25])
    """
    fs = findings_for(tmp_path, "kernels/wrap.py", body, "host-sync")
    assert len(fs) == 1 and fs[0].severity == "warning"


# ------------------------------------------------------------- jit-sharding

def test_jit_sharding_true_positive(tmp_path):
    body = """\
        import jax

        def build(fn):
            return jax.jit(fn)
    """
    fs = findings_for(tmp_path, "core/engine.py", body, "jit-sharding")
    assert len(fs) == 1 and fs[0].severity == "error"


def test_jit_sharding_explicit_shardings_pass(tmp_path):
    body = """\
        import jax

        def build(fn, sh):
            return jax.jit(fn, in_shardings=sh, out_shardings=sh)
    """
    assert findings_for(tmp_path, "core/engine.py", body,
                        "jit-sharding") == []


def test_jit_sharding_half_sharded_flagged(tmp_path):
    body = """\
        import jax

        def build(fn, sh):
            return jax.jit(fn, in_shardings=sh)
    """
    fs = findings_for(tmp_path, "core/engine.py", body, "jit-sharding")
    assert len(fs) == 1 and "out_shardings" in fs[0].message


def test_jit_sharding_unsharded_branch_recognized(tmp_path):
    body = """\
        import jax

        def build(fn, sh, cap):
            if sh is None or cap != 8:
                return jax.jit(fn)
            f = jax.jit(fn) if sh is None else jax.jit(
                fn, in_shardings=sh, out_shardings=sh)
            return f
    """
    assert findings_for(tmp_path, "core/engine.py", body,
                        "jit-sharding") == []


def test_jit_sharding_out_of_scope_file(tmp_path):
    body = """\
        import jax

        def build(fn):
            return jax.jit(fn)
    """
    assert findings_for(tmp_path, "launch/train.py", body,
                        "jit-sharding") == []


# ------------------------------------------------------------- scatter-drop

def test_scatter_drop_true_positive(tmp_path):
    body = """\
        def write(cache, rows, k):
            return cache["k"].at[rows].set(k)
    """
    fs = findings_for(tmp_path, "models/m.py", body, "scatter-drop")
    assert len(fs) == 1 and 'mode="drop"' in fs[0].message


def test_scatter_drop_mode_drop_passes(tmp_path):
    body = """\
        def write(cache, rows, k, lk):
            a = cache["k"].at[rows].set(k, mode="drop")
            b = lk.at[rows].add(k, mode="drop")
            return a, b
    """
    assert findings_for(tmp_path, "models/m.py", body, "scatter-drop") == []


def test_scatter_drop_non_cache_array_ignored(tmp_path):
    body = """\
        def route(buf, idx, x):
            return buf.at[idx].set(x)
    """
    assert findings_for(tmp_path, "models/moe.py", body,
                        "scatter-drop") == []


def test_scatter_drop_out_of_scope_dir(tmp_path):
    body = """\
        def write(cache, rows, k):
            return cache["k"].at[rows].set(k)
    """
    assert findings_for(tmp_path, "training/opt.py", body,
                        "scatter-drop") == []


# ---------------------------------------------------------------- cow-write

def test_cow_write_true_positive_serving(tmp_path):
    body = """\
        def inject(tc, rows, k, pos):
            a = tc["k"].at[rows].set(k, mode="drop")
            b = pos.at[rows].set(0)
            return a, b
    """
    fs = findings_for(tmp_path, "serving/slots.py", body, "cow-write")
    assert len(fs) == 2 and all(f.severity == "error" for f in fs)
    assert all("block-copy" in f.message for f in fs)


def test_cow_write_block_table_and_plain_arrays_ignored(tmp_path):
    # `bt` is per-slot host state (never shared) and generic buffers are
    # out of scope — only pool-backed KV leaves are guarded
    body = """\
        def route(tc, buf, idx, x):
            a = tc["bt"].at[idx].set(x)
            b = buf.at[idx].set(x)
            return a, b
    """
    assert findings_for(tmp_path, "serving/slots.py", body, "cow-write") == []


def test_cow_write_dynamic_key_pool_chain_flagged(tmp_path):
    body = """\
        def wipe(pool_kv, key, idx, x):
            return pool_kv[key].at[idx].set(x)
    """
    fs = findings_for(tmp_path, "core/spec_decode.py", body, "cow-write")
    assert len(fs) == 1


def test_cow_write_block_copy_helper_exempt(tmp_path):
    body = """\
        def _build_block_copy(tc):
            def block_copy(tc, src, dst, k):
                return tc["k"].at[dst].set(tc["k"][src], mode="drop")
            return block_copy
    """
    assert findings_for(tmp_path, "core/spec_decode.py", body,
                        "cow-write") == []


def test_cow_write_out_of_scope_dir(tmp_path):
    # models/ scatters answer to scatter-drop, not the sharing contract
    body = """\
        def write(tc, rows, k):
            return tc["k"].at[rows].set(k, mode="drop")
    """
    assert findings_for(tmp_path, "models/m.py", body, "cow-write") == []


def test_cow_write_pragma_suppresses(tmp_path):
    body = """\
        def inject(tc, rows, k):
            # lint: allow-cow-write(freshly allocated, refcount 1)
            return tc["k"].at[rows].set(k, mode="drop")
    """
    assert findings_for(tmp_path, "serving/slots.py", body) == []


# ------------------------------------------------------- telemetry-readonly

def test_telemetry_forbidden_import(tmp_path):
    body = """\
        from repro.serving.slots import BlockPool
        import repro.core.spec_decode
    """
    fs = findings_for(tmp_path, "serving/telemetry.py", body,
                      "telemetry-readonly")
    assert len(fs) == 2


def test_telemetry_mutation_call(tmp_path):
    body = """\
        def snoop(pool, slot):
            pool.release(slot)
            return pool.gauges()
    """
    fs = findings_for(tmp_path, "serving/telemetry.py", body,
                      "telemetry-readonly")
    assert len(fs) == 1 and ".release()" in fs[0].message


def test_telemetry_reads_are_fine(tmp_path):
    body = """\
        import numpy as np

        def observe(trace):
            return float(np.mean([b.duration for b in trace]))
    """
    assert findings_for(tmp_path, "serving/telemetry.py", body,
                        "telemetry-readonly") == []


def test_telemetry_rule_only_binds_to_telemetry_module(tmp_path):
    body = """\
        def drive(pool, slot):
            pool.release(slot)
    """
    assert findings_for(tmp_path, "serving/scheduler_helpers.py", body,
                        "telemetry-readonly") == []


# -------------------------------------------------------- pallas-index-map

def test_pallas_index_map_captured_local_flagged(tmp_path):
    body = """\
        from jax.experimental import pallas as pl

        def kernel(x, table):
            spec = pl.BlockSpec((1, 128), lambda i, j: (table[i], j))
            return spec
    """
    fs = findings_for(tmp_path, "kernels/k.py", body, "pallas-index-map")
    assert len(fs) == 1 and "`table`" in fs[0].message


def test_pallas_index_map_compute_flagged(tmp_path):
    body = """\
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def kernel(x):
            spec = pl.BlockSpec((1, 128),
                                lambda i, bt: (jnp.sum(bt[i]), 0))
            return spec
    """
    fs = findings_for(tmp_path, "kernels/k.py", body, "pallas-index-map")
    assert len(fs) == 1 and "jnp.sum" in fs[0].message


def test_pallas_index_map_clamped_prefetch_passes(tmp_path):
    # the shape PR 5's fused kernel uses: a named def over grid indices +
    # the scalar-prefetched block table, clamped with jnp.maximum
    body = """\
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def kernel(x):
            def _kv_map(b, j, bt):
                return (jnp.maximum(bt[b, j], 0), 0, 0, 0)

            specs = [pl.BlockSpec((1, 8, 1, 128), _kv_map),
                     pl.BlockSpec((1, 8), lambda b, j, bt: (b, 0))]
            return specs
    """
    assert findings_for(tmp_path, "kernels/k.py", body,
                        "pallas-index-map") == []


# ------------------------------------------------------------------ pragmas

def test_pragma_suppresses_same_line(tmp_path):
    body = """\
        import numpy as np

        def wrap(x):
            return np.asarray(x)  # lint: allow-host-sync(test fence)
    """
    fs = findings_for(tmp_path, "kernels/w.py", body)
    assert fs == []


def test_pragma_standalone_suppresses_next_line(tmp_path):
    body = """\
        import numpy as np

        def wrap(x):
            # lint: allow-host-sync(test fence)
            return np.asarray(x)
    """
    assert findings_for(tmp_path, "kernels/w.py", body) == []


def test_stale_pragma_is_an_error(tmp_path):
    body = """\
        def wrap(x):
            return x + 1  # lint: allow-host-sync(nothing to excuse)
    """
    fs = findings_for(tmp_path, "kernels/w.py", body)
    assert len(fs) == 1
    assert fs[0].rule == "stale-pragma" and fs[0].severity == "error"


def test_pragma_without_reason_is_an_error(tmp_path):
    body = """\
        import numpy as np

        def wrap(x):
            return np.asarray(x)  # lint: allow-host-sync()
    """
    fs = findings_for(tmp_path, "kernels/w.py", body)
    # the reasonless pragma suppresses nothing: original finding + error
    rules = sorted(f.rule for f in fs)
    assert rules == ["host-sync", "malformed-pragma"]


def test_pragma_only_matches_its_rule(tmp_path):
    body = """\
        import numpy as np

        def wrap(x):
            return np.asarray(x)  # lint: allow-scatter-drop(wrong rule)
    """
    rules = sorted(f.rule for f in findings_for(tmp_path, "kernels/w.py",
                                                body))
    assert rules == ["host-sync", "stale-pragma"]


# ---------------------------------------------------------------- CLI shape

def test_exit_codes(tmp_path, capsys):
    empty = tmp_path / "empty"
    empty.mkdir()
    assert main([str(empty)]) == EXIT_NO_FILES
    capsys.readouterr()
    write(tmp_path, "clean/ok.py", "X = 1\n")
    assert main([str(tmp_path / "clean")]) == EXIT_CLEAN
    capsys.readouterr()
    write(tmp_path, "models/bad.py",
          "def w(cache, r, k):\n    return cache['k'].at[r].set(k)\n")
    assert main([str(tmp_path / "models")]) == EXIT_FINDINGS
    capsys.readouterr()
    assert main([str(tmp_path / "missing")]) == EXIT_USAGE
    assert main([]) == EXIT_USAGE


def test_json_output_sorted_and_stable(tmp_path, capsys):
    write(tmp_path, "models/bad.py",
          "def w(cache, r, k):\n"
          "    a = cache['v'].at[r].set(k)\n"
          "    b = cache['k'].at[r].set(k)\n"
          "    return a, b\n")
    outs = []
    for _ in range(2):
        assert main([str(tmp_path), "--json"]) == EXIT_FINDINGS
        outs.append(capsys.readouterr().out)
    assert outs[0] == outs[1]
    payload = json.loads(outs[0])
    assert [f["line"] for f in payload] == [2, 3]
    keys = set(payload[0])
    assert keys == {"file", "line", "col", "rule", "severity", "message"}


def test_baseline_subtracts_known_findings(tmp_path, capsys):
    write(tmp_path, "models/bad.py",
          "def w(cache, r, k):\n    return cache['k'].at[r].set(k)\n")
    assert main([str(tmp_path), "--json"]) == EXIT_FINDINGS
    baseline = tmp_path / "baseline.json"
    baseline.write_text(capsys.readouterr().out)
    assert main([str(tmp_path), "--baseline", str(baseline)]) == EXIT_CLEAN


def test_syntax_error_is_a_finding(tmp_path):
    write(tmp_path, "models/broken.py", "def w(:\n")
    found, _ = lint_paths([str(tmp_path)])
    assert [f.rule for f in found] == ["parse-error"]


# ----------------------------------------------------------- standing gates

def test_repo_tree_is_lint_clean():
    """The acceptance gate: HEAD carries zero findings (fixes + justified
    pragmas only)."""
    findings, n_files = lint_paths([os.path.join(ROOT, "src")])
    assert n_files > 40
    assert findings == [], "\n".join(
        f"{f.file}:{f.line}: {f.rule}: {f.message}" for f in findings)


def test_committed_baseline_is_empty():
    path = os.path.join(ROOT, "tools", "lint", "baseline.json")
    assert json.load(open(path)) == []


def test_citier_lint_tier_fails_on_injected_violation(tmp_path):
    write(tmp_path, "models/bad.py",
          "def w(cache, r, k):\n    return cache['k'].at[r].set(k)\n")
    citier = os.path.join(ROOT, "tools", "citier.py")
    bad = subprocess.run([sys.executable, citier, "lint", str(tmp_path)],
                         capture_output=True, text=True)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "scatter-drop" in bad.stdout
    empty = tmp_path / "empty"
    empty.mkdir()
    vacuous = subprocess.run([sys.executable, citier, "lint", str(empty)],
                             capture_output=True, text=True)
    assert vacuous.returncode == 2  # zero files is loud, not green
    good = subprocess.run([sys.executable, citier, "lint"],
                          capture_output=True, text=True)
    assert good.returncode == 0, good.stdout + good.stderr
