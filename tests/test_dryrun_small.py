"""CI-scale pjit dry-run: exercises the exact launch/specs + meshctx path on
8 virtual devices in a subprocess (so the main test process keeps its single
CPU device).  The 512-device production sweep is run out-of-band via
``python -m repro.launch.dryrun --all`` (results in results/dryrun/)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import build_plan
from repro.launch.dryrun import collective_bytes, cost_analysis_dict
from repro.runtime.meshctx import use_mesh

mesh = make_test_mesh(2, 4)
out = {}
for arch, shape in [("internlm2-1.8b", "decode_32k"),
                    ("internlm2-1.8b", "train_4k"),
                    ("mamba2-1.3b", "long_500k")]:
    plan = build_plan(arch, shape, mesh)
    with use_mesh(mesh):
        compiled = plan.lower().compile()
    ca = cost_analysis_dict(compiled)
    out[f"{arch}|{shape}"] = {
        "flops": ca.get("flops", 0.0),
        "colls": collective_bytes(compiled.as_text()),
        "temp": compiled.memory_analysis().temp_size_in_bytes,
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_dryrun_compiles():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert len(out) == 3
    for key, rec in out.items():
        assert rec["flops"] > 0, key
        # sharded programs must actually communicate
        assert sum(rec["colls"].values()) > 0, key


def test_production_dryrun_records_if_present():
    """Validate any records the out-of-band 512-device sweep has produced."""
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("production dry-run not yet executed")
    n = 0
    for f in os.listdir(d):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(d, f)))
        assert rec["chips"] in (256, 512)
        rf = rec["roofline"]
        assert rf["compute_s"] > 0 and rf["memory_s"] > 0
        assert rf["dominant"] in ("compute", "memory", "collective")
        n += 1
    assert n >= 1
