"""graph-lint (tools/graphlint): the compiled-artifact contract checker.

The heavy fixture drives the real engine through the driver's paged+fused
double replay once per module and every check reads from it: retrace
stability (each (name, key) jit traces exactly once, the second identical
replay traces nothing), transfer-free jaxprs, no gathered-KV
materialization on the fused path (with the gather-path probe proving the
detector sees the view it is banning), and donation aliasing in the
lowered HLO.  Pass logic is also unit-tested on fabricated entries, and
the subprocess tests prove the CLI/citier gate fails *loudly* on injected
violations (exit 1) and on a vacuous zero-jit run (exit 5).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spec_decode import JitEntry
from tools.graphlint import cli as gl_cli
from tools.graphlint.passes import (donation, materialize, retrace,
                                    sharding, transfer_free)
from tools.lint import pragmas as P
from tools.lint.report import Finding

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def collections():
    from tools.graphlint import driver
    return driver.collect_fused(), driver.collect_gather_probe()


def _entry(name="step", key=(1, 1), **kw):
    defaults = dict(hot=True, kv_args=(), donate=(), sharded=False,
                    out_shardings=None, paged_rows=None, paged_fused=None,
                    src_file="src/repro/core/spec_decode.py", src_line=10)
    defaults.update(kw)
    return JitEntry(name=name, key=tuple(key), **defaults)


def _jaxprs(entries):
    return {(e.name, e.key): e.fn.trace(*e.arg_specs).jaxpr
            for e in entries if e.arg_specs is not None}


# ---------------------------------------------------------------------------
# the driven collection


def test_registry_covers_every_dispatch_family(collections):
    """The replay exercises every paged-serving jit family the engine can
    build — if a family is missing here, the driver's trace shrank and the
    passes went partially blind."""
    col, _ = collections
    names = {e.name for e in col.entries}
    assert names >= {"step", "prefill", "inject", "inject_paged",
                     "chunk", "chunk_begin", "chunk_commit", "retire_paged"}
    # the adaptive LUT sweeps s with occupancy: at least two step keys
    assert len([e for e in col.entries if e.name == "step"]) >= 2


def test_retrace_stability_exactly_once_then_cached(collections):
    """Satellite contract: one full serving replay compiles each (name,
    key) exactly once, and an identical second replay against the same
    engine compiles nothing at all."""
    col, _ = collections
    assert col.run1 and all(n == 1 for n in col.run1.values()), col.run1
    assert all(n == 0 for n in col.run2.values()), col.run2
    assert retrace.check(col.entries, col.run1, col.run2) == []


def test_transfer_free_on_real_engine(collections):
    col, probe = collections
    assert transfer_free.check(col.entries, _jaxprs(col.entries)) == []
    assert transfer_free.check(probe.entries, _jaxprs(probe.entries)) == []


def test_fused_never_materializes_and_probe_does(collections):
    col, probe = collections
    findings = materialize.check(
        col.entries, _jaxprs(col.entries), col.kv_trailing,
        guard_entries=probe.entries, guard_jaxprs=_jaxprs(probe.entries))
    assert findings == []
    # the probe's gather-path step really builds the [B, L, KVH, hd] view
    e = next(e for e in probe.entries if e.name == "step")
    hits = materialize.find_gathered_views(
        e.fn.trace(*e.arg_specs).jaxpr.jaxpr, e.paged_rows, col.kv_trailing)
    assert hits, "gather probe lost the materialized view"


def test_donation_aliased_in_lowering(collections):
    col, _ = collections
    lowered = {(e.name, e.key): e.fn.lower(*e.arg_specs).as_text()
               for e in col.entries
               if e.name in donation.DONATING_NAMES and e.arg_specs}
    assert lowered, "no donating jits collected"
    assert donation.check(col.entries, lowered) == []


def test_sharded_collection_needs_two_devices():
    from tools.graphlint import driver
    if len(jax.devices()) < 2:
        assert driver.collect_sharded() is None


# ---------------------------------------------------------------------------
# pass logic on fabricated entries


def test_transfer_free_catches_callback():
    def fn(x):
        jax.debug.print("x={}", x)
        return x + 1

    e = _entry(name="step", key=(2, 2))
    e.fn = jax.jit(fn)
    e.arg_specs = (jax.ShapeDtypeStruct((4,), jnp.float32),)
    jaxprs = {(e.name, e.key): e.fn.trace(*e.arg_specs).jaxpr}
    findings = transfer_free.check([e], jaxprs)
    assert len(findings) == 1 and findings[0].rule == "transfer-free"
    assert "callback" in findings[0].message


def test_donation_flags_lost_annotation_and_undonated():
    lost = _entry(name="retire", key=(), kv_args=(), donate=())
    undonated = _entry(name="inject", key=(), kv_args=(0,), donate=())
    f = donation.check([lost, undonated], {})
    assert [x.rule for x in f] == ["donation", "donation"]
    assert "annotation was lost" in f[0].message
    assert "not donated" in f[1].message


def test_donation_flags_declined_aliasing():
    e = _entry(name="inject", key=(), kv_args=(0,), donate=(0,))
    e.arg_specs = ((jax.ShapeDtypeStruct((2, 2), jnp.float32),
                    jax.ShapeDtypeStruct((2, 2), jnp.float32)),)
    f = donation.check([e], {(e.name, e.key): "module @jit_inject {}"})
    assert len(f) == 1 and "aliases only 0" in f[0].message


def test_retrace_flags_midflight_and_repeat_compiles():
    a = _entry(name="step", key=(1, 1))
    b = _entry(name="step", key=(1, 2))
    f = retrace.check([a, b],
                      {("step", (1, 1)): 3, ("step", (1, 2)): 1},
                      {("step", (1, 2)): 2})
    assert len(f) == 2
    assert "traced 3x" in f[0].message
    assert "retraced 2x" in f[1].message


def test_materialize_vacuous_guard_fires():
    e = _entry(name="step", key=(1, 1), paged_rows=16, paged_fused=True)

    def clean(x):
        return x * 2.0

    e.fn = jax.jit(clean)
    e.arg_specs = (jax.ShapeDtypeStruct((2, 4), jnp.float32),)
    jaxprs = {(e.name, e.key): e.fn.trace(*e.arg_specs).jaxpr}
    probe = _entry(name="step", key=(9, 9), paged_rows=16, paged_fused=False)
    probe.fn = e.fn
    probe.arg_specs = e.arg_specs
    guard_jaxprs = {(probe.name, probe.key): jaxprs[(e.name, e.key)]}
    f = materialize.check([e], jaxprs, (2, 4),
                          guard_entries=[probe], guard_jaxprs=guard_jaxprs)
    assert len(f) == 1 and "vacuous" in f[0].message


def test_find_gathered_views_trailing_filter():
    def gatherish(x):
        # [1, 16, 2, 4]: rows=16 leading + KV trailing (2, 4) => the view
        return jnp.broadcast_to(x, (1, 16, 2, 4)) + 1.0

    closed = jax.make_jaxpr(gatherish)(
        jax.ShapeDtypeStruct((2, 4), jnp.float32))
    assert materialize.find_gathered_views(closed.jaxpr, 16, (2, 4))
    # same rows, wrong KV geometry (a draft-cache-shaped array): filtered
    assert not materialize.find_gathered_views(closed.jaxpr, 16, (1, 8))
    # kernel_bench mode (trailing=None): rows alone decides
    assert materialize.find_gathered_views(closed.jaxpr, 16)


def test_broadcast_decl_prefix_semantics():
    spec = {"k": (jax.ShapeDtypeStruct((2,), jnp.float32),
                  jax.ShapeDtypeStruct((3,), jnp.float32)),
            "v": jax.ShapeDtypeStruct((4,), jnp.float32)}
    # a single None broadcasts over every leaf
    pairs = sharding.broadcast_decl(None, spec)
    assert len(pairs) == 3 and all(d is None for d, _ in pairs)
    # dict prefix: one decl per key, tuple decl zips elementwise
    decl = {"k": (None, None), "v": None}
    pairs = sharding.broadcast_decl(decl, spec)
    assert len(pairs) == 3


def test_sharding_flags_entry_without_shardings():
    e = _entry(name="step", key=(4, 2), sharded=False)
    f = sharding.check([e], {})
    assert len(f) == 1 and "without explicit shardings" in f[0].message


# ---------------------------------------------------------------------------
# pragma grammar + CLI contract


def test_graphlint_pragma_marker_roundtrip():
    src = ("x = 1\n"
           "y = 2  # graphlint: allow-donation(tcache checkpoint cannot alias)\n"
           "z = 3  # graphlint: allow-retrace()\n")
    prags = P.collect("src/repro/core/spec_decode.py", src,
                      pattern=gl_cli.PRAGMA_RE)
    assert [(p.rule, p.target_line) for p in prags] == [
        ("donation", 2), ("retrace", 3)]
    hit = Finding(file="src/repro/core/spec_decode.py", line=2, col=0,
                  rule="donation", severity="error", message="m")
    kept, problems = P.apply([hit], prags)
    assert kept == []                      # the valid pragma suppressed it
    assert [p.rule for p in problems] == ["malformed-pragma"]


def test_repro_lint_marker_is_not_a_graphlint_pragma():
    src = "y = 2  # lint: allow-donation(wrong subsystem)\n"
    assert P.collect("f.py", src, pattern=gl_cli.PRAGMA_RE) == []


def test_exit_codes_match_repro_lint():
    assert (gl_cli.EXIT_CLEAN, gl_cli.EXIT_FINDINGS,
            gl_cli.EXIT_USAGE, gl_cli.EXIT_NO_JITS) == (0, 1, 2, 5)


def _run_cli(*args, timeout=900):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)            # the CLI forces its own devices
    return subprocess.run([sys.executable, "-m", "tools.graphlint", *args],
                          cwd=ROOT, env=env, capture_output=True, text=True,
                          timeout=timeout)


def test_cli_vacuous_run_exits_5():
    proc = _run_cli("--inject", "no-jits")
    assert proc.returncode == 5, proc.stderr[-2000:]
    assert "no jits collected" in proc.stderr


def test_cli_usage_error_exits_2():
    proc = _run_cli("--inject", "bogus")
    assert proc.returncode == 2


@pytest.mark.slow
def test_cli_injected_no_donation_fails_loudly():
    proc = _run_cli("--no-sharded", "--inject", "no-donation")
    assert proc.returncode == 1, (proc.stdout[-2000:], proc.stderr[-2000:])
    assert "donation" in proc.stdout and "not donated" in proc.stdout


@pytest.mark.slow
def test_citier_graph_tier_fails_loudly_on_injected_retrace():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join("tools", "citier.py"), "graph",
         "--no-sharded", "--inject", "retrace"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 1, (proc.stdout[-2000:], proc.stderr[-2000:])
    assert "retraced" in proc.stdout
    assert "graph-lint FAILED" in proc.stderr
