"""Ragged fused paged attention: the real-length-grid kernel must be
BIT-identical to the dense fused kernel (and allclose to the gather
reference) across extreme raggedness patterns — one max-length slot among
1-block slots, all-dead rows, interior table holes, pending
(mid-chunked-prefill) slots, int8 pools, every manual-DMA depth — plus the
launch-planning arithmetic (kernels/tuning.py), the autotune-cache lookup,
and the mixed verify+chunk launch: ``step_with_chunk`` equals
``flush_chunk`` + ``step`` state-for-state on the interpret-mode ragged
kernel, and ``serve_continuous_live(mixed_launch=True)`` is token- and
StepTrace-identical to the unfused run, chunked admission and preemption
included.  Fast tier; citier ``kernels`` runs the kernel-parity subset."""
import dataclasses
import json
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.adaptive import AdaptiveController, SpeculationLUT
from repro.core.spec_decode import SpecDecodeEngine
from repro.kernels.paged import gather_verify_attn, paged_verify_attn
from repro.kernels.paged_verify_attn import (paged_verify_attn_pallas,
                                             ragged_paged_verify_attn_pallas)
from repro.kernels.tuning import (DEFAULT_CONFIG, RaggedConfig, cell_key,
                                  clear_config_cache, dead_tile_fraction,
                                  grid_steps_dense, grid_steps_ragged,
                                  host_cu_blocks, lookup_config)
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     PrefillBudgetAdmit,
                                     serve_continuous_live)
from repro.serving.traffic import TrafficPhase, make_requests

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, k=0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape).astype(dtype)


def _pool(B, lens, NB, bs, MAXB, KVH, hd, seed=0, holes=()):
    """Ragged paged pool (same construction as test_paged_fused_kernel):
    block tables with optional interior -1 holes, pool pos map, and k/v
    pools whose unowned blocks hold garbage."""
    rng = np.random.default_rng(seed)
    k = _rand((NB, bs, KVH, hd), k=seed + 1)
    v = _rand((NB, bs, KVH, hd), k=seed + 2)
    bt = np.full((B, MAXB), -1, np.int32)
    pos = np.full((NB, bs), -1, np.int32)
    order = rng.permutation(NB)
    nxt = 0
    for b, L in enumerate(lens):
        nblk = -(-L // bs) if L else 0
        for j in range(nblk):
            if (b, j) in holes:
                continue
            pb = int(order[nxt]); nxt += 1
            bt[b, j] = pb
            for o in range(bs):
                p = j * bs + o
                if p < L:
                    pos[pb, o] = p
    return k, v, jnp.asarray(bt), jnp.asarray(pos)


def _qpos(lens, T):
    return jnp.asarray(np.stack([
        np.arange(T, dtype=np.int32) + (L - 1) if L else
        np.full(T, -1, np.int32) for L in lens]))


# raggedness matrix: (lens, MAXB, bs, NB, holes) per pattern.  "extreme" is
# the worst case the dense grid pays for: one near-max slot among 1-block
# slots plus an empty (pending / mid-chunked-prefill: device table row all
# -1) slot; "all_dead" has no live query row at all.
_PATTERNS = {
    "basic": ([13, 24, 7], 3, 8, 14, ()),
    "extreme": ([115, 3, 5, 2, 7, 0], 15, 8, 24, ()),
    "all_dead": ([0, 0, 0], 3, 8, 6, ()),
    "holes": ([22, 15, 9], 3, 8, 12, ((0, 1), (2, 0))),
}


def _case(name, T=3, H=4, KVH=2, hd=32):
    lens, MAXB, bs, NB, holes = _PATTERNS[name]
    B = len(lens)
    k, v, bt, pos = _pool(B, lens, NB, bs, MAXB, KVH, hd,
                          seed=len(name), holes=holes)
    q = _rand((B, T, H, hd), k=29 + len(name))
    qp = _qpos(lens, T)
    cu = jnp.asarray(host_cu_blocks(np.asarray(bt)))
    return q, k, v, qp, pos, bt, cu


# ---------------------------------------------------------------------------
# kernel-level parity (interpret mode executes the real kernel body)


@pytest.mark.parametrize("pattern", sorted(_PATTERNS))
def test_ragged_bit_identical_to_dense_fused(pattern):
    """The ragged grid visits a (sub)set of the dense grid's live tiles in
    the same per-slot order, so its output must be BIT-identical to the
    dense fused kernel — and allclose to the gather reference — on every
    raggedness pattern."""
    q, k, v, qp, pos, bt, cu = _case(pattern)
    ragged = ragged_paged_verify_attn_pallas(q, k, v, qp, pos, bt, cu,
                                             interpret=True)
    dense = paged_verify_attn_pallas(q, k, v, qp, pos, bt, interpret=True)
    np.testing.assert_array_equal(np.asarray(ragged), np.asarray(dense))
    want = np.asarray(gather_verify_attn(q, k, v, qp, pos, bt,
                                         use_pallas=False))
    got = np.asarray(ragged)
    live = np.asarray(qp) >= 0                    # dead rows: ragged/dense
    np.testing.assert_allclose(got[live], want[live],  # give 0, gather NaN
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("nbuf", [2, 3, 4])
def test_manual_dma_depths_bit_identical(nbuf):
    """Every manual-DMA ring depth must reproduce the auto-pipelined
    (num_buffers=0) output bit-for-bit on the extreme pattern — buffering
    is a schedule, never a numeric."""
    q, k, v, qp, pos, bt, cu = _case("extreme")
    base = ragged_paged_verify_attn_pallas(q, k, v, qp, pos, bt, cu,
                                           interpret=True)
    dma = ragged_paged_verify_attn_pallas(q, k, v, qp, pos, bt, cu,
                                          num_buffers=nbuf, interpret=True)
    np.testing.assert_array_equal(np.asarray(dma), np.asarray(base))


@pytest.mark.parametrize("nbuf", [0, 2])
def test_ragged_int8_window_prefix(nbuf):
    """int8 pool scales (dequant in-kernel, including through the manual-DMA
    scale stream) plus sliding-window and bidirectional-prefix masking."""
    q, k, v, qp, pos, bt, cu = _case("holes", T=4)
    ks = jnp.max(jnp.abs(k), -1) / 127.0 + 1e-8          # [NB, bs, KVH]
    vs = jnp.max(jnp.abs(v), -1) / 127.0 + 1e-8
    kq = jnp.clip(jnp.round(k / ks[..., None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v / vs[..., None]), -127, 127).astype(jnp.int8)
    for kw in ({}, {"window": 10, "prefix_len": 5}):
        got = ragged_paged_verify_attn_pallas(
            q, kq, vq, qp, pos, bt, cu, k_scale=ks, v_scale=vs,
            num_buffers=nbuf, interpret=True, **kw)
        dense = paged_verify_attn_pallas(q, kq, vq, qp, pos, bt,
                                         k_scale=ks, v_scale=vs,
                                         interpret=True, **kw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))
        want = gather_verify_attn(q, kq, vq, qp, pos, bt, k_scale=ks,
                                  v_scale=vs, use_pallas=False, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_dispatcher_routes_ragged_on_cu_blocks():
    """paged_verify_attn with cu_blocks + forced pallas runs the ragged
    kernel (same numbers as calling it directly); without cu_blocks the
    dense kernel answers; forced-ref ignores cu_blocks entirely."""
    q, k, v, qp, pos, bt, cu = _case("basic")
    via_dispatch = paged_verify_attn(q, k, v, qp, pos, bt, use_pallas=True,
                                     cu_blocks=cu,
                                     config=RaggedConfig(num_buffers=2))
    direct = ragged_paged_verify_attn_pallas(q, k, v, qp, pos, bt, cu,
                                             num_buffers=2, interpret=True)
    np.testing.assert_array_equal(np.asarray(via_dispatch),
                                  np.asarray(direct))
    ref = paged_verify_attn(q, k, v, qp, pos, bt, use_pallas=False,
                            cu_blocks=cu)
    np.testing.assert_allclose(np.asarray(via_dispatch), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# launch planning: grid arithmetic + autotune-cache lookup


def test_grid_step_accounting():
    tables = np.array([[3, 7, -1, -1],      # 2 live
                       [-1, -1, -1, -1],    # empty slot still gets 1 step
                       [1, 2, 5, 9]])       # full
    cu = host_cu_blocks(tables)
    np.testing.assert_array_equal(cu, [0, 2, 3, 7])
    assert grid_steps_ragged(tables) == 7
    assert grid_steps_dense(tables) == 12
    assert dead_tile_fraction(tables) == pytest.approx(5 / 12)
    # interior holes count live entries, not prefix length
    holey = np.array([[4, -1, 8]])
    np.testing.assert_array_equal(host_cu_blocks(holey), [0, 2])


def test_lookup_config_exact_nearest_default(tmp_path):
    path = str(tmp_path / "bench.json")
    clear_config_cache()
    assert lookup_config(4, 4, 8, path=path) == DEFAULT_CONFIG  # no file
    table = {
        "autotune": {
            cell_key(4, 4, 8): {"config": {"num_buffers": 2,
                                           "vmem_limit_bytes": None}},
            cell_key(8, 4, 16): {"config": {"num_buffers": 4,
                                            "vmem_limit_bytes": 33554432}},
        }
    }
    with open(path, "w") as f:
        json.dump(table, f)
    clear_config_cache()
    assert lookup_config(4, 4, 8, path=path) == RaggedConfig(num_buffers=2)
    # nearest-by-log-distance: (7, 4, 14) is closest to the B8/MAXB16 cell
    assert lookup_config(7, 4, 14, path=path) == RaggedConfig(
        num_buffers=4, vmem_limit_bytes=32 << 20)
    clear_config_cache()


# ---------------------------------------------------------------------------
# the mixed verify+chunk launch


CACHE_LEN = 96
BLOCK = 8


@pytest.fixture(scope="module")
def engine():
    tcfg = R.get_smoke_config("yi-9b")
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2,
                                 head_dim=32))
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=24)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = eng.draft.init(jax.random.PRNGKey(1))
    return eng, tp, dp, tcfg


def _mixed_setup(eng, tp, dp, tcfg):
    """Two live decode slots plus one deferred (pending) prefill chunk."""
    rng = np.random.default_rng(5)
    p0 = rng.integers(0, tcfg.vocab_size, (9,)).astype(np.int32)
    p1 = rng.integers(0, tcfg.vocab_size, (13,)).astype(np.int32)
    long_p = rng.integers(0, tcfg.vocab_size, (22,)).astype(np.int32)
    state = eng.init_slots(3, cache_len=CACHE_LEN, block_size=BLOCK)
    state = eng.prefill_into(tp, dp, state, 0, p0, len(p0), CACHE_LEN)
    state = eng.prefill_into(tp, dp, state, 1, p1, len(p1), CACHE_LEN)
    toks = np.ones((8,), np.int32)
    toks[:8] = long_p[:8]
    state, chunk = eng.prefill_chunk_into(tp, dp, state, 2, toks, 0, 8,
                                          len(long_p), defer=True)
    return state, chunk


def test_step_with_chunk_matches_flush_then_step(engine):
    """On the interpret-mode ragged kernel, the ONE mixed verify+chunk
    launch must leave bit-identical row state and step stats to the
    two-launch order (standalone chunk dispatch, then the plain step)."""
    eng, tp, dp, tcfg = engine
    eng.set_paged_fused(True)        # interpret-mode ragged kernel on CPU
    try:
        state_a, chunk_a = _mixed_setup(eng, tp, dp, tcfg)
        state_a = eng.flush_chunk(tp, dp, state_a, chunk_a)
        state_a, st_a = eng.step(tp, dp, state_a, 2)

        state_b, chunk_b = _mixed_setup(eng, tp, dp, tcfg)
        state_b, st_b = eng.step_with_chunk(tp, dp, state_b, 2, chunk_b)
    finally:
        eng.set_paged_fused(None)

    np.testing.assert_array_equal(st_a.accepted, st_b.accepted)
    np.testing.assert_array_equal(st_a.committed, st_b.committed)
    for name in ("seq_lens", "last2", "out", "n_generated", "done"):
        np.testing.assert_array_equal(
            np.asarray(getattr(state_a, name)),
            np.asarray(getattr(state_b, name)), err_msg=name)
    for key in state_a.tcache:
        np.testing.assert_array_equal(np.asarray(state_a.tcache[key]),
                                      np.asarray(state_b.tcache[key]),
                                      err_msg=f"tcache[{key}]")
    for key in state_a.dcache:
        np.testing.assert_array_equal(np.asarray(state_a.dcache[key]),
                                      np.asarray(state_b.dcache[key]),
                                      err_msg=f"dcache[{key}]")


def _trace(tcfg, n=8, seed=11):
    reqs = make_requests(n, [TrafficPhase(0.0005, 1.0, float("inf"))],
                         tcfg.vocab_size, seed=seed, max_new=16)
    rng = np.random.default_rng(3)
    for i, r in enumerate(reqs):
        # arrivals pinned to 0: the schedule must not depend on wall time,
        # or the faster mixed run would admit on a different iteration
        r.arrival = 0.0
        r.max_new = int(rng.integers(10, 17))
        if i % 2 == 0:
            L = int(rng.integers(24, 40))
            r.tokens = rng.integers(0, tcfg.vocab_size, (L,)).astype(np.int32)
            r.prompt_len = L
    return reqs


def _serve(engine, mixed, num_blocks):
    eng, tp, dp, tcfg = engine
    backend = ContinuousEngineBackend(eng, tp, dp, capacity=4,
                                      cache_len=CACHE_LEN, block_size=BLOCK,
                                      num_blocks=num_blocks,
                                      collect_outputs=True, warm_s=(2, 3, 4),
                                      mixed_launch=mixed)
    ctrl = AdaptiveController(lut=SpeculationLUT({1: 4, 2: 3, 4: 2}))
    res = serve_continuous_live(_trace(tcfg), eng, tp, dp, ctrl,
                                backend=backend,
                                policy=PrefillBudgetAdmit(token_budget=16,
                                                          chunk=8))
    return backend, res


@pytest.mark.parametrize("num_blocks,needs_preempt",
                         [(40, False), (20, True)],
                         ids=["chunked", "chunked+preempt"])
def test_serve_mixed_launch_token_and_trace_parity(engine, num_blocks,
                                                   needs_preempt):
    """serve_continuous_live with mixed_launch on vs off: token outputs and
    every non-duration StepTrace field identical, across chunked admission
    and (undersized pool) preemption."""
    b_off, r_off = _serve(engine, False, num_blocks)
    b_on, r_on = _serve(engine, True, num_blocks)
    per_rid = Counter(rid for t in r_on.trace for rid, _ in t.chunked)
    assert per_rid and max(per_rid.values()) >= 3
    if needs_preempt:
        assert any(t.preempted for t in r_on.trace), \
            "pool was not under pressure; the preemption leg lost its bite"
    assert set(b_off.outputs) == set(b_on.outputs)
    for rid in b_off.outputs:
        np.testing.assert_array_equal(b_off.outputs[rid], b_on.outputs[rid],
                                      err_msg=f"rid {rid}")
    assert len(r_off.trace) == len(r_on.trace)
    for t0, t1 in zip(r_off.trace, r_on.trace):
        for f in ("occupancy", "s", "rids", "committed", "admitted",
                  "preempted", "done_rids", "chunked", "cache_hits"):
            assert getattr(t0, f) == getattr(t1, f), f


def test_mixed_launch_needs_paged_pool(engine):
    eng, tp, dp, _ = engine
    with pytest.raises(ValueError, match="paged KV pool"):
        ContinuousEngineBackend(eng, tp, dp, capacity=2,
                                cache_len=CACHE_LEN, mixed_launch=True)
