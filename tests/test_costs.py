"""Analytic cost model cross-checks: cache-byte formulas must equal the
actual cache pytree sizes, and FLOP estimates must bracket MODEL_FLOPS."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry as R
from repro.configs.base import SHAPES, param_count
from repro.launch import costs as C


def _cache_nbytes(model, cfg, B, L):
    if cfg.family == "ssm":
        tree = jax.eval_shape(lambda: model.init_cache(B, dtype=jnp.bfloat16))
    elif cfg.family in ("encdec", "audio"):
        tree = jax.eval_shape(lambda: model.init_cache(B, cache_len=L,
                                                       dtype=jnp.bfloat16,
                                                       src_len=1024))
    else:
        tree = jax.eval_shape(lambda: model.init_cache(B, cache_len=L,
                                                       dtype=jnp.bfloat16))
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-236b", "mamba2-1.3b",
                                  "recurrentgemma-2b", "seamless-m4t-large-v2"])
def test_kv_cache_bytes_matches_real_cache(arch):
    cfg = R.get_config(arch)
    model = R.build_model(cfg)
    B, L = 4, 4096
    actual = _cache_nbytes(model, cfg, B, L)
    est = C.kv_cache_bytes(cfg, B, L if cfg.family != "hybrid" else
                           min(L, cfg.rglru.window), dtype_bytes=2)
    # estimate within 2x (the formula ignores pos arrays / minor buffers)
    assert 0.5 < est / actual < 2.0, (arch, est, actual)


@pytest.mark.parametrize("arch", ["yi-9b", "qwen3-8b", "internlm2-1.8b"])
def test_train_flops_brackets_6nd(arch):
    cfg = R.get_config(arch)
    shape = SHAPES["train_4k"]
    cost = C.train_step_cost(cfg, shape)
    mf = C.model_flops_6nd(cfg, shape.global_batch * shape.seq_len)
    # analytic >= 6ND (it adds remat + full-pair attention) but same order
    assert 1.0 < cost.flops / mf < 4.0, (arch, cost.flops / mf)


def test_decode_cost_scales_with_s():
    cfg, dcfg = R.get_config("yi-9b"), R.get_draft_config("yi-9b")
    shape = SHAPES["decode_32k"]
    c2 = C.decode_step_cost(cfg, dcfg, shape, 2, 32768, 32768)
    c8 = C.decode_step_cost(cfg, dcfg, shape, 8, 32768, 32768)
    assert c8.flops > c2.flops
    # verify flops scale ~ (s+1)
    assert 2.5 < c8.flops / c2.flops < 3.5
    # memory: weight streaming identical, cache identical
    assert abs(c8.detail["weights_bytes"] - c2.detail["weights_bytes"]) < 1e-3


def test_moe_active_vs_full_params():
    cfg = R.get_config("qwen3-moe-30b-a3b")
    full, active = param_count(cfg), param_count(cfg, active_only=True)
    assert full > 25e9 and active < 5e9         # ~30B total, ~3B active
