"""Stochastic speculative sampling (Leviathan/Chen-style, beyond-paper).

The load-bearing property: for ANY draft, the tokens produced by
speculative sampling are distributed EXACTLY as sampling from the target
alone.  We verify it empirically on a tiny model with a small vocab by
comparing the first-token distribution across many seeded runs against the
target's softmax, plus structural invariants (acceptance bounds, perfect
acceptance when draft == target).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.spec_decode import SpecDecodeEngine

VOCAB_SMALL = 512


def _setup(sample=True, temperature=1.0, draft_same=False, donate=True):
    tcfg = R.get_smoke_config("yi-9b")
    if draft_same:
        dcfg = tcfg
    else:
        dcfg = dataclasses.replace(R.get_smoke_config("internlm2-1.8b"),
                                   vocab_size=tcfg.vocab_size)
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=8, sample=sample,
                           temperature=temperature, donate=donate)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = tp if draft_same else eng.draft.init(jax.random.PRNGKey(1))
    return eng, tp, dp, tcfg


def test_draft_equals_target_accepts_everything():
    eng, tp, dp, tcfg = _setup(draft_same=True)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, tcfg.vocab_size, (2, 8)).astype(np.int32)
    lens = np.full((2,), 8, np.int32)
    state = eng.prefill(tp, dp, toks, lens, 64)
    for i in range(3):
        state, st = eng.step(tp, dp, state, 4, rng=jax.random.PRNGKey(i))
        live = ~np.asarray(state.done)
        # p == q for every draft token -> acceptance prob 1 -> a == s
        assert (st.accepted[:2] == 4).all() or not live.any()


def test_acceptance_bounds_hold_when_sampling():
    eng, tp, dp, tcfg = _setup()
    rng = np.random.default_rng(1)
    toks = rng.integers(0, tcfg.vocab_size, (3, 8)).astype(np.int32)
    lens = np.full((3,), 8, np.int32)
    state = eng.prefill(tp, dp, toks, lens, 64)
    for i in range(3):
        state, st = eng.step(tp, dp, state, 5, rng=jax.random.PRNGKey(10 + i))
        assert (st.accepted >= 0).all() and (st.accepted <= 5).all()
        assert (st.committed <= st.accepted + 1).all()


def test_first_token_distribution_matches_target():
    """Chi-square-style check: empirical first-token frequencies from
    speculative sampling match the target's softmax at the prompt tip."""
    # donate=False: this test deliberately re-steps the SAME prefilled
    # state under 600 different rngs, which pool-buffer donation (the
    # serving default) forbids — a donating step consumes its input state
    eng, tp, dp, tcfg = _setup(donate=False)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, tcfg.vocab_size, (1, 8)).astype(np.int32)
    lens = np.full((1,), 8, np.int32)

    # target distribution at the next position
    m = eng.target
    cache = m.init_cache(1, 64)
    logits, _, _ = m.prefill(tp, jnp.asarray(toks), cache,
                             prompt_lens=jnp.asarray(lens) - 1)
    p = np.asarray(jax.nn.softmax(logits[0]))

    N = 600
    counts = np.zeros(tcfg.vocab_size)
    state0 = eng.prefill(tp, dp, toks, lens, 64)
    for i in range(N):
        st, _ = eng.step(tp, dp, state0, 3, rng=jax.random.PRNGKey(1000 + i))
        first = int(np.asarray(st.out)[0, 0])
        counts[first] += 1
    emp = counts / N
    # compare on the top-probability support (rare tokens are noise-limited)
    top = np.argsort(p)[::-1][:20]
    tv_top = 0.5 * np.abs(emp[top] - p[top]).sum()
    assert tv_top < 0.12, (tv_top, p[top][:5], emp[top][:5])


def test_greedy_mode_unaffected():
    """sample=False path must be byte-identical to before (golden)."""
    eng_g, tp, dp, tcfg = _setup(sample=False)
    rng = np.random.default_rng(3)
    toks = rng.integers(0, tcfg.vocab_size, (2, 8)).astype(np.int32)
    lens = np.full((2,), 8, np.int32)
    ref, _, _ = eng_g.generate(tp, dp, toks, lens, s=0, cache_len=64)
    spec, _, _ = eng_g.generate(tp, dp, toks, lens, s=3, cache_len=64)
    np.testing.assert_array_equal(ref, spec)
