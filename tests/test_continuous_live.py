"""Live continuous-batching runtime on a real SpecDecodeEngine: slot-pool
correctness (prefill_into vs solo generate), sim-vs-live scheduling parity,
and the scheduling win over the run-to-completion server loop."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.adaptive import AdaptiveController, SpeculationLUT
from repro.core.analytical import LatencyModel
from repro.core.spec_decode import SpecDecodeEngine
from repro.serving.metrics import mean_occupancy, ttft_summary
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     ContinuousScheduler, SimStepBackend,
                                     replay_sources, serve_continuous_live)
from repro.serving.server import EngineBackend, serve
from repro.serving.traffic import TrafficPhase, make_requests, uniform_traffic

CACHE_LEN = 96


@pytest.fixture(scope="module")
def engine():
    tcfg = R.get_smoke_config("yi-9b")
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2, head_dim=32))
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=24)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = eng.draft.init(jax.random.PRNGKey(1))
    return eng, tp, dp, tcfg


def _ctrl():
    return AdaptiveController(lut=SpeculationLUT({1: 4, 2: 3, 4: 2}))


# ---------------------------------------------------------------------------
# engine-level slot pool


def test_prefill_into_matches_solo_generate(engine):
    """Tokens generated in a shared live batch — including a request injected
    mid-flight and a reused slot — must equal each prompt's solo output."""
    eng, tp, dp, tcfg = engine
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, tcfg.vocab_size, (L,)).astype(np.int32)
               for L in (8, 6, 9)]
    refs = []
    for p in prompts:
        out, _, _ = eng.generate(tp, dp, p[None, :],
                                 np.array([len(p)], np.int32), s=3,
                                 cache_len=CACHE_LEN)
        refs.append(out[0])

    state = eng.init_slots(4, cache_len=CACHE_LEN)
    assert bool(np.asarray(state.done).all())          # all slots empty
    state = eng.prefill_into(tp, dp, state, 0, prompts[0], len(prompts[0]), CACHE_LEN)
    state = eng.prefill_into(tp, dp, state, 1, prompts[1], len(prompts[1]), CACHE_LEN)
    for _ in range(2):                                 # run 0/1 two steps ahead
        state, st = eng.step(tp, dp, state, 3)
        assert (st.committed[2:] == 0).all()           # empty slots stay silent
    state = eng.prefill_into(tp, dp, state, 2, prompts[2], len(prompts[2]), CACHE_LEN)
    for _ in range(40):
        state, _ = eng.step(tp, dp, state, 3)
        if bool(np.asarray(state.done)[:3].all()):
            break
    out = np.asarray(state.out)[:, :eng.max_new]
    for i in range(3):
        np.testing.assert_array_equal(out[i], refs[i], err_msg=f"slot {i}")

    # retire slot 0 and reuse it for a fresh prompt
    state = eng.retire_slot(state, 0)
    p = rng.integers(0, tcfg.vocab_size, (7,)).astype(np.int32)
    state = eng.prefill_into(tp, dp, state, 0, p, 7, CACHE_LEN)
    for _ in range(40):
        state, _ = eng.step(tp, dp, state, 3)
        if bool(np.asarray(state.done)[0]):
            break
    ref, _, _ = eng.generate(tp, dp, p[None, :], np.array([7], np.int32),
                             s=3, cache_len=CACHE_LEN)
    np.testing.assert_array_equal(np.asarray(state.out)[0, :eng.max_new], ref[0])


# ---------------------------------------------------------------------------
# serve_continuous_live


def _trace(tcfg, n=20, seed=7, burst=False):
    phases = ([TrafficPhase(0.004, 5.0, float("inf"))] if burst
              else [TrafficPhase(0.0005, 1.0, float("inf"))])
    reqs = make_requests(n, phases, tcfg.vocab_size, seed=seed, max_new=16)
    rng = np.random.default_rng(3)
    for r in reqs:
        r.max_new = int(rng.integers(4, 17))
    return reqs


def test_serve_continuous_live_serves_trace(engine):
    eng, tp, dp, tcfg = engine
    reqs = _trace(tcfg)
    res = serve_continuous_live(reqs, eng, tp, dp, _ctrl(), capacity=4,
                                cache_len=CACHE_LEN)
    assert all(r.finish is not None and r.finish > r.arrival for r in res.requests)
    assert sum(b.tokens_generated for b in res.batches) == sum(r.max_new for r in reqs)
    assert all(r.n_generated == r.max_new for r in res.requests)
    assert max(t.occupancy for t in res.trace) <= 4
    # adaptive: s re-chosen from live occupancy every iteration
    ctrl = _ctrl()
    for t in res.trace:
        assert t.s == ctrl.choose(t.occupancy)
    assert len({t.occupancy for t in res.trace}) > 1
    assert ttft_summary(res).mean > 0
    assert 1.0 <= mean_occupancy(res) <= 4.0


def test_sim_vs_live_scheduling_parity(engine):
    """Same trace, same scheduler: the sim backend replaying the live run's
    observed outcomes (commit counts, step/prefill durations) must reproduce
    the live admission order, batch-size sequence, and per-step commits
    exactly."""
    eng, tp, dp, tcfg = engine
    res = serve_continuous_live(_trace(tcfg), eng, tp, dp, _ctrl(),
                                capacity=4, cache_len=CACHE_LEN)
    live = res.trace
    accept, duration, prefill, done, _chunk = replay_sources(live)
    model = LatencyModel(alpha={b: 1e-4 for b in (1, 2, 4)},
                         beta={b: 5e-3 for b in (1, 2, 4)},
                         t_s={b: 2e-4 for b in (1, 2, 4)}, c=0.9, gamma=0.548)
    sim = ContinuousScheduler(
        SimStepBackend(model, capacity=4, accept_source=accept,
                       duration_source=duration, prefill_source=prefill,
                       done_source=done),
        _ctrl())
    res_sim = sim.run(_trace(tcfg))
    assert [t.admitted for t in sim.trace] == [t.admitted for t in live]
    assert [t.occupancy for t in sim.trace] == [t.occupancy for t in live]
    assert [t.committed for t in sim.trace] == [t.committed for t in live]
    # with durations replayed too, per-request latencies agree as well
    np.testing.assert_allclose(res_sim.latencies, res.latencies, rtol=1e-9)


def test_parity_with_eos_retirement(engine):
    """A request stopped by EOS retires through the backend-done path with a
    zero-commit step in the trace; the replay must reproduce that schedule
    too (zero commits encode as accepted = -1)."""
    eng, tp, dp, tcfg = engine
    # EOS = the 3rd greedy token of the first trace request's own stream, so
    # that request is guaranteed to stop within its first ~3 tokens
    r0 = _trace(tcfg, n=8)[0]
    ref, _, _ = eng.generate(tp, dp, np.asarray(r0.tokens)[None, :],
                             np.array([r0.prompt_len], np.int32), s=0,
                             cache_len=CACHE_LEN)
    eos_cfg = R.get_smoke_config("yi-9b")
    eng2 = SpecDecodeEngine(eos_cfg, eng.dcfg, max_new=24,
                            eos_id=int(ref[0, 2]))
    res = serve_continuous_live(_trace(tcfg, n=8), eng2, tp, dp, _ctrl(),
                                capacity=2, cache_len=CACHE_LEN)
    assert all(r.finish is not None for r in res.requests)
    # at least one request must have stopped early for this test to bite
    assert any(r.n_generated < r.max_new for r in res.requests)
    accept, duration, prefill, done, _chunk = replay_sources(res.trace)
    model = LatencyModel(alpha={b: 1e-4 for b in (1, 2)},
                         beta={b: 5e-3 for b in (1, 2)},
                         t_s={b: 2e-4 for b in (1, 2)}, c=0.9, gamma=0.548)
    sim = ContinuousScheduler(
        SimStepBackend(model, capacity=2, accept_source=accept,
                       duration_source=duration, prefill_source=prefill,
                       done_source=done),
        _ctrl())
    sim.run(_trace(tcfg, n=8))
    assert [t.occupancy for t in sim.trace] == [t.occupancy for t in res.trace]
    assert [t.committed for t in sim.trace] == [t.committed for t in res.trace]


def test_live_continuous_beats_run_to_completion(engine):
    """Bursty trace, equal max_batch: iteration-level scheduling must beat
    the paper's run-to-completion loop (head-of-line blocking) on mean
    latency — the live analogue of fig7.

    Wall-clock comparisons are sensitive to transient machine load, so each
    scheme runs twice in alternating order and the best run of each is
    compared (the structural gap is ~2-3x; this only filters noise).
    """
    eng, tp, dp, tcfg = engine
    ctrl = _ctrl()
    cont, rtc = [], []
    backend = EngineBackend(eng, tp, dp, cache_len=CACHE_LEN)
    for _ in range(2):
        res_c = serve_continuous_live(_trace(tcfg, n=24, burst=True), eng, tp,
                                      dp, ctrl, capacity=4, cache_len=CACHE_LEN)
        cont.append(res_c.mean_latency)
        res_r = serve(_trace(tcfg, n=24, burst=True), backend, ctrl, max_batch=4)
        rtc.append(res_r.mean_latency)
    assert min(cont) < min(rtc), (cont, rtc)
