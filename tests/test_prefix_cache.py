"""Copy-on-write prefix cache over the paged BlockPool.

Correctness backbone of cross-request prefix sharing
(serving/prefix_cache.py + the refcount/COW extensions of serving/slots.py):

  * unit tests of the refcount lifecycle, the radix index, LRU eviction,
    and the sharing-aware fragmentation/occupancy accounting;
  * a property-based campaign driving hundreds of random interleavings of
    admit / share / COW-write / insert / retire / evict against a shadow
    reference model — no double-free, no leaked block, no in-place write
    to a shared block, radix round-trips (fast; pure host accounting);
  * end-to-end parity on the live engine: shared-prefix runs are token-
    AND StepTrace-identical to cold runs (fused kernel on/off), chunked
    admission of a partially-cached prompt, preempt-then-restore via the
    surviving shared prefix, and sim-vs-live replay with the cache on;
  * eviction under pressure on an undersized pool.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # fallback shim, see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, st

from repro.core.adaptive import AdaptiveController, SpeculationLUT, fixed_controller
from repro.core.analytical import LatencyModel
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     ContinuousScheduler, ImmediateAdmit,
                                     PrefillBudgetAdmit, SimStepBackend,
                                     replay_sources)
from repro.serving.slots import BlockPool, PagedKVTables

BS = 4                                   # block size used by the host tests


def _kv(num_blocks=24, capacity=4, max_blocks=8, cache=True):
    kv = PagedKVTables(num_blocks, BS, capacity, max_blocks)
    pc = None
    if cache:
        pc = PrefixCache(kv.pool)
        kv.attach_cache(pc)
    return kv, pc


# ---------------------------------------------------------------------------
# refcount lifecycle (BlockPool)


def test_refcount_lifecycle():
    pool = BlockPool(6, BS)
    a, b = pool.alloc(2)
    assert pool.refcount(a) == pool.refcount(b) == 1
    pool.incref(a)
    assert pool.refcount(a) == 2 and pool.shared_count == 1
    assert pool.exclusive_count == 1
    assert pool.decref(a) is False       # still held once
    assert pool.decref(a) is True        # now actually freed
    assert pool.refcount(a) == 0 and a in pool._free
    pool.check_invariants()


def test_double_free_raises():
    pool = BlockPool(4, BS)
    (a,) = pool.alloc(1)
    pool.decref(a)
    with pytest.raises(RuntimeError):
        pool.decref(a)
    with pytest.raises(RuntimeError):
        pool.free([a])
    with pytest.raises(RuntimeError):
        pool.incref(a)                   # incref of a free block is a bug too


def test_bulk_free_returns_only_actually_freed():
    pool = BlockPool(6, BS)
    a, b, c = pool.alloc(3)
    pool.incref(b)                       # b shared with a second owner
    freed = pool.free([a, b, c])
    assert freed == [a, c]               # b survives at refcount 1
    assert pool.refcount(b) == 1
    assert pool.free([b]) == [b]
    pool.check_invariants()
    assert pool.free_count == 6


# ---------------------------------------------------------------------------
# radix index (PrefixCache)


def _toks(*vals):
    return np.asarray(vals, np.int32)


def test_radix_insert_match_roundtrip():
    kv, pc = _kv()
    kv.prefill(0, 3 * BS)
    tokens = np.arange(3 * BS, dtype=np.int32)
    added = pc.insert(tokens, kv.table(0))
    assert added == 3 and pc.size == 3
    assert pc.match(tokens) == kv.table(0)[:3]
    # partial-block tails never match (block granularity)
    assert pc.match(tokens[:2 * BS + 1]) == kv.table(0)[:2]
    # diverging tokens stop the walk at the shared prefix
    div = tokens.copy()
    div[2 * BS] += 1
    assert pc.match(div) == kv.table(0)[:2]
    assert pc.match(np.arange(100, 100 + BS, dtype=np.int32)) == []


def test_radix_first_writer_wins_and_rejects_double_index():
    kv, pc = _kv()
    tokens = np.arange(2 * BS, dtype=np.int32)
    kv.prefill(0, 2 * BS)
    kv.prefill(1, 2 * BS)
    pc.insert(tokens, kv.table(0))
    # same prefix from another slot: existing nodes keep the first blocks
    assert pc.insert(tokens, kv.table(1)) == 0
    assert pc.match(tokens) == kv.table(0)[:2]
    # a block id cannot back two different trie nodes
    with pytest.raises(RuntimeError):
        pc.insert(np.arange(50, 50 + BS, dtype=np.int32), [kv.table(0)[0]])
    with pytest.raises(ValueError):
        pc.insert(tokens, kv.table(0)[:1])   # fewer blocks than token blocks


def test_lock_pins_against_reclaim():
    kv, pc = _kv()
    t_a = np.arange(0, 2 * BS, dtype=np.int32)
    t_b = np.arange(100, 100 + BS, dtype=np.int32)
    kv.prefill(0, 2 * BS)
    kv.prefill(1, BS)
    pc.insert(t_a, kv.table(0))
    pc.insert(t_b, kv.table(1))
    b_unlocked = kv.table(1)[0]
    kv.release(0), kv.release(1)         # cache is now the only owner
    assert pc.reclaimable() == 3
    locked = pc.lock(t_a)
    assert len(locked) == 2
    # locked blocks are not evictable — only t_b's block goes
    evicted = pc.reclaim(10)
    assert evicted == [b_unlocked]
    assert set(evicted).isdisjoint(locked)
    assert pc.size == 2
    pc.unlock(locked)
    assert pc.reclaim(10) != [] and pc.size == 0
    kv.pool.check_invariants()
    assert kv.pool.free_count == kv.num_blocks


def test_reclaim_is_lru_and_leaf_first():
    kv, pc = _kv()
    t_a = np.arange(0, 3 * BS, dtype=np.int32)      # chain of 3
    kv.prefill(0, 3 * BS)
    pc.insert(t_a, kv.table(0))
    blocks = list(kv.table(0))
    kv.release(0)
    # the deepest node is the only leaf: eviction drains leaf-first even
    # though the root of the chain is older
    assert pc.reclaim(1) == [blocks[2]]
    assert pc.reclaim(1) == [blocks[1]]
    assert pc.reclaim(1) == [blocks[0]]
    # LRU across independent entries: older last_used goes first
    kv.prefill(0, BS)
    pc.insert(np.arange(100, 100 + BS, dtype=np.int32), kv.table(0))
    old = kv.table(0)[0]
    kv.release(0)
    kv.prefill(1, BS)
    pc.insert(np.arange(200, 200 + BS, dtype=np.int32), kv.table(1))
    new = kv.table(1)[0]
    kv.release(1)
    assert pc.reclaim(1) == [old]
    assert pc.reclaim(1) == [new]


# ---------------------------------------------------------------------------
# attach / COW over the slot tables


def test_attach_shares_blocks_and_cow_isolates_writes():
    kv, pc = _kv()
    tokens = np.arange(2 * BS, dtype=np.int32)
    kv.prefill(0, 2 * BS + 2)            # donor: 2 full blocks + a tail
    pc.insert(tokens, kv.table(0))
    locked = pc.lock(tokens)
    kv.attach(1, locked, 2 * BS)
    pc.unlock(locked)
    assert kv.table(1) == kv.table(0)[:2]
    assert all(kv.pool.refcount(b) == 3 for b in locked)  # donor+cache+slot1
    assert kv.shared_blocks == 2
    # slot 1 writes into the shared range: COW swaps in fresh copies
    pairs = kv.cow_for_range(1, 0, 2 * BS)
    assert [src for src, _ in pairs] == locked
    assert kv.table(1) != kv.table(0)[:2]
    assert all(kv.pool.refcount(dst) == 1 for _, dst in pairs)
    assert all(kv.pool.refcount(src) == 2 for src, _ in pairs)
    # donor's own table is untouched and still cache-indexed
    assert pc.match(tokens) == kv.table(0)[:2]
    kv.release(0), kv.release(1)
    kv.pool.check_invariants()


def test_attach_rejects_bad_geometry():
    kv, pc = _kv()
    kv.prefill(0, BS)
    pc.insert(np.arange(BS, dtype=np.int32), kv.table(0))
    locked = pc.lock(np.arange(BS, dtype=np.int32))
    kv.prefill(1, 2)
    with pytest.raises(RuntimeError):
        kv.attach(1, locked, BS)         # non-empty slot
    with pytest.raises(ValueError):
        kv.attach(2, locked, BS + 1)     # tokens not block-aligned
    pc.unlock(locked)


def test_alloc_reclaims_cache_blocks_on_demand():
    kv, pc = _kv(num_blocks=4, capacity=2, max_blocks=4)
    kv.prefill(0, 3 * BS)
    pc.insert(np.arange(3 * BS, dtype=np.int32), kv.table(0))
    kv.release(0)                        # 3 blocks cache-only, 1 free
    assert kv.free_blocks == 1 and kv.available_blocks == 4
    kv.prefill(1, 3 * BS)                # needs 3: evicts 2 from the cache
    assert kv.evicted_pending and kv.evicted_total == 2
    assert pc.size == 1
    kv.pool.check_invariants()


# ---------------------------------------------------------------------------
# sharing-aware fragmentation / occupancy accounting (satellite bugfix)


def test_fragmentation_counts_reclaimable_blocks():
    kv, pc = _kv(num_blocks=8, capacity=4, max_blocks=4)
    kv.prefill(0, BS)                    # block 0
    kv.prefill(1, BS)                    # block 1
    kv.prefill(2, BS)                    # block 2
    pc.insert(np.arange(100, 100 + BS, dtype=np.int32), kv.table(1))
    kv.release(1)                        # block 1: cache-only (reclaimable)
    # free list is [3..7]; naive free-list-only accounting would report the
    # 5-run as largest over 5 free => 0.0 fragmentation, hiding that block
    # 1 splits the *reclaimable* space. Sharing-aware accounting scans
    # free ∪ reclaimable = {1,3,4,5,6,7}: largest run 5 of 6.
    assert kv.available_blocks == 6
    assert kv.fragmentation == pytest.approx(1 - 5 / 6)
    # a cache-held block that is also slot-shared is NOT reclaimable and
    # must not count as available space
    locked = pc.lock(np.arange(100, 100 + BS, dtype=np.int32))
    assert kv.available_blocks == 5
    assert kv.fragmentation == pytest.approx(0.0)
    pc.unlock(locked)


def test_shared_vs_exclusive_gauges():
    kv, pc = _kv()
    kv.prefill(0, 2 * BS)
    tokens = np.arange(2 * BS, dtype=np.int32)
    pc.insert(tokens, kv.table(0))
    assert kv.shared_blocks == 2         # slot 0 + cache
    assert kv.cached_blocks == 2
    locked = pc.lock(tokens)
    kv.attach(1, locked, 2 * BS)
    pc.unlock(locked)
    assert kv.shared_blocks == 2 and kv.pool.exclusive_count == 0
    kv.release(0), kv.release(1)
    assert kv.shared_blocks == 0 and kv.cached_blocks == 2
    assert kv.pool.exclusive_count == 2  # cache is now the only owner


# ---------------------------------------------------------------------------
# property-based campaign: random interleavings vs a shadow reference model


class _Machine:
    """Drives PagedKVTables + PrefixCache with randomized operations and
    checks the standing invariants against a shadow model after each one.

    Shadow model: the expected refcount of every block is (number of slot
    tables containing it) + (1 if the cache indexes it).  No block leaks:
    blocks with expected refcount 0 are exactly the free list.
    """

    PREFIXES = 3                          # shared system-prompt vocabulary

    def __init__(self, num_blocks=16, capacity=4, max_blocks=6):
        self.kv, self.pc = _kv(num_blocks, capacity, max_blocks)
        self.capacity = capacity
        self.max_rows = max_blocks * BS
        self.slots = {}                   # slot -> (tokens, tainted)
        self.rid = 0

    # -- op helpers --------------------------------------------------------

    def _prompt(self, seed):
        rng = np.random.default_rng(seed)
        pfx = int(rng.integers(self.PREFIXES))
        n_pre = int(rng.integers(1, 3))          # 1-2 shared blocks
        tail = rng.integers(0, 5)
        sys = np.arange(1000 * pfx, 1000 * pfx + n_pre * BS, dtype=np.int32)
        tl = rng.integers(0, 30, (int(tail),)).astype(np.int32) + 5000
        return np.concatenate([sys, tl])

    def admit(self, seed):
        free = [s for s in range(self.capacity) if s not in self.slots]
        if not free:
            return
        slot = free[0]
        prompt = self._prompt(seed)
        total = len(prompt) + 1                  # +1: the first decode row
        locked = self.pc.lock(prompt)
        P = len(locked) * BS
        need = (self.kv.blocks_for(total) - P // BS
                + (1 if P == total else 0))
        if need > self.kv.available_blocks:
            self.pc.unlock(locked)               # admission abort
            return
        if P:
            self.kv.attach(slot, locked, P)
            self.pc.unlock(locked)
            self.kv.ensure(slot, total)
            self.kv.commit(slot, total - P)
        else:
            self.pc.unlock(locked)
            self.kv.prefill(slot, total)
        self.kv.evicted_pending.clear()
        self.slots[slot] = [prompt, False]

    def write(self, seed):
        """COW-write a random row range of a random slot.  The standing
        invariant: after cow_for_range, every block covering the range is
        exclusively owned — an in-place write would have been illegal on
        any block the cow pass had to copy."""
        if not self.slots:
            return
        rng = np.random.default_rng(seed)
        slot = list(self.slots)[int(rng.integers(len(self.slots)))]
        n = self.kv.tokens(slot)
        lo = int(rng.integers(n))
        hi = int(rng.integers(lo, n)) + 1
        covered = self.kv.table(slot)[lo // BS:self.kv.blocks_for(hi)]
        n_copies = sum(self.kv.pool.refcount(b) > 1 for b in covered)
        if n_copies > self.kv.available_blocks:
            # a real scheduler preempts before COW can exhaust the pool
            # (admission reserves the copy block up front)
            return
        shared_before = [b for b in self.kv.table(slot)[lo // BS:]
                        if self.kv.pool.refcount(b) > 1]
        pairs = self.kv.cow_for_range(slot, lo, hi)
        self.kv.evicted_pending.clear()
        for bi in range(lo // BS, self.kv.blocks_for(hi)):
            b = self.kv.table(slot)[bi]
            others = sum(b in self.kv.table(s) for s in self.slots
                         if s != slot)
            assert self.kv.pool.refcount(b) == 1 + others + (
                b in self.pc._blocks) and others == 0 and \
                b not in self.pc._blocks, \
                f"post-COW block {b} still shared (refs " \
                f"{self.kv.pool.refcount(b)})"
        if pairs and shared_before:
            # sources survive the copy (cache/donor still reference them)
            assert all(self.kv.pool.refcount(src) >= 1 for src, _ in pairs)
        if lo // BS < len(self.slots[slot][0]) // BS:
            # the write touched a full-prompt block: its content no longer
            # matches the prompt tokens, so this slot must never insert
            self.slots[slot][1] = True

    def insert(self, seed):
        if not self.slots:
            return
        rng = np.random.default_rng(seed)
        slot = list(self.slots)[int(rng.integers(len(self.slots)))]
        prompt, tainted = self.slots[slot]
        if tainted:                              # blocks no longer hold prompt
            return
        # full prompt blocks only — the partial tail block (which also
        # holds the decode row) is never indexed
        n_ins = len(prompt) // BS
        if not n_ins:
            return
        self.pc.insert(prompt[:n_ins * BS], self.kv.table(slot)[:n_ins])
        # round-trip: the inserted prefix is immediately matchable
        got = self.pc.match(prompt[:n_ins * BS])
        assert len(got) == n_ins

    def retire(self, seed):
        if not self.slots:
            return
        rng = np.random.default_rng(seed)
        slot = list(self.slots)[int(rng.integers(len(self.slots)))]
        freed = self.kv.release(slot)
        for b in freed:
            assert self.kv.pool.refcount(b) == 0
        del self.slots[slot]

    def evict(self, seed):
        rng = np.random.default_rng(seed)
        before = self.pc.size
        evicted = self.pc.reclaim(int(rng.integers(1, 4)))
        assert self.pc.size == before - len(evicted)
        for b in evicted:
            assert self.kv.pool.refcount(b) == 0
            assert b not in self.pc._blocks

    def lock_cycle(self, seed):
        """Lock a prefix, apply reclaim pressure, verify the locked blocks
        survive, then release the lock (eviction-races-admission)."""
        prompt = self._prompt(seed)
        locked = self.pc.lock(prompt)
        evicted = self.pc.reclaim(2)
        assert set(evicted).isdisjoint(locked)
        for b in locked:                         # still valid to attach
            assert self.kv.pool.refcount(b) >= 1
        self.pc.unlock(locked)

    OPS = (admit, write, insert, retire, evict, lock_cycle)

    # -- invariants --------------------------------------------------------

    def check(self):
        pool = self.kv.pool
        pool.check_invariants()                  # partition + free-list shape
        expected = [0] * self.kv.num_blocks
        for slot in self.slots:
            for b in self.kv.table(slot):
                expected[b] += 1
        for b in self.pc._blocks:
            expected[b] += 1
        for b in range(self.kv.num_blocks):
            assert pool.refcount(b) == expected[b], \
                f"block {b}: refcount {pool.refcount(b)} != " \
                f"shadow {expected[b]} (leak or double-count)"
        free = {b for b in range(self.kv.num_blocks) if expected[b] == 0}
        assert set(pool._free) == free, "free list != zero-ref blocks"


@settings(max_examples=500)
@given(st.lists(st.tuples(st.integers(0, len(_Machine.OPS) - 1),
                          st.integers(0, 10**6)),
                min_size=1, max_size=40))
def test_property_interleavings(ops):
    """500 random admit/share/COW-write/insert/retire/evict interleavings
    keep every refcount invariant."""
    m = _Machine()
    for code, seed in ops:
        _Machine.OPS[code](m, seed)
        m.check()


@settings(max_examples=24)
@given(st.lists(st.tuples(st.integers(0, len(_Machine.OPS) - 1),
                          st.integers(0, 10**6)),
                min_size=30, max_size=120),
       st.booleans())
def test_property_interleavings_under_pressure(ops, tiny):
    """Same campaign on an undersized pool: allocation-triggered eviction
    races admission and the invariants must still hold."""
    m = _Machine(num_blocks=6 if tiny else 9, capacity=3, max_blocks=5)
    for code, seed in ops:
        _Machine.OPS[code](m, seed)
        m.check()
    assert m.kv.evicted_total >= 0       # counter only moves forward


# ---------------------------------------------------------------------------
# scheduler-level eviction under pressure (sim backend)


def _model(batches=(1, 2, 4, 8, 16, 32)):
    return LatencyModel(alpha={b: 1e-4 * b ** 0.8 for b in batches},
                        beta={b: 5e-3 for b in batches},
                        t_s={b: 2e-4 for b in batches}, c=0.9, gamma=0.548)


def _sim_reqs(n, sys_len=16, tail=5, max_new=8):
    sys = np.arange(100, 100 + sys_len, dtype=np.int32)
    out = []
    for i in range(n):
        toks = np.concatenate(
            [sys, np.arange(1000 * i, 1000 * i + tail, dtype=np.int32)])
        out.append(Request(rid=i, arrival=0.0, tokens=toks,
                           prompt_len=len(toks), max_new=max_new))
    return out


def test_sim_shared_vs_cold_scheduling_signature():
    """With ImmediateAdmit and a roomy pool the cache changes *when work
    happens inside an iteration*, never *what the scheduler decides*: the
    full scheduling signature is identical to a cold run."""
    def run(cache):
        be = SimStepBackend(_model(), capacity=4, seed=3, block_size=8,
                            num_blocks=40, max_context=64,
                            prefix_cache=cache, prefill_token_cost=1e-3)
        sched = ContinuousScheduler(be, fixed_controller(4),
                                    policy=ImmediateAdmit())
        res = sched.run(_sim_reqs(4))
        return be, sched, res

    be_c, sc_c, res_c = run(True)
    be_0, sc_0, res_0 = run(False)
    sig = lambda tr: [(t.occupancy, t.s, t.rids,
                       tuple(sorted(t.committed.items())), t.admitted,
                       t.preempted, t.done_rids) for t in tr]
    assert sig(sc_c.trace) == sig(sc_0.trace)
    hits = [h for t in sc_c.trace for h in t.cache_hits]
    assert hits and all(p == 16 for _, p in hits)
    assert all(not t.cache_hits for t in sc_0.trace)
    be_c.kv.pool.check_invariants()
    # cached prefills fed fewer rows => strictly earlier first tokens
    assert (sum(r.first_token for r in res_c.requests)
            < sum(r.first_token for r in res_0.requests))


def test_sim_replay_with_cache_hits():
    """replay_sources over a cache-on trace reproduces it exactly —
    including the cache_hits column (chunked admission path)."""
    def build(**src):
        return SimStepBackend(_model(), capacity=4, seed=3, block_size=8,
                              num_blocks=40, max_context=96,
                              prefix_cache=True, **src)

    reqs = lambda: _sim_reqs(3, sys_len=16, tail=20)
    be = build(prefill_token_cost=1e-3)
    sched = ContinuousScheduler(be, fixed_controller(4),
                                policy=PrefillBudgetAdmit(token_budget=16,
                                                          chunk=8))
    sched.run(reqs())
    assert any(t.cache_hits for t in sched.trace)
    assert any(t.chunked for t in sched.trace)
    accept, duration, prefill, done, chunk = replay_sources(sched.trace)
    be2 = build(accept_source=accept, duration_source=duration,
                prefill_source=prefill, done_source=done, chunk_source=chunk)
    sched2 = ContinuousScheduler(be2, fixed_controller(4),
                                 policy=PrefillBudgetAdmit(token_budget=16,
                                                           chunk=8))
    sched2.run(reqs())
    assert sched2.trace == sched.trace


def test_sim_eviction_under_pressure_completes():
    """Undersized pool: cache blocks are evicted to make room, admissions
    never map evicted blocks (the lock protocol), every request completes,
    and the pool accounting survives."""
    be = SimStepBackend(_model(), capacity=3, seed=3, block_size=4,
                        num_blocks=12, max_context=48, prefix_cache=True,
                        prefill_token_cost=1e-3)
    sched = ContinuousScheduler(be, fixed_controller(2))
    reqs = _sim_reqs(10, sys_len=8, tail=8, max_new=6)
    res = sched.run(reqs)
    assert all(r.n_generated == r.max_new for r in res.requests)
    assert be.kv.evicted_total > 0       # pressure actually evicted
    assert be.cache.hits > 0             # and sharing still happened
    assert any(t.preempted for t in sched.trace)  # preemption raced it too
    be.kv.pool.check_invariants()
    # every slot retired: only the cache may still hold blocks
    assert be.kv.active_slots() == []
    assert (be.kv.free_blocks + be.kv.cached_blocks) == be.kv.num_blocks


def test_gauges_reach_telemetry():
    """The scheduler publishes cache gauges every iteration."""
    from repro.serving.telemetry import Telemetry
    tel = Telemetry()
    be = SimStepBackend(_model(), capacity=4, seed=0, block_size=8,
                        num_blocks=40, max_context=64, prefix_cache=True)
    sched = ContinuousScheduler(be, fixed_controller(2), telemetry=tel)
    sched.run(_sim_reqs(3))
    assert tel.iterations > 0, "telemetry hub recorded no iterations"
    for key in ("cache_hit_rate", "shared_blocks", "cached_blocks",
                "evicted_blocks", "cache_hit_tokens"):
        assert key in tel.gauges
    assert tel.gauges["cache_hit_rate"] > 0
    assert tel.gauges["cache_hit_tokens"] >= 16


# ---------------------------------------------------------------------------
# live-engine parity (token- and StepTrace-identity vs cold)

CACHE_LEN = 96


@pytest.fixture(scope="module")
def engine():
    import jax
    from repro.configs import registry as R
    from repro.core.spec_decode import SpecDecodeEngine
    tcfg = R.get_smoke_config("yi-9b")
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2,
                                 head_dim=32))
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=24)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = eng.draft.init(jax.random.PRNGKey(1))
    return eng, tp, dp, tcfg


def _ctrl():
    return AdaptiveController(lut=SpeculationLUT({1: 4, 2: 3, 4: 2}))


def _live_reqs(tcfg, n=4, sys_len=16, tail=5, max_new=8, seed=5):
    rng = np.random.default_rng(seed)
    sys = rng.integers(0, tcfg.vocab_size, (sys_len,)).astype(np.int32)
    out = []
    for i in range(n):
        toks = np.concatenate(
            [sys, rng.integers(0, tcfg.vocab_size, (tail,)).astype(np.int32)])
        out.append(Request(rid=i, arrival=0.0, tokens=toks,
                           prompt_len=len(toks), max_new=max_new))
    return out


def _run_live(engine, reqs, *, prefix_cache, policy=None, num_blocks=48,
              capacity=4, paged_fused=None, s_cap=4):
    eng, tp, dp, _ = engine
    be = ContinuousEngineBackend(eng, tp, dp, capacity=capacity,
                                 cache_len=CACHE_LEN, warm_s=[2, 3, 4],
                                 block_size=8, num_blocks=num_blocks,
                                 collect_outputs=True, s_cap=s_cap,
                                 paged_fused=paged_fused,
                                 prefix_cache=prefix_cache)
    sched = ContinuousScheduler(be, _ctrl(), policy=policy)
    sched.run(reqs)
    return be, sched


def _sig(trace):
    return [(t.occupancy, t.s, t.rids, tuple(sorted(t.committed.items())),
             t.admitted, t.preempted, t.done_rids) for t in trace]


@pytest.mark.parametrize("fused", [False, True])
def test_live_shared_vs_cold_identity(engine, fused):
    """Shared-prefix serving is token- AND StepTrace-identical to cold,
    on both paged kernel paths."""
    reqs = lambda: _live_reqs(engine[3])
    be_c, sc_c = _run_live(engine, reqs(), prefix_cache=True,
                           paged_fused=fused)
    be_0, sc_0 = _run_live(engine, reqs(), prefix_cache=False,
                           paged_fused=fused)
    hits = [h for t in sc_c.trace for h in t.cache_hits]
    assert len(hits) == 3 and all(p == 16 for _, p in hits)
    for rid in range(4):
        np.testing.assert_array_equal(be_c.outputs[rid], be_0.outputs[rid],
                                      err_msg=f"rid {rid}")
        assert len(be_c.outputs[rid]) == 8
    assert _sig(sc_c.trace) == _sig(sc_0.trace)
    be_c.kv.pool.check_invariants()


def test_live_chunked_partial_hit(engine):
    """Chunked admission of a partially-cached prompt: the cached prefix is
    attached, only the uncached suffix is fed through the chunk machinery,
    and token outputs equal the cold run's."""
    reqs = lambda: _live_reqs(engine[3], n=3, sys_len=16, tail=20,
                              max_new=6, seed=9)
    pol = lambda: PrefillBudgetAdmit(token_budget=16, chunk=8)
    be_c, sc_c = _run_live(engine, reqs(), prefix_cache=True, policy=pol())
    be_0, sc_0 = _run_live(engine, reqs(), prefix_cache=False, policy=pol())
    hits = [h for t in sc_c.trace for h in t.cache_hits]
    assert hits, "no cache hit on the shared prefix"
    assert any(t.chunked for t in sc_c.trace)
    for rid in range(3):
        np.testing.assert_array_equal(be_c.outputs[rid], be_0.outputs[rid],
                                      err_msg=f"rid {rid}")
    be_c.kv.pool.check_invariants()


def test_live_preempt_then_restore_shared_prefix(engine):
    """An undersized pool forces preemption; the victim's re-admission
    re-attaches the surviving shared prefix (a cache hit for a rid that
    was preempted) and final tokens equal the roomy cold run."""
    # 12 blocks: all four admit cheaply through the shared prefix (need is
    # ~1 block each past the 2 shared), but full growth to 19+16 tokens
    # wants 2 shared + 4×3 exclusive = 14 blocks, so decode must preempt
    mk = lambda: _live_reqs(engine[3], n=4, sys_len=16, tail=3, max_new=16,
                            seed=11)
    be_c, sc_c = _run_live(engine, mk(), prefix_cache=True, num_blocks=12,
                           capacity=4, s_cap=4)
    preempted = [r for t in sc_c.trace for r in t.preempted]
    assert preempted, "pool was not small enough to force preemption"
    hits = [h for t in sc_c.trace for h in t.cache_hits]
    hit_rids = {rid for rid, _ in hits}
    assert hit_rids & set(preempted), \
        "no preempted request re-admitted via the shared prefix"
    be_0, _ = _run_live(engine, mk(), prefix_cache=False, num_blocks=48)
    for rid in range(4):
        np.testing.assert_array_equal(be_c.outputs[rid], be_0.outputs[rid],
                                      err_msg=f"rid {rid}")
    be_c.kv.pool.check_invariants()


def test_sim_vs_live_replay_with_cache(engine):
    """A cache-on live trace replays exactly on a cache-on sim backend with
    the live pool geometry — including the cache_hits column."""
    reqs = lambda: _live_reqs(engine[3], n=4, sys_len=16, tail=5, max_new=8)
    be, sc = _run_live(engine, reqs(), prefix_cache=True)
    accept, duration, prefill, done, chunk = replay_sources(sc.trace)
    sim = SimStepBackend(_model(), capacity=4, seed=0, block_size=8,
                         num_blocks=48, max_context=CACHE_LEN,
                         prefix_cache=True, accept_source=accept,
                         duration_source=duration, prefill_source=prefill,
                         done_source=done, chunk_source=chunk)
    sched = ContinuousScheduler(sim, _ctrl())
    sched.run(reqs())
    assert sched.trace == sc.trace
