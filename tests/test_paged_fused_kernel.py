"""Fused paged-attention kernel: interpret-mode parity vs kernels/ref.py and
vs the materialized gather+verify path — ragged block tables with -1 holes,
sliding window, bidirectional prefix, int8 pool scales, the ≤1-block gather
fast path, the sublane block-size fix, and a scheduler-level
serve_continuous_live run that must be token- and StepTrace-identical with
the fused kernel on vs off.  All fast tier (citier `kernels` runs the
kernel-parity subset)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.core.adaptive import AdaptiveController, SpeculationLUT
from repro.core.spec_decode import SpecDecodeEngine
from repro.kernels import ref as KR
from repro.kernels.paged import (gather_key_positions, gather_kv_blocks,
                                 gather_scales, gather_verify_attn,
                                 paged_verify_attn)
from repro.kernels.paged_verify_attn import paged_verify_attn_pallas
from repro.kernels.spec_verify_attn import choose_block_k
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousEngineBackend,
                                     PrefillBudgetAdmit,
                                     serve_continuous_live)

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, k=0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape).astype(dtype)


def _pool(B, lens, NB, bs, MAXB, KVH, hd, seed=0, holes=()):
    """Build a ragged paged pool: per-slot block tables (optionally with
    interior -1 holes — e.g. a preempted slot's partially-rebuilt table),
    the pool pos map, and k/v pool arrays with garbage in unowned blocks."""
    rng = np.random.default_rng(seed)
    k = _rand((NB, bs, KVH, hd), k=seed + 1)
    v = _rand((NB, bs, KVH, hd), k=seed + 2)
    bt = np.full((B, MAXB), -1, np.int32)
    pos = np.full((NB, bs), -1, np.int32)
    order = rng.permutation(NB)
    nxt = 0
    for b, L in enumerate(lens):
        nblk = -(-L // bs) if L else 0
        for j in range(nblk):
            if (b, j) in holes:
                continue
            pb = int(order[nxt]); nxt += 1
            bt[b, j] = pb
            for o in range(bs):
                p = j * bs + o
                if p < L:
                    pos[pb, o] = p
    return k, v, jnp.asarray(bt), jnp.asarray(pos)


def _qpos(lens, T):
    return jnp.asarray(np.stack([
        np.arange(T, dtype=np.int32) + (L - 1) if L else
        np.full(T, -1, np.int32) for L in lens]))


# ---------------------------------------------------------------------------
# kernel-level parity (interpret mode executes the real kernel body)


@pytest.mark.parametrize("T,H,KVH", [(1, 2, 2), (4, 4, 2), (6, 4, 1)])
def test_fused_matches_gather_and_ref(T, H, KVH):
    B, hd, NB, bs, MAXB = 3, 32, 12, 8, 3
    lens = [13, 24, 7]
    k, v, bt, pos = _pool(B, lens, NB, bs, MAXB, KVH, hd, seed=3)
    q = _rand((B, T, H, hd), k=9)
    qp = _qpos(lens, T)
    got = paged_verify_attn_pallas(q, k, v, qp, pos, bt, interpret=True)
    via_gather = gather_verify_attn(q, k, v, qp, pos, bt, use_pallas=False)
    kg, vg = gather_kv_blocks(k, v, bt)
    want = KR.gqa_masked_ref(q, kg, vg, qp, gather_key_positions(pos, bt))
    np.testing.assert_allclose(np.asarray(got), np.asarray(via_gather),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_ragged_tables_with_holes_and_empty_slot():
    """-1 entries anywhere in the table (trailing raggedness, interior
    holes, a fully empty slot) must contribute nothing — exactly the
    gather path's k_pos = -1 convention."""
    B, T, H, KVH, hd, NB, bs, MAXB = 4, 3, 4, 2, 32, 16, 8, 4
    lens = [30, 9, 0, 17]
    # slot 3 has an interior hole at logical block 1: its rows are simply
    # not attendable (the gather path surfaces them as k_pos = -1)
    k, v, bt, pos = _pool(B, lens, NB, bs, MAXB, KVH, hd, seed=5,
                          holes={(3, 1)})
    assert int(np.asarray(bt)[3, 1]) == -1 and int(np.asarray(bt)[3, 2]) >= 0
    q = _rand((B, T, H, hd), k=11)
    qp = _qpos(lens, T)
    got = paged_verify_attn_pallas(q, k, v, qp, pos, bt, interpret=True)
    want = gather_verify_attn(q, k, v, qp, pos, bt, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # the empty slot's rows are fully masked -> exact zeros on both paths
    assert np.all(np.asarray(got)[2] == 0)


@pytest.mark.parametrize("window,prefix", [(11, 0), (None, 5), (11, 5)])
def test_fused_window_and_prefix_masking(window, prefix):
    B, T, H, KVH, hd, NB, bs, MAXB = 2, 4, 4, 2, 32, 10, 8, 3
    lens = [22, 15]
    k, v, bt, pos = _pool(B, lens, NB, bs, MAXB, KVH, hd, seed=7)
    q = _rand((B, T, H, hd), k=13)
    qp = _qpos(lens, T)
    got = paged_verify_attn_pallas(q, k, v, qp, pos, bt, window=window,
                                   prefix_len=prefix, interpret=True)
    want = gather_verify_attn(q, k, v, qp, pos, bt, window=window,
                              prefix_len=prefix, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_int8_scales_dequant_in_kernel():
    B, T, H, KVH, hd, NB, bs, MAXB = 2, 4, 4, 2, 32, 10, 8, 3
    lens = [19, 8]
    k, v, bt, pos = _pool(B, lens, NB, bs, MAXB, KVH, hd, seed=17)
    ks = jnp.max(jnp.abs(k), -1) / 127.0 + 1e-8          # [NB, bs, KVH]
    vs = jnp.max(jnp.abs(v), -1) / 127.0 + 1e-8
    kq = jnp.clip(jnp.round(k / ks[..., None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v / vs[..., None]), -127, 127).astype(jnp.int8)
    q = _rand((B, T, H, hd), k=19)
    qp = _qpos(lens, T)
    got = paged_verify_attn_pallas(q, kq, vq, qp, pos, bt,
                                   k_scale=ks, v_scale=vs, interpret=True)
    want = gather_verify_attn(q, kq, vq, qp, pos, bt, k_scale=ks, v_scale=vs,
                              use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_paged_verify_attn_dispatch_modes_agree():
    """The public dispatcher: forced-ref, forced-pallas (interpret on CPU),
    and the gather+Pallas-verify combination all agree."""
    B, T, H, KVH, hd, NB, bs, MAXB = 2, 3, 4, 2, 32, 8, 8, 2
    lens = [12, 10]
    k, v, bt, pos = _pool(B, lens, NB, bs, MAXB, KVH, hd, seed=23)
    q = _rand((B, T, H, hd), k=29)
    qp = _qpos(lens, T)
    ref = paged_verify_attn(q, k, v, qp, pos, bt, use_pallas=False)
    fused = paged_verify_attn(q, k, v, qp, pos, bt, use_pallas=True)
    gather_pallas = gather_verify_attn(q, k, v, qp, pos, bt, use_pallas=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gather_pallas), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# gather fast path (MAXB == 1) and the sublane block-size fix


def test_gather_single_block_fast_path_matches_general():
    KVH, hd, NB, bs = 2, 16, 6, 8
    k = _rand((NB, bs, KVH, hd), k=31)
    v = _rand((NB, bs, KVH, hd), k=32)
    scale = jnp.abs(_rand((NB, bs, KVH), k=33)) + 0.1
    pos = jnp.where(_rand((NB, bs), k=34) > 0,
                    jnp.arange(bs, dtype=jnp.int32)[None, :], -1)
    bt1 = jnp.asarray([[3], [-1], [0]], jnp.int32)       # MAXB == 1
    kg, vg = gather_kv_blocks(k, v, bt1)
    kp = gather_key_positions(pos, bt1)
    sg = gather_scales(scale, bt1)
    assert kg.shape == (3, bs, KVH, hd) and kp.shape == (3, bs)
    safe = np.maximum(np.asarray(bt1)[:, 0], 0)
    np.testing.assert_array_equal(np.asarray(kg), np.asarray(k)[safe])
    np.testing.assert_array_equal(np.asarray(vg), np.asarray(v)[safe])
    np.testing.assert_array_equal(np.asarray(sg), np.asarray(scale)[safe])
    # the empty slot's positions are forced to -1 despite aliasing block 0
    assert np.all(np.asarray(kp)[1] == -1)
    np.testing.assert_array_equal(np.asarray(kp)[0], np.asarray(pos)[3])


def test_choose_block_k_never_degrades_to_tiny_tiles():
    for L, bk_req in [(97, 512), (97, 32), (100, 64), (8, 512), (3, 16),
                      (512, 512), (96, 16), (640, 512), (202, 512)]:
        bk, Lp = choose_block_k(L, bk_req)
        assert bk % 8 == 0 and bk >= 8, (L, bk_req, bk)
        assert Lp % bk == 0 and Lp >= L and Lp - L < bk, (L, bk_req, bk, Lp)
    # the old failure mode: prime L forced 1-row tiles; now the tail pads
    assert choose_block_k(97, 32)[0] == 32
    assert choose_block_k(512, 512) == (512, 512)        # aligned unchanged
    # a large divisor beats padding (zero-copy): 640 keeps the old bk=320,
    # and the 64-row search floor keeps 520/1000 on zero-copy divisor
    # tiles (104/200 — the old loop's 260/500 were not sublane-aligned)
    assert choose_block_k(640, 512) == (320, 640)
    assert choose_block_k(520, 512) == (104, 520)
    assert choose_block_k(1000, 512) == (200, 1000)
    assert choose_block_k(96, 16) == (16, 96)            # exact divisor
    # but a divisor below the 64-row floor is rejected in favor of
    # full-size padded tiles (the anti-degradation half of the policy)
    assert choose_block_k(136, 128) == (128, 256)        # not bk=8


@pytest.mark.parametrize("L", [97, 100, 37])
def test_verify_kernel_padded_tail_matches_ref(L):
    """Prime-ish cache lengths run with full-size padded tiles and still
    match the reference bit-for-bit on the unpadded rows."""
    from repro.kernels.spec_verify_attn import spec_verify_attn_pallas
    B, Tq, hd = 2, 4, 32
    q = _rand((B, Tq, hd), k=41)
    k = _rand((B, L, hd), k=42)
    v = _rand((B, L, hd), k=43)
    seq = L - Tq - 1
    qp = jnp.broadcast_to(jnp.arange(Tq, dtype=jnp.int32) + seq, (B, Tq))
    kp = jnp.where(jnp.arange(L) < seq + Tq, jnp.arange(L, dtype=jnp.int32), -1)
    kp = jnp.broadcast_to(kp, (B, L))
    got = spec_verify_attn_pallas(q, k, v, qp, kp, block_k=32, interpret=True)
    want = KR.spec_verify_ref(q, k, v, qp, kp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# sharded paged pools pin auto routing to the gather path


def test_sharded_paged_pool_pins_gather_unless_forced():
    """A mesh-sharded paged pool cannot run the fused kernel's prefetched
    block table through GSPMD (blocks are not shard-local), so auto routing
    (paged_fused=None) must pin the gather path — and restore auto on the
    next unsharded pool.  Forcing True is respected."""
    from repro.launch.mesh import make_serving_mesh
    tcfg = R.get_smoke_config("yi-9b")
    eng = SpecDecodeEngine(tcfg, None, max_new=8)
    mesh = make_serving_mesh(1)
    eng.init_slots(2, cache_len=32, block_size=8, mesh=mesh)
    assert eng.tcfg.paged_fused is False          # pinned for the mesh pool
    eng.init_slots(2, cache_len=32, block_size=8)
    assert eng.tcfg.paged_fused is None           # restored off-mesh
    forced = SpecDecodeEngine(tcfg, None, max_new=8, paged_fused=True)
    forced.init_slots(2, cache_len=32, block_size=8, mesh=mesh)
    assert forced.tcfg.paged_fused is True        # explicit force respected


# ---------------------------------------------------------------------------
# engine-level: the paged int8 (kv_quant) pool, fused vs gather vs solo


def test_engine_paged_kv_quant_matches_solo_both_kernels():
    """The paged pool's new int8 cache: a paged run (scale leaves injected
    block-wise, dequant in the kernel) must match the solo contiguous
    kv_quant run token-for-token on BOTH kernel paths."""
    tcfg = R.get_smoke_config("yi-9b").with_(kv_quant=True)
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2,
                                 head_dim=32))
    rng = np.random.default_rng(3)
    p = rng.integers(0, tcfg.vocab_size, (9,)).astype(np.int32)
    outs = {}
    ref = None
    for fused in (False, True):
        eng = SpecDecodeEngine(tcfg, dcfg, max_new=12, paged_fused=fused)
        tp = eng.target.init(jax.random.PRNGKey(0))
        dp = eng.draft.init(jax.random.PRNGKey(1))
        if ref is None:
            ref, _, _ = eng.generate(tp, dp, p[None, :],
                                     np.array([9], np.int32), s=3,
                                     cache_len=64)
        state = eng.init_slots(2, cache_len=64, block_size=8)
        assert "k_scale" in state.tcache and state.tcache["k"].dtype == jnp.int8
        state = eng.prefill_into(tp, dp, state, 0, p, len(p), 64)
        for _ in range(12):
            state, _ = eng.step(tp, dp, state, 3)
            if bool(np.asarray(state.done)[0]):
                break
        outs[fused] = np.asarray(state.out)[0, :12].copy()
    np.testing.assert_array_equal(outs[False], ref[0])
    np.testing.assert_array_equal(outs[False], outs[True])


# ---------------------------------------------------------------------------
# scheduler-level: fused on vs off must be token- and trace-identical


@pytest.fixture(scope="module")
def smoke_pair():
    tcfg = R.get_smoke_config("yi-9b")
    d = R.get_draft_config("yi-9b")
    dcfg = dataclasses.replace(
        d, n_layers=1, d_model=64, d_ff=128, vocab_size=tcfg.vocab_size,
        dtype="float32",
        attn=dataclasses.replace(d.attn, n_heads=2, n_kv_heads=2,
                                 head_dim=32))
    eng = SpecDecodeEngine(tcfg, dcfg, max_new=10)
    tp = eng.target.init(jax.random.PRNGKey(0))
    dp = eng.draft.init(jax.random.PRNGKey(1))
    return tcfg, dcfg, tp, dp


def _trace(tcfg, n=5):
    rng = np.random.default_rng(11)
    reqs = []
    for rid in range(n):
        L = int(rng.integers(5, 12))
        toks = rng.integers(0, tcfg.vocab_size, (L,)).astype(np.int32)
        reqs.append(Request(rid=rid, arrival=0.0, tokens=toks, prompt_len=L,
                            max_new=int(rng.integers(4, 9))))
    return reqs


@pytest.mark.parametrize("chunked", [False, True])
def test_serve_paged_fused_token_and_trace_identical(smoke_pair, chunked):
    """A full serve_continuous_live paged run with the fused kernel on vs
    off: token- and StepTrace-identical, with (``chunked=True``) the over-
    budget prompts admitted chunk-by-chunk so the fused prefix-extension
    chunk forward is on the measured path too."""
    tcfg, dcfg, tp, dp = smoke_pair
    ctrl = lambda: AdaptiveController(lut=SpeculationLUT({1: 3, 2: 2, 4: 2}))
    runs = {}
    for fused in (False, True):
        # the backend plumb (engine.set_paged_fused before init_slots) is
        # the serving-layer entry point; the engine ctor kwarg is covered
        # by the engine-level parity below
        eng = SpecDecodeEngine(tcfg, dcfg, max_new=10)
        be = ContinuousEngineBackend(eng, tp, dp, capacity=3, cache_len=32,
                                     warm_s=[2, 3], block_size=8,
                                     collect_outputs=True, paged_fused=fused)
        assert eng.tcfg.paged_fused is fused
        policy = PrefillBudgetAdmit(token_budget=6) if chunked else None
        res = serve_continuous_live(_trace(tcfg), eng, tp, dp, ctrl(),
                                    backend=be, policy=policy)
        runs[fused] = (res, be)
    (r0, b0), (r1, b1) = runs[False], runs[True]
    t0, t1 = r0.trace, r1.trace
    assert [t.admitted for t in t0] == [t.admitted for t in t1]
    assert [t.occupancy for t in t0] == [t.occupancy for t in t1]
    assert [t.committed for t in t0] == [t.committed for t in t1]
    assert [t.preempted for t in t0] == [t.preempted for t in t1]
    assert [t.done_rids for t in t0] == [t.done_rids for t in t1]
    assert [t.chunked for t in t0] == [t.chunked for t in t1]
    if chunked:
        assert sum(len(t.chunked) for t in t0) > 0   # chunk path exercised
    assert set(b0.outputs) == set(b1.outputs) and len(b0.outputs) == 5
    for rid in b0.outputs:
        np.testing.assert_array_equal(b0.outputs[rid], b1.outputs[rid],
                                      err_msg=f"rid {rid}")
